# int4 group quantization: roundtrip error bounds + packing invariants.
# The Rust side (model/quant.rs) implements the identical scheme; its unit
# tests pin the same constants so the two stay bit-compatible.

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=15, deadline=None)
@given(
    din=st.sampled_from([64, 128, 256]),
    dout=st.sampled_from([16, 64, 96]),
    seed=st.integers(0, 2**16),
)
def test_roundtrip_error_bound(din, dout, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (din, dout)) * 0.1
    packed, scales = quant.quantize(w)
    w2 = quant.dequantize(packed, scales)
    # max error per element is half a quantization step = scale/2 per group
    step = np.repeat(np.asarray(scales), quant.GROUP, axis=0)
    assert np.all(np.abs(np.asarray(w2 - w)) <= step / 2 + 1e-7)


def test_packed_shapes():
    w = jnp.ones((128, 32))
    packed, scales = quant.quantize(w)
    assert packed.shape == (64, 32) and packed.dtype == jnp.uint8
    assert scales.shape == (128 // quant.GROUP, 32)


def test_exact_on_grid_values():
    """Weights already on the int4 grid roundtrip exactly."""
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, size=(128, 8)).astype(np.float32)
    w = jnp.asarray(q * 0.01)
    packed, scales = quant.quantize(w)
    w2 = quant.dequantize(packed, scales)
    # scale = max|w|/7 per group; values at multiples of scale survive when
    # the group max is 7*step or 8... only check error vs half-step bound
    step = np.repeat(np.asarray(scales), quant.GROUP, axis=0)
    assert np.all(np.abs(np.asarray(w2) - np.asarray(w)) <= step / 2 + 1e-8)


def test_zero_weights():
    w = jnp.zeros((64, 16))
    packed, scales = quant.quantize(w)
    np.testing.assert_allclose(np.asarray(quant.dequantize(packed, scales)),
                               0.0, atol=0)


def test_memory_ratio():
    """The whole point: packed bytes ≈ 0.5 B/param + scales."""
    din, dout = 1024, 512
    w = jax.random.normal(jax.random.PRNGKey(1), (din, dout))
    packed, scales = quant.quantize(w)
    f32_bytes = din * dout * 4
    q_bytes = packed.size + scales.size * 4
    assert q_bytes < f32_bytes / 7  # > 7x smaller than f32
