# L2 correctness: the manually derived backward passes (paper Appendix A)
# against jax autodiff, for every backward variant the runtime ships —
# this is the paper's "mathematically identical gradients" claim, asserted.

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")


def make_inputs(cfg, seed=0, scale=0.05):
    ks = iter(jax.random.split(jax.random.PRNGKey(seed), 64))

    def rnd(shape, s=scale):
        return jax.random.normal(next(ks), shape, jnp.float32) * s

    frozen = [rnd(cfg.frozen_shapes()[n]) for n in M.FROZEN]
    # norm weights near 1, as in a real model
    frozen[0] = frozen[0] * 0.1 + 1.0
    frozen[5] = frozen[5] * 0.1 + 1.0
    lora = []
    for p in M.PROJS:
        lora.append(rnd(cfg.lora_shapes()[f"a_{p}"], 0.1))
        lora.append(rnd(cfg.lora_shapes()[f"b_{p}"], 0.1))
    x = rnd((cfg.batch, cfg.seq, cfg.d_model), 0.5)
    gy = rnd((cfg.batch, cfg.seq, cfg.d_model), 0.5)
    return x, gy, frozen, lora


def assert_close(got, want, rtol=3e-4, atol=3e-6):
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol,
            err_msg=f"output {i}")


@pytest.fixture(scope="module")
def toy():
    return CONFIGS["toy"]


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_mesp_equals_autodiff(seed):
    """Paper §5.5/Appendix A: MeSP computes mathematically identical
    gradients to framework autodiff."""
    cfg = CONFIGS["toy"]
    x, gy, frozen, lora = make_inputs(cfg, seed)
    got = M.block_bwd_mesp(cfg, x, gy, frozen, lora)
    want = M.block_bwd_autodiff(cfg, x, gy, frozen, lora)
    assert len(got) == 1 + 2 * len(M.PROJS)
    assert_close(got, want)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_storeh_equals_autodiff(seed):
    cfg = CONFIGS["toy"]
    x, gy, frozen, lora = make_inputs(cfg, seed)
    saved = M.block_fwd_saveh(cfg, x, frozen, lora)
    got = M.block_bwd_storeh(cfg, x, gy, saved[1:], frozen, lora)
    assert_close(got, M.block_bwd_autodiff(cfg, x, gy, frozen, lora))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_residuals_equals_autodiff(seed):
    """The MeBP two-phase path (fwd saves residuals → bwd consumes them)
    produces the same gradients as fused autodiff."""
    cfg = CONFIGS["toy"]
    x, gy, frozen, lora = make_inputs(cfg, seed)
    res = M.block_fwd_residuals(cfg, x, frozen, lora)
    got = M.block_bwd_residuals(cfg, gy, res[1:], frozen, lora)
    assert_close(got, M.block_bwd_autodiff(cfg, x, gy, frozen, lora))


def test_flash_config_matches_probs_config():
    """config.attention='flash' (all-Pallas path) computes the same forward
    and backward as the default path."""
    cfg = CONFIGS["toy"]
    cfgf = CONFIGS["toy_flash"]
    x, gy, frozen, lora = make_inputs(cfg, 123)
    y0 = M.block_fwd(cfg, x, frozen, lora)[0]
    yf = M.block_fwd(cfgf, x, frozen, lora)[0]
    np.testing.assert_allclose(np.asarray(yf), np.asarray(y0),
                               rtol=3e-4, atol=3e-6)
    assert_close(M.block_bwd_mesp(cfgf, x, gy, frozen, lora),
                 M.block_bwd_autodiff(cfg, x, gy, frozen, lora),
                 rtol=6e-4, atol=6e-6)


def test_all_variants_same_forward(toy):
    x, _, frozen, lora = make_inputs(toy, 9)
    y = M.block_fwd(toy, x, frozen, lora)[0]
    y_h = M.block_fwd_saveh(toy, x, frozen, lora)[0]
    y_r = M.block_fwd_residuals(toy, x, frozen, lora)[0]
    np.testing.assert_allclose(np.asarray(y_h), np.asarray(y), atol=0)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y), atol=0)


def test_residual_set_contains_all_h(toy):
    """Table 5's premise: the framework-retained set includes all 7 h's."""
    h_names = [n for n in M.RESIDUALS if n.startswith("h_")]
    assert sorted(h_names) == sorted(f"h_{p}" for p in M.PROJS)
    x, _, frozen, lora = make_inputs(toy, 1)
    res = M.block_fwd_residuals(toy, x, frozen, lora)
    m = toy.batch * toy.seq
    for name, t in zip(M.RESIDUALS, res[1:]):
        if name.startswith("h_"):
            assert t.shape == (m, toy.rank), name


def test_rope_inverse_is_vjp(toy):
    """apply_rope(·, inverse=True) is the exact VJP of apply_rope."""
    cos, sin = M._rope_tables(toy, jnp.float32)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (1, toy.n_heads, toy.seq, toy.head_dim))
    g = jax.random.normal(k2, x.shape)
    _, vjp = jax.vjp(lambda t: M.apply_rope(t, cos, sin), x)
    np.testing.assert_allclose(
        np.asarray(vjp(g)[0]),
        np.asarray(M.apply_rope(g, cos, sin, inverse=True)),
        rtol=1e-5, atol=1e-6)


def test_rope_preserves_norm(toy):
    cos, sin = M._rope_tables(toy, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (1, toy.n_heads, toy.seq, toy.head_dim))
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(np.asarray(x)), rtol=1e-5)


def test_gqa_reduce_is_repeat_vjp(toy):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    kv = jax.random.normal(k1, (1, toy.n_kv_heads, toy.seq, toy.head_dim))
    g = jax.random.normal(k2, (1, toy.n_heads, toy.seq, toy.head_dim))
    _, vjp = jax.vjp(lambda t: M._repeat_kv(toy, t), kv)
    np.testing.assert_allclose(np.asarray(vjp(g)[0]),
                               np.asarray(M._reduce_kv(toy, g)),
                               rtol=1e-6, atol=1e-7)


def test_causal_masking(toy):
    """Changing future tokens must not change past block outputs."""
    x, _, frozen, lora = make_inputs(toy, 5)
    y1 = np.asarray(M.block_fwd(toy, x, frozen, lora)[0])
    x2 = x.at[:, -1, :].add(7.0)
    y2 = np.asarray(M.block_fwd(toy, x2, frozen, lora)[0])
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(y1[:, -1], y2[:, -1])


def test_lm_loss_grad_matches_autodiff(toy):
    ks = iter(jax.random.split(jax.random.PRNGKey(2), 8))
    h = jax.random.normal(next(ks), (toy.batch, toy.seq, toy.d_model))
    emb = jax.random.normal(next(ks), (toy.vocab, toy.d_model)) * 0.05
    nw = jnp.ones((toy.d_model,))
    tgt = jax.random.randint(next(ks), (toy.batch, toy.seq), 0, toy.vocab)
    loss, gh = M.lm_loss_grad(toy, h, nw, emb, tgt)
    l2, gh2 = jax.value_and_grad(
        lambda h_: M.lm_loss_fwd(toy, h_, nw, emb, tgt)[0])(h)
    np.testing.assert_allclose(float(loss), float(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(gh2),
                               rtol=3e-4, atol=1e-7)


def test_lm_loss_perfect_prediction_low(toy):
    """Loss sanity: logits aligned with targets → loss far below uniform."""
    emb = jnp.eye(toy.vocab, toy.d_model) * 10.0
    nw = jnp.ones((toy.d_model,))
    tgt = jnp.arange(toy.seq, dtype=jnp.int32)[None, :] % toy.d_model
    h = jax.nn.one_hot(tgt[0], toy.d_model)[None] * 10.0
    loss = M.lm_loss_fwd(toy, h, nw, emb, tgt)[0]
    uniform = jnp.log(jnp.asarray(float(toy.vocab)))
    assert float(loss) < float(uniform) / 4


def test_grad_zero_when_gy_zero(toy):
    x, _, frozen, lora = make_inputs(toy, 8)
    out = M.block_bwd_mesp(toy, x, jnp.zeros_like(x), frozen, lora)
    for t in out:
        np.testing.assert_allclose(np.asarray(t), 0.0, atol=1e-8)


def test_rank_sweep_shapes():
    """Artifact ABI: grads always come out [d_in, r], [r, d_out] per site."""
    for r in (2, 4, 8):
        cfg = dataclasses.replace(CONFIGS["toy"], rank=r)
        x, gy, frozen, lora = make_inputs(cfg, r)
        out = M.block_bwd_mesp(cfg, x, gy, frozen, lora)
        assert out[0].shape == x.shape
        for i, p in enumerate(M.PROJS):
            din, dout = cfg.proj_dims(p)
            assert out[1 + 2 * i].shape == (din, r)
            assert out[2 + 2 * i].shape == (r, dout)
