# The quantized-base-weights forward (paper §4.5): in-graph int4 dequant
# must reproduce the f32 forward up to quantization error, and exactly
# reproduce a forward through host-dequantized weights.

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import quant
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")


def setup(seed=0):
    cfg = CONFIGS["toy"]
    ks = iter(jax.random.split(jax.random.PRNGKey(seed), 64))

    def rnd(shape, s=0.05):
        return jax.random.normal(next(ks), shape, jnp.float32) * s

    frozen = [rnd(cfg.frozen_shapes()[n]) for n in M.FROZEN]
    frozen[0] = frozen[0] * 0.1 + 1.0
    frozen[5] = frozen[5] * 0.1 + 1.0
    lora = []
    for p in M.PROJS:
        lora.append(rnd(cfg.lora_shapes()[f"a_{p}"], 0.1))
        lora.append(rnd(cfg.lora_shapes()[f"b_{p}"], 0.1))
    x = rnd((cfg.batch, cfg.seq, cfg.d_model), 0.5)
    return cfg, x, frozen, lora


def quantize_frozen(frozen):
    """(ln1, ln2, qpairs) from the FROZEN-ordered tensor list."""
    by_name = dict(zip(M.FROZEN, frozen))
    qpairs = []
    for name in M.QUANT_MATS:
        packed, scales = quant.quantize(by_name[name])
        qpairs += [packed, scales]
    return by_name["ln1"], by_name["ln2"], qpairs


def test_q4_matches_host_dequant_exactly():
    cfg, x, frozen, lora = setup(1)
    ln1, ln2, qpairs = quantize_frozen(frozen)
    # rebuild frozen with host-side dequantized weights
    deq = [quant.dequantize(qpairs[2 * i], qpairs[2 * i + 1])
           for i in range(len(M.QUANT_MATS))]
    frozen_dq = [ln1, deq[0], deq[1], deq[2], deq[3], ln2,
                 deq[4], deq[5], deq[6]]
    y_host = M.block_fwd(cfg, x, frozen_dq, lora)[0]
    y_graph = M.block_fwd_q4(cfg, x, ln1, ln2, qpairs, lora)[0]
    np.testing.assert_allclose(np.asarray(y_graph), np.asarray(y_host),
                               rtol=1e-5, atol=1e-6)


def test_q4_close_to_f32_forward():
    cfg, x, frozen, lora = setup(2)
    y_f32 = M.block_fwd(cfg, x, frozen, lora)[0]
    ln1, ln2, qpairs = quantize_frozen(frozen)
    y_q4 = M.block_fwd_q4(cfg, x, ln1, ln2, qpairs, lora)[0]
    # int4 error propagates but stays small at toy dims
    err = np.abs(np.asarray(y_q4) - np.asarray(y_f32)).max()
    scale = np.abs(np.asarray(y_f32)).max()
    assert err < 0.15 * scale, f"q4 error {err} vs scale {scale}"


def test_q4_artifact_in_manifest():
    import json
    import pathlib
    man_path = (pathlib.Path(__file__).resolve().parents[2]
                / "artifacts" / "toy" / "manifest.json")
    if not man_path.exists():
        import pytest
        pytest.skip("run make artifacts")
    man = json.loads(man_path.read_text())
    if "block_fwd_q4" not in man["artifacts"]:
        import pytest
        pytest.skip("artifacts predate the q4 variant; run make artifacts")
    spec = man["artifacts"]["block_fwd_q4"]
    names = [a["name"] for a in spec["args"]]
    assert names[0] == "x" and "q_wq" in names and "s_wd" in names
    qi = [a for a in spec["args"] if a["name"].startswith("q_")]
    assert len(qi) == len(M.QUANT_MATS)
    # packed nibbles travel as u8 — same dtype quant.quantize emits and
    # the Rust reference backend's block_fwd_q4 spec declares
    assert all(a["dtype"] == "u8" for a in qi)
