# L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).
# hypothesis sweeps shapes/ranks/tiles — the CORE correctness signal for
# the kernels that end up inside the AOT artifacts.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attn, lora_grad, ref, rmsnorm, silu_mul

jax.config.update("jax_platform_name", "cpu")


def rnd(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(seed, n):
    return list(jax.random.split(jax.random.PRNGKey(seed), n))


# --------------------------------------------------------------- lora_grad
@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64, 96]),
    d_in=st.sampled_from([16, 64, 128]),
    d_out=st.sampled_from([16, 48, 128]),
    r=st.sampled_from([2, 4, 8, 16]),
    tile=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_lora_grad_matches_ref(m, d_in, d_out, r, tile, seed):
    k1, k2, k3, k4 = keys(seed, 4)
    x = rnd(k1, (m, d_in))
    g = rnd(k2, (m, d_out))
    a = rnd(k3, (d_in, r), 0.1)
    b = rnd(k4, (r, d_out), 0.1)
    s = 2.0
    da, db, gx = lora_grad.lora_grad(x, g, a, b, s, tile_n=tile)
    da_r, db_r, gx_r = ref.lora_grad_ref(x, g, a, b, s)
    np.testing.assert_allclose(da, da_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(db, db_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-5)


def test_lora_grad_is_true_gradient():
    """dA/dB from the kernel equal jax.grad of the LoRA forward — the
    paper's Appendix A equivalence at the single-layer level."""
    k1, k2, k3, k4, k5 = keys(7, 5)
    m, d_in, d_out, r, s = 32, 64, 48, 8, 2.0
    x = rnd(k1, (m, d_in))
    a = rnd(k2, (d_in, r), 0.1)
    b = rnd(k3, (r, d_out), 0.1)
    w0 = rnd(k4, (d_in, d_out), 0.1)
    g = rnd(k5, (m, d_out))

    def f(a_, b_, x_):
        return jnp.sum(ref.lora_fwd_ref(x_, w0, a_, b_, s) * g)

    da_t, db_t, gx_t = jax.grad(f, argnums=(0, 1, 2))(a, b, x)
    da, db, gx = lora_grad.lora_grad(x, g, a, b, s)
    gx = gx + g @ w0.T   # kernel returns only the LoRA branch of dx
    np.testing.assert_allclose(da, da_t, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(db, db_t, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gx, gx_t, rtol=1e-4, atol=1e-5)


def test_lora_grad_vmem_estimate_independent_of_seq():
    b128 = lora_grad.vmem_bytes(128, 896, 896, 8)
    assert b128 == lora_grad.vmem_bytes(128, 896, 896, 8)
    # footprint is per-tile: growing the sequence does not appear anywhere
    assert b128 < 16 * 1024 * 1024  # fits VMEM at Qwen-0.5B dims


# ----------------------------------------------------------------- rmsnorm
@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 32, 96]),
    d=st.sampled_from([8, 64, 256]),
    tile=st.sampled_from([4, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_fwd_bwd_match_ref(m, d, tile, seed):
    k1, k2, k3 = keys(seed, 3)
    x = rnd(k1, (m, d))
    w = rnd(k2, (d,), 0.5) + 1.0
    g = rnd(k3, (m, d))
    np.testing.assert_allclose(
        rmsnorm.rmsnorm(x, w, tile_m=tile), ref.rmsnorm_ref(x, w),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        rmsnorm.rmsnorm_bwd(x, w, g, tile_m=tile),
        ref.rmsnorm_bwd_ref(x, w, g), rtol=1e-5, atol=1e-6)


def test_rmsnorm_bwd_is_true_gradient():
    k1, k2, k3 = keys(3, 3)
    x = rnd(k1, (16, 32))
    w = rnd(k2, (32,), 0.5) + 1.0
    g = rnd(k3, (16, 32))
    gt = jax.grad(lambda x_: jnp.sum(ref.rmsnorm_ref(x_, w) * g))(x)
    np.testing.assert_allclose(
        rmsnorm.rmsnorm_bwd(x, w, g), gt, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- silu_mul
@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 32, 96]),
    f=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**16),
)
def test_silu_mul_fwd_bwd_match_ref(m, f, seed):
    k1, k2, k3 = keys(seed, 3)
    gate = rnd(k1, (m, f))
    up = rnd(k2, (m, f))
    g = rnd(k3, (m, f))
    np.testing.assert_allclose(
        silu_mul.silu_mul(gate, up), ref.silu_mul_ref(gate, up),
        rtol=1e-5, atol=1e-6)
    dg, du = silu_mul.silu_mul_bwd(gate, up, g)
    dg_r, du_r = ref.silu_mul_bwd_ref(gate, up, g)
    np.testing.assert_allclose(dg, dg_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(du, du_r, rtol=1e-5, atol=1e-6)


def test_silu_mul_bwd_is_true_gradient():
    k1, k2, k3 = keys(11, 3)
    gate = rnd(k1, (8, 16))
    up = rnd(k2, (8, 16))
    g = rnd(k3, (8, 16))
    dg_t, du_t = jax.grad(
        lambda a, b: jnp.sum(ref.silu_mul_ref(a, b) * g), argnums=(0, 1)
    )(gate, up)
    dg, du = silu_mul.silu_mul_bwd(gate, up, g)
    np.testing.assert_allclose(dg, dg_t, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(du, du_t, rtol=1e-4, atol=1e-6)


# -------------------------------------------------------------- flash attn
@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([16, 64, 128]),
    hd=st.sampled_from([8, 32]),
    tile=st.sampled_from([8, 16, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_matches_ref(n, hd, tile, causal, seed):
    k1, k2, k3 = keys(seed, 3)
    q = rnd(k1, (n, hd))
    k = rnd(k2, (n, hd))
    v = rnd(k3, (n, hd))
    out, lse = flash_attn.flash_attention(q, k, v, causal=causal,
                                          tile_q=tile, tile_k=tile)
    out_r, probs = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, out_r, rtol=2e-4, atol=2e-5)
    # lse must reproduce the softmax normalizer
    np.testing.assert_allclose(
        jnp.exp(lse),
        jnp.exp(jax.nn.logsumexp(
            _masked_scores(q, k, causal), axis=-1)),
        rtol=2e-4, atol=2e-5)


def _masked_scores(q, k, causal):
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    if causal:
        n = q.shape[0]
        s = jnp.where(jnp.tril(jnp.ones((n, n), bool)), s, -1e30)
    return s


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([16, 64]),
    hd=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_bwd_matches_ref(n, hd, causal, seed):
    k1, k2, k3, k4 = keys(seed, 4)
    q = rnd(k1, (n, hd))
    k = rnd(k2, (n, hd))
    v = rnd(k3, (n, hd))
    go = rnd(k4, (n, hd))
    out, lse = flash_attn.flash_attention(q, k, v, causal=causal)
    dq, dk, dv = flash_attn.flash_attention_bwd(q, k, v, out, lse, go,
                                                causal=causal)
    dq_r, dk_r, dv_r = ref.attention_bwd_ref(q, k, v, go, causal=causal)
    np.testing.assert_allclose(dq, dq_r, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(dk, dk_r, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(dv, dv_r, rtol=5e-4, atol=5e-5)


def test_softmax_bwd_rowsum_zero():
    """Softmax backward lies in the tangent space: rows of dscores sum to 0
    (paper eq. 19 invariant)."""
    k1, k2 = keys(5, 2)
    probs = jax.nn.softmax(rnd(k1, (4, 16, 16)), axis=-1)
    g = rnd(k2, (4, 16, 16))
    ds = ref.softmax_bwd_ref(probs, g)
    np.testing.assert_allclose(jnp.sum(ds, axis=-1),
                               jnp.zeros((4, 16)), atol=1e-5)


@pytest.mark.parametrize("m,pref,expect", [(32, 128, 32), (96, 64, 48),
                                           (100, 64, 50), (7, 4, 1)])
def test_pick_tile_divides(m, pref, expect):
    t = lora_grad._pick_tile(m, pref)
    assert m % t == 0 and t == expect
