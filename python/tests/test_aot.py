# AOT pipeline integrity: manifest ↔ config consistency, HLO text parses,
# the ABI the Rust runtime depends on (arg order, output arity).

import json
import pathlib

import pytest

from compile import aot
from compile.configs import CONFIGS
from compile.model import FROZEN, PROJS, RESIDUALS

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ARTIFACTS / "toy" / "manifest.json").exists(),
    reason="run `make artifacts` first",
)


def load_manifest(name):
    return json.loads((ARTIFACTS / name / "manifest.json").read_text())


def test_manifest_config_roundtrip():
    for name, cfg in CONFIGS.items():
        man = load_manifest(name)
        mc = man["config"]
        assert mc["d_model"] == cfg.d_model
        assert mc["n_layers"] == cfg.n_layers
        assert mc["rank"] == cfg.rank
        assert mc["scale"] == pytest.approx(cfg.alpha / cfg.rank)
        assert mc["param_count"] > 0


def test_artifact_files_exist_and_parse():
    for name in CONFIGS:
        man = load_manifest(name)
        for aname, spec in man["artifacts"].items():
            p = ARTIFACTS / name / spec["file"]
            assert p.exists(), f"{name}/{aname}"
            head = p.read_text()[:200]
            assert head.startswith("HloModule"), f"{name}/{aname}: {head!r}"


def test_block_bwd_abi():
    """Rust unpacks outputs positionally: g_x then (dA, dB) per PROJS."""
    for name, cfg in CONFIGS.items():
        man = load_manifest(name)
        for aname in ("block_bwd_mesp", "block_bwd_autodiff"):
            spec = man["artifacts"][aname]
            assert spec["outputs"] == 1 + 2 * len(PROJS)
            args = [a["name"] for a in spec["args"]]
            assert args[:2] == ["x", "g_y"]
            assert args[2:2 + len(FROZEN)] == list(FROZEN)
            lora_names = args[2 + len(FROZEN):]
            want = []
            for p in PROJS:
                want += [f"a_{p}", f"b_{p}"]
            assert lora_names == want


def test_residual_abi():
    man = load_manifest("toy")
    spec = man["artifacts"]["block_bwd_residuals"]
    args = [a["name"] for a in spec["args"]]
    assert args[0] == "g_y"
    assert args[1:1 + len(RESIDUALS)] == list(RESIDUALS)
    fwd = man["artifacts"]["block_fwd_residuals"]
    assert fwd["outputs"] == 1 + len(RESIDUALS)


def test_h_shapes_in_manifest():
    """h = xA is [batch*seq, r] — the tensor the whole paper is about."""
    for name, cfg in CONFIGS.items():
        man = load_manifest(name)
        if "block_bwd_storeh" not in man["artifacts"]:
            continue
        spec = man["artifacts"]["block_bwd_storeh"]
        hs = [a for a in spec["args"] if a["name"].startswith("h_")]
        assert len(hs) == len(PROJS)
        for a in hs:
            assert a["shape"] == [cfg.batch * cfg.seq, cfg.rank]


def test_loss_artifacts():
    for name, cfg in CONFIGS.items():
        man = load_manifest(name)
        assert man["artifacts"]["lm_loss_fwd"]["outputs"] == 1
        assert man["artifacts"]["lm_loss_grad"]["outputs"] == 2
        emb = [a for a in man["artifacts"]["lm_loss_fwd"]["args"]
               if a["name"] == "emb"][0]
        assert emb["shape"] == [cfg.vocab, cfg.d_model]


def test_index_lists_all_configs():
    idx = json.loads((ARTIFACTS / "index.json").read_text())
    for name in CONFIGS:
        assert name in idx


def test_build_is_idempotent():
    """Second build with unchanged sources is a no-op (stamp check)."""
    assert aot.build_config(CONFIGS["toy"]) is False
