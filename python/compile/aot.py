# AOT compiler: lowers every L2 function of every registered config to HLO
# TEXT and writes artifacts/<config>/{*.hlo.txt, manifest.json}.
#
# HLO text — NOT lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()
# or jax.export — is the interchange format: jax >= 0.5 emits protos with
# 64-bit instruction ids which the xla crate's XLA (xla_extension 0.5.1)
# rejects (`proto.id() <= INT_MAX`); the HLO *text* parser reassigns ids and
# round-trips cleanly. See /opt/xla-example/gen_hlo.py.
#
# Python runs exactly once: `make artifacts` calls this module, and the
# content hash of the compile/ package is stored per config so unchanged
# inputs make the build a no-op. The Rust runtime consumes manifest.json
# (arg names/shapes/dtypes + model dims) and never imports Python.

import argparse
import dataclasses
import functools
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import quant
from .configs import CONFIGS, lora_param_count, param_count
from .model import FROZEN, PROJS, RESIDUALS, ModelConfig, block_bwd_autodiff
from .model import block_bwd_mesp, block_bwd_residuals, block_bwd_storeh
from .model import block_fwd, block_fwd_residuals, block_fwd_saveh
from .model import embed_fwd, lm_loss_fwd, lm_loss_grad

REPO = pathlib.Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"


# ----------------------------------------------------------------- argspec
def _f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def frozen_args(cfg: ModelConfig):
    return [(n, _f32(cfg.frozen_shapes()[n])) for n in FROZEN]


def lora_args(cfg: ModelConfig):
    out = []
    for p in PROJS:
        out.append((f"a_{p}", _f32(cfg.lora_shapes()[f"a_{p}"])))
        out.append((f"b_{p}", _f32(cfg.lora_shapes()[f"b_{p}"])))
    return out


def residual_args(cfg: ModelConfig):
    b, n, d = cfg.batch, cfg.seq, cfg.d_model
    m = b * n
    shapes = {
        "x": (m, d), "h1": (m, d), "h2": (m, d), "x2": (m, d),
        "q_rope": (b, cfg.n_heads, n, cfg.head_dim),
        "k_rope": (b, cfg.n_kv_heads, n, cfg.head_dim),
        "v_heads": (b, cfg.n_kv_heads, n, cfg.head_dim),
        "probs": (b, cfg.n_heads, n, n),
        "attn_flat": (m, cfg.q_dim),
        "gate_out": (m, cfg.d_ff), "up_out": (m, cfg.d_ff),
        "silu_out": (m, cfg.d_ff),
    }
    for p in PROJS:
        shapes[f"h_{p}"] = (m, cfg.rank)
    return [(name, _f32(shapes[name])) for name in RESIDUALS]


def h_args(cfg: ModelConfig):
    m = cfg.batch * cfg.seq
    return [(f"h_{p}", _f32((m, cfg.rank))) for p in PROJS]


def x_arg(cfg):
    return ("x", _f32((cfg.batch, cfg.seq, cfg.d_model)))


def gy_arg(cfg):
    return ("g_y", _f32((cfg.batch, cfg.seq, cfg.d_model)))


def artifact_specs(cfg: ModelConfig):
    """name → (callable(cfg, *args), [(arg_name, ShapeDtypeStruct)…])."""
    fz, lo = frozen_args(cfg), lora_args(cfg)
    emb = ("emb", _f32((cfg.vocab, cfg.d_model)))
    tgt = ("targets", _i32((cfg.batch, cfg.seq)))
    nw = ("norm_w", _f32((cfg.d_model,)))

    def split_fz_lo(fn, n_lead):
        # adapt flat positional args → (leads…, frozen tuple, lora tuple)
        def wrapped(*args):
            leads = args[:n_lead]
            rest = args[n_lead:]
            return fn(cfg, *leads, rest[: len(fz)], rest[len(fz):])
        return wrapped

    specs = {
        "embed_fwd": (
            lambda tokens, e: embed_fwd(cfg, tokens, e),
            [("tokens", _i32((cfg.batch, cfg.seq))), emb],
        ),
        "block_fwd": (split_fz_lo(block_fwd, 1), [x_arg(cfg)] + fz + lo),
        "block_fwd_saveh": (
            split_fz_lo(block_fwd_saveh, 1), [x_arg(cfg)] + fz + lo),
        "block_bwd_mesp": (
            split_fz_lo(block_bwd_mesp, 2),
            [x_arg(cfg), gy_arg(cfg)] + fz + lo),
        "block_bwd_autodiff": (
            split_fz_lo(block_bwd_autodiff, 2),
            [x_arg(cfg), gy_arg(cfg)] + fz + lo),
        "lm_loss_fwd": (
            lambda h, w, e, t: lm_loss_fwd(cfg, h, w, e, t),
            [("h", _f32((cfg.batch, cfg.seq, cfg.d_model))), nw, emb, tgt]),
        "lm_loss_grad": (
            lambda h, w, e, t: lm_loss_grad(cfg, h, w, e, t),
            [("h", _f32((cfg.batch, cfg.seq, cfg.d_model))), nw, emb, tgt]),
    }
    # quantized-base-weights variant (paper §4.5); requires dims divisible
    # by the quant group. Compiled for every config that qualifies.
    from .model import QUANT_MATS, block_fwd_q4
    from . import quant as quant_mod

    if all(cfg.proj_dims(p)[0] % quant_mod.GROUP == 0
           for p in ("q", "o", "down")):
        qargs = []
        for name in QUANT_MATS:
            fz_shape = {
                "wq": ("q",), "wk": ("k",), "wv": ("v",), "wo": ("o",),
                "wg": ("gate",), "wu": ("up",), "wd": ("down",),
            }[name]
            din, dout = cfg.proj_dims(fz_shape[0])
            # packed nibbles travel as uint8 ("u8" in the manifest) — the
            # same dtype quant.quantize emits and the Rust reference
            # backend's block_fwd_q4 spec declares.
            qargs.append((f"q_{name}", jax.ShapeDtypeStruct(
                (din // 2, dout), jnp.uint8)))
            qargs.append((f"s_{name}", _f32((din // quant_mod.GROUP, dout))))

        def fwd_q4(*args):
            x, l1, l2 = args[0], args[1], args[2]
            qpairs = args[3: 3 + 2 * len(QUANT_MATS)]
            rest = args[3 + 2 * len(QUANT_MATS):]
            return block_fwd_q4(cfg, x, l1, l2, qpairs, rest)

        specs["block_fwd_q4"] = (
            fwd_q4,
            [x_arg(cfg), ("ln1", _f32((cfg.d_model,))),
             ("ln2", _f32((cfg.d_model,)))] + qargs + lo)

    if cfg.attention == "probs":
        # residual/storeh paths store probs — flash variants skip them.
        def bwd_storeh(*args):
            x, g_y = args[0], args[1]
            hs = args[2: 2 + len(PROJS)]
            rest = args[2 + len(PROJS):]
            return block_bwd_storeh(cfg, x, g_y, hs, rest[: len(fz)],
                                    rest[len(fz):])

        def bwd_res(*args):
            g_y = args[0]
            res = args[1: 1 + len(RESIDUALS)]
            rest = args[1 + len(RESIDUALS):]
            return block_bwd_residuals(cfg, g_y, res, rest[: len(fz)],
                                       rest[len(fz):])

        specs["block_fwd_residuals"] = (
            split_fz_lo(block_fwd_residuals, 1), [x_arg(cfg)] + fz + lo)
        specs["block_bwd_residuals"] = (
            bwd_res, [gy_arg(cfg)] + residual_args(cfg) + fz + lo)
        specs["block_bwd_storeh"] = (
            bwd_storeh, [x_arg(cfg), gy_arg(cfg)] + h_args(cfg) + fz + lo)
    return specs


# ---------------------------------------------------------------- lowering
def to_hlo_text(fn, args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(sds) -> str:
    return {"float32": "f32", "int32": "i32", "uint8": "u8"}[str(sds.dtype)]


def _source_hash() -> str:
    h = hashlib.sha256()
    pkg = pathlib.Path(__file__).parent
    for f in sorted(pkg.rglob("*.py")):
        h.update(f.read_bytes())
    return h.hexdigest()[:16]


def build_config(cfg: ModelConfig, force: bool = False) -> bool:
    """Lower all artifacts for one config. Returns True if work was done."""
    outdir = ARTIFACTS / cfg.name
    stamp = outdir / ".build_hash"
    want = _source_hash() + ":" + json.dumps(dataclasses.asdict(cfg),
                                             sort_keys=True, default=list)
    want = hashlib.sha256(want.encode()).hexdigest()[:16]
    if not force and stamp.exists() and stamp.read_text() == want:
        print(f"[aot] {cfg.name}: up to date")
        return False
    outdir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "config": {
            **{k: v for k, v in dataclasses.asdict(cfg).items()
               if not isinstance(v, (list, tuple))},
            "pallas_ops": list(cfg.pallas_ops),
            "scale": cfg.scale,
            "param_count": param_count(cfg),
            "lora_param_count": lora_param_count(cfg),
        },
        "artifacts": {},
    }
    for name, (fn, argspec) in artifact_specs(cfg).items():
        args = [sds for _, sds in argspec]
        print(f"[aot] {cfg.name}/{name}: lowering "
              f"({len(args)} args) ...", flush=True)
        text = to_hlo_text(fn, args)
        fname = f"{name}.hlo.txt"
        (outdir / fname).write_text(text)
        n_out = _count_outputs(fn, args)
        manifest["artifacts"][name] = {
            "file": fname,
            "args": [
                {"name": an, "shape": list(sds.shape),
                 "dtype": _dtype_name(sds)}
                for an, sds in argspec
            ],
            "outputs": n_out,
        }
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    stamp.write_text(want)
    print(f"[aot] {cfg.name}: wrote {len(manifest['artifacts'])} artifacts")
    return True


@functools.lru_cache(maxsize=None)
def _noop():
    return None


def _count_outputs(fn, args) -> int:
    out = jax.eval_shape(fn, *args)
    if isinstance(out, (tuple, list)):
        return len(out)
    return 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append",
                    help="config name(s) to build (default: all)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", help="ignored (Makefile stamp compat)")
    ns = ap.parse_args()
    if ns.list:
        for name, cfg in CONFIGS.items():
            print(f"{name}: {param_count(cfg)/1e6:.1f}M params, "
                  f"seq={cfg.seq}, rank={cfg.rank}, attn={cfg.attention}")
        return 0
    names = ns.config or list(CONFIGS)
    for name in names:
        build_config(CONFIGS[name], force=ns.force)
    # top-level index so the Rust side can enumerate configs
    index = {n: f"{n}/manifest.json" for n in names
             if (ARTIFACTS / n / "manifest.json").exists()}
    existing = {}
    idx_path = ARTIFACTS / "index.json"
    if idx_path.exists():
        existing = json.loads(idx_path.read_text())
    existing.update(index)
    idx_path.write_text(json.dumps(existing, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
