# In-graph int4 group quantization for frozen base weights (QLoRA-style).
#
# The paper keeps base weights "in 4-bit quantized format with on-the-fly
# dequantization" (§4.5). We reproduce that as an artifact *variant*: the
# q4 block forward takes packed uint8 weights + per-group f32 scales and
# dequantizes inside the HLO graph, so the host never holds an f32 copy of
# the base weights. The Rust side packs with model::quant (bit-identical
# scheme, asserted by tests) and the memory model accounts 0.5 B/param.
#
# Scheme: symmetric int4 (levels -8..7), group size G along the input
# dimension, two nibbles per byte (even index → low nibble).

import jax.numpy as jnp

GROUP = 64


def quantize(w, group: int = GROUP):
    """f32 [din, dout] → (packed uint8 [din//2, dout], scales f32
    [din//group, dout]). din must be divisible by 2 and group."""
    din, dout = w.shape
    assert din % group == 0 and din % 2 == 0
    g = w.reshape(din // group, group, dout)
    scale = jnp.max(jnp.abs(g), axis=1) / 7.0            # [din//group, dout]
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / safe[:, None, :]), -8, 7).astype(jnp.int8)
    q = q.reshape(din, dout)
    lo = (q[0::2] & 0x0F).astype(jnp.uint8)
    hi = (q[1::2] & 0x0F).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def dequantize(packed, scales, group: int = GROUP):
    """Inverse of quantize; runs inside the lowered graph."""
    half, dout = packed.shape
    din = half * 2
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    q = jnp.zeros((din, dout), jnp.int8).at[0::2].set(lo).at[1::2].set(hi)
    s = jnp.repeat(scales, group, axis=0)                # [din, dout]
    return q.astype(jnp.float32) * s
