# Compiled-config registry: every ModelConfig that `make artifacts` lowers
# to an HLO artifact set. The Rust side reads the dims back from each
# config's manifest.json, so this file is the single source of truth for
# runtime-executable shapes. (The Qwen2.5-{0.5B,1.5B,3B} dims used by the
# analytical memory model are sim-only — they live in rust/src/config/
# presets and are never compiled here.)

import dataclasses

from .model import ModelConfig

CONFIGS = {
    # Minimal dims for fast unit/integration tests and gradcheck.
    "toy": ModelConfig(
        name="toy", vocab=256, d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, seq=32, batch=1, rank=4,
        alpha=8.0,
    ),
    # Every Pallas kernel on the artifact path + flash attention, to prove
    # the full kernel set composes end-to-end (extension ablation).
    "toy_flash": ModelConfig(
        name="toy_flash", vocab=256, d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, seq=32, batch=1, rank=4,
        alpha=8.0, pallas_ops=("lora", "norm", "mlp"), attention="flash",
    ),
    # Convergence runs, MeZO gradient-quality analysis, criterion benches.
    "small": ModelConfig(
        name="small", vocab=512, d_model=128, n_layers=4, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, seq=64, batch=1, rank=8,
    ),
    # Weight-dominated dims for the shared-base-weight fleet demo: a fat
    # embedding over two thin blocks at seq 4, so the resident frozen
    # base dwarfs any per-job activation cost (tests/shared_weights.rs
    # and the CI shared-weights smoke).
    "basebound": ModelConfig(
        name="basebound", vocab=131072, d_model=256, n_layers=2, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=128, seq=4, batch=1, rank=4,
        alpha=8.0,
    ),
    # Long-context loss-head stress: a fat vocab (32768) over a thin
    # trunk (d 128) at seq 512, so the m×vocab logits dwarf every
    # per-block intermediate — the regime where the chunked lm head
    # (`--loss-chunk`) pays. The CI obs-tier runs `mesp report` here.
    "longctx": ModelConfig(
        name="longctx", vocab=32768, d_model=128, n_layers=8, n_heads=2,
        n_kv_heads=2, head_dim=64, d_ff=256, seq=512, batch=1, rank=8,
    ),
    # The end-to-end validation model: ~98M params (DESIGN.md §2).
    "e2e100m": ModelConfig(
        name="e2e100m", vocab=16384, d_model=768, n_layers=12, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2304, seq=128, batch=1, rank=8,
    ),
}


def param_count(cfg: ModelConfig) -> int:
    """Frozen + embedding parameter count (LoRA excluded)."""
    per_block = sum(
        int(a * b) if len(sh) == 2 else int(sh[0])
        for sh in cfg.frozen_shapes().values()
        for a, b in [sh if len(sh) == 2 else (sh[0], 1)]
    )
    return cfg.vocab * cfg.d_model + cfg.n_layers * per_block + cfg.d_model


def lora_param_count(cfg: ModelConfig) -> int:
    return sum(
        sh[0] * sh[1] for sh in cfg.lora_shapes().values()
    ) * cfg.n_layers


def variants(name: str):
    """Derived configs (e.g. rank sweeps) — reserved for ablation builds."""
    base = CONFIGS[name]
    return {r: dataclasses.replace(base, rank=r) for r in (4, 8, 16, 32)}
