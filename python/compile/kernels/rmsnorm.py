# L1 Pallas kernels: RMSNorm forward and backward.
#
# Row-tiled: each grid step normalizes a tile of rows entirely in VMEM.
# The backward implements the paper's eq. 22 extended with the (frozen)
# elementwise weight that Qwen2.5's RMSNorm carries. Because norm weights
# are frozen under LoRA fine-tuning, only dL/dx is produced — exactly the
# tensor-lifecycle discipline MeSP prescribes (nothing is computed that
# will not be consumed).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(m: int, preferred: int) -> int:
    t = min(preferred, m)
    while m % t != 0:
        t -= 1
    return t


def _fwd_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "tile_m"))
def rmsnorm(x, w, eps: float = 1e-6, tile_m: int = 128):
    """RMSNorm over the last axis. x: [M, d], w: [d]."""
    m, d = x.shape
    tm = _pick_tile(m, tile_m)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, w)


def _bwd_kernel(x_ref, w_ref, g_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    u = x * inv
    gw = g_ref[...] * w_ref[...]
    o_ref[...] = (gw - u * jnp.mean(gw * u, axis=-1, keepdims=True)) * inv


@functools.partial(jax.jit, static_argnames=("eps", "tile_m"))
def rmsnorm_bwd(x, w, g, eps: float = 1e-6, tile_m: int = 128):
    """dL/dx of rmsnorm(x, w) given upstream g. Shapes as in rmsnorm."""
    m, d = x.shape
    tm = _pick_tile(m, tile_m)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, w, g)
