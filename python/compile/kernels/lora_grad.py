# L1 Pallas kernel: fused LoRA gradient with in-VMEM recomputation of h.
#
# This is the paper's core contribution expressed at kernel granularity
# (MeSP §4.1-4.2): the low-rank intermediate h = xA is NEVER materialized
# to HBM. Each grid step streams one sequence tile of x and g into VMEM,
# recomputes h_tile = x_tile @ A on the fly, and accumulates
#
#   dA += x_tile^T (s·g_tile B^T)        [d_in, r]
#   dB += (x_tile A)^T (s·g_tile)        [r, d_out]
#   gx_tile = (s·g_tile B^T) A^T         [tile_n, d_in]
#
# so peak VMEM per step is tile_n·(d_in + d_out + r) + r·(d_in + d_out)
# floats — independent of sequence length. On a real TPU the two rank-r
# GEMMs are deliberately shaped [tile_n, d]·[d, r]: with tile_n and d
# multiples of 128 they map onto the MXU systolic array; r < 128 wastes
# lanes on the [*, r] side, which is the irreducible cost of low rank (the
# paper pays the same on the ANE). interpret=True is mandatory on CPU —
# real lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
#
# HARDWARE ADAPTATION (DESIGN.md §3): the paper implements this as MLX
# GEMMs with explicit buffer lifecycle on Apple unified memory; here the
# lifecycle discipline becomes a BlockSpec HBM↔VMEM schedule, and "never
# store h" becomes "h lives only in a VMEM temporary inside one grid step".

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(m: int, preferred: int) -> int:
    """Largest divisor of m that is <= preferred (grid must tile exactly)."""
    t = min(preferred, m)
    while m % t != 0:
        t -= 1
    return t


def _lora_grad_kernel(x_ref, g_ref, a_ref, b_ref, da_ref, db_ref, gx_ref, *, s):
    i = pl.program_id(0)
    x_t = x_ref[...]                      # [tn, d_in]
    sg_t = g_ref[...] * s                 # [tn, d_out]
    a = a_ref[...]                        # [d_in, r]
    h_t = x_t @ a                         # recomputed in VMEM — the paper's trick
    dh_t = sg_t @ b_ref[...].T            # [tn, r]

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    da_ref[...] += x_t.T @ dh_t
    db_ref[...] += h_t.T @ sg_t
    gx_ref[...] = dh_t @ a.T


@functools.partial(jax.jit, static_argnames=("s", "tile_n"))
def lora_grad(x, g, a, b, s: float, tile_n: int = 128):
    """Fused LoRA backward (recompute-h). See ref.lora_grad_ref.

    Args:
      x: [M, d_in] layer input (flattened batch*seq).
      g: [M, d_out] upstream gradient.
      a: [d_in, r], b: [r, d_out] LoRA matrices.
      s: LoRA scale alpha/r (static).
      tile_n: preferred sequence-tile size (static; clipped to a divisor).

    Returns (dA, dB, gx_lora).
    """
    m, d_in = x.shape
    d_out = g.shape[1]
    r = a.shape[1]
    tn = _pick_tile(m, tile_n)
    grid = (m // tn,)
    return pl.pallas_call(
        functools.partial(_lora_grad_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d_in), lambda i: (i, 0)),    # stream x tiles
            pl.BlockSpec((tn, d_out), lambda i: (i, 0)),   # stream g tiles
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),     # A resident
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),    # B resident
        ],
        out_specs=[
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),     # dA accumulator
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),    # dB accumulator
            pl.BlockSpec((tn, d_in), lambda i: (i, 0)),    # gx tiles
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, r), x.dtype),
            jax.ShapeDtypeStruct((r, d_out), x.dtype),
            jax.ShapeDtypeStruct((m, d_in), x.dtype),
        ],
        interpret=True,
    )(x, g, a, b)


def vmem_bytes(tile_n: int, d_in: int, d_out: int, r: int,
               bytes_per_el: int = 2) -> int:
    """Estimated peak VMEM footprint of one grid step (for DESIGN.md §9)."""
    stream = tile_n * (d_in + d_out + r) + tile_n * r   # x, g, gx(dh) + h
    resident = 2 * r * (d_in + d_out)                   # A, B, dA, dB
    return bytes_per_el * (stream + resident)
