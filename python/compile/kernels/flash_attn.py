# L1 Pallas kernels: FlashAttention-style causal attention, fwd + bwd.
#
# The paper's Appendix E stores per-block attention probabilities ([b, H,
# n, n]) as one of the four retained intermediates. That tensor dominates
# block-intermediate memory at long sequence lengths; FlashAttention (cited
# by the paper as the same recompute-over-store principle applied to
# attention) removes it by recomputing probabilities tile-wise from the
# saved row log-sum-exp. We provide these kernels as the `flash` attention
# mode (config.attention = "flash"), the memory model's `flash` variant,
# and the Table-2 extension ablation; the default path matches the paper
# (store probs).
#
# Layout: single head, q/k/v: [n, hd]. Heads/batch are vmapped at L2.
# The forward streams Q tiles through the grid; K/V are VMEM-resident
# (they are O(n·hd), vastly smaller than the O(n²) probs we refuse to
# materialize). Online softmax keeps running (max, sum, acc) per row.
# interpret=True: CPU lowering, see lora_grad.py.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_tile(m: int, preferred: int) -> int:
    t = min(preferred, m)
    while m % t != 0:
        t -= 1
    return t


# ----------------------------------------------------------------- forward
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, tq, tk, scale, causal):
    i = pl.program_id(0)
    q = q_ref[...] * scale                       # [tq, hd]
    n = k_ref.shape[0]
    q_pos = i * tq + jax.lax.iota(jnp.int32, tq)

    def body(j, carry):
        m_i, l_i, acc = carry
        k_t = jax.lax.dynamic_slice_in_dim(k_ref[...], j * tk, tk, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v_ref[...], j * tk, tk, 0)
        s = q @ k_t.T                            # [tq, tk]
        if causal:
            k_pos = j * tk + jax.lax.iota(jnp.int32, tk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v_t
        return m_new, l_new, acc

    hd = q_ref.shape[1]
    init = (
        jnp.full((tq,), NEG_INF, q.dtype),
        jnp.zeros((tq,), q.dtype),
        jnp.zeros((tq, hd), q.dtype),
    )
    m_i, l_i, acc = jax.lax.fori_loop(0, n // tk, body, init)
    o_ref[...] = acc / l_i[:, None]
    lse_ref[...] = m_i + jnp.log(l_i)


@functools.partial(jax.jit, static_argnames=("causal", "tile_q", "tile_k"))
def flash_attention(q, k, v, causal: bool = True,
                    tile_q: int = 64, tile_k: int = 64):
    """Causal flash attention for one head. Returns (out [n,hd], lse [n])."""
    n, hd = q.shape
    tq = _pick_tile(n, tile_q)
    tk = _pick_tile(n, tile_k)
    scale = 1.0 / float(hd) ** 0.5
    return pl.pallas_call(
        functools.partial(_fwd_kernel, tq=tq, tk=tk, scale=scale, causal=causal),
        grid=(n // tq,),
        in_specs=[
            pl.BlockSpec((tq, hd), lambda i: (i, 0)),
            pl.BlockSpec((n, hd), lambda i: (0, 0)),
            pl.BlockSpec((n, hd), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, hd), lambda i: (i, 0)),
            pl.BlockSpec((tq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hd), q.dtype),
            jax.ShapeDtypeStruct((n,), q.dtype),
        ],
        interpret=True,
    )(q, k, v)


# ---------------------------------------------------------------- backward
def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, tq, tk, scale, causal):
    i = pl.program_id(0)
    q = q_ref[...]
    do = do_ref[...]
    lse = lse_ref[...]
    delta = delta_ref[...]
    n = k_ref.shape[0]
    q_pos = i * tq + jax.lax.iota(jnp.int32, tq)

    def body(j, dq):
        k_t = jax.lax.dynamic_slice_in_dim(k_ref[...], j * tk, tk, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v_ref[...], j * tk, tk, 0)
        s = (q @ k_t.T) * scale
        if causal:
            k_pos = j * tk + jax.lax.iota(jnp.int32, tk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])            # recomputed probs tile
        dp = do @ v_t.T
        ds = p * (dp - delta[:, None])           # softmax bwd w/ saved delta
        return dq + (ds @ k_t) * scale

    dq_ref[...] = jax.lax.fori_loop(
        0, n // tk, body, jnp.zeros_like(q))


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, tq, tk, scale, causal):
    j = pl.program_id(0)
    k_t = k_ref[...]
    v_t = v_ref[...]
    n = q_ref.shape[0]
    k_pos = j * tk + jax.lax.iota(jnp.int32, tk)

    def body(i, carry):
        dk, dv = carry
        q_t = jax.lax.dynamic_slice_in_dim(q_ref[...], i * tq, tq, 0)
        do_t = jax.lax.dynamic_slice_in_dim(do_ref[...], i * tq, tq, 0)
        lse_t = jax.lax.dynamic_slice_in_dim(lse_ref[...], i * tq, tq, 0)
        dl_t = jax.lax.dynamic_slice_in_dim(delta_ref[...], i * tq, tq, 0)
        s = (q_t @ k_t.T) * scale
        if causal:
            q_pos = i * tq + jax.lax.iota(jnp.int32, tq)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse_t[:, None])
        dp = do_t @ v_t.T
        ds = p * (dp - dl_t[:, None])
        dv = dv + p.T @ do_t
        dk = dk + (ds.T @ q_t) * scale
        return dk, dv

    dk0 = jnp.zeros_like(k_t)
    dv0 = jnp.zeros_like(v_t)
    dk, dv = jax.lax.fori_loop(0, n // tq, body, (dk0, dv0))
    dk_ref[...] = dk
    dv_ref[...] = dv


@functools.partial(jax.jit, static_argnames=("causal", "tile_q", "tile_k"))
def flash_attention_bwd(q, k, v, out, lse, d_out, causal: bool = True,
                        tile_q: int = 64, tile_k: int = 64):
    """Backward of flash_attention. Returns (dq, dk, dv).

    Probabilities are recomputed tile-wise from `lse`; the only extra saved
    tensor vs. the forward is `lse` [n] — this is the FlashAttention-2
    delta trick (delta = rowsum(do ⊙ o))."""
    n, hd = q.shape
    tq = _pick_tile(n, tile_q)
    tk = _pick_tile(n, tile_k)
    scale = 1.0 / float(hd) ** 0.5
    delta = jnp.sum(d_out * out, axis=-1)        # [n]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, tq=tq, tk=tk, scale=scale,
                          causal=causal),
        grid=(n // tq,),
        in_specs=[
            pl.BlockSpec((tq, hd), lambda i: (i, 0)),
            pl.BlockSpec((n, hd), lambda i: (0, 0)),
            pl.BlockSpec((n, hd), lambda i: (0, 0)),
            pl.BlockSpec((tq, hd), lambda i: (i, 0)),
            pl.BlockSpec((tq,), lambda i: (i,)),
            pl.BlockSpec((tq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tq, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hd), q.dtype),
        interpret=True,
    )(q, k, v, d_out, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, tq=tq, tk=tk, scale=scale,
                          causal=causal),
        grid=(n // tk,),
        in_specs=[
            pl.BlockSpec((n, hd), lambda j: (0, 0)),
            pl.BlockSpec((tk, hd), lambda j: (j, 0)),
            pl.BlockSpec((tk, hd), lambda j: (j, 0)),
            pl.BlockSpec((n, hd), lambda j: (0, 0)),
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tk, hd), lambda j: (j, 0)),
            pl.BlockSpec((tk, hd), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hd), q.dtype),
            jax.ShapeDtypeStruct((n, hd), q.dtype),
        ],
        interpret=True,
    )(q, k, v, d_out, lse, delta)
    return dq, dk, dv
