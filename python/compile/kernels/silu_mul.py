# L1 Pallas kernels: fused SwiGLU elementwise core, silu(gate) ⊙ up.
#
# Fusing the activation with the gating multiply halves the HBM traffic of
# the MLP's elementwise stage and — in the backward — regenerates sigmoid
# from the stored gate tensor instead of storing silu(gate) as a second
# intermediate. This mirrors the paper's Appendix E checkpoint strategy:
# only the *gate projection output* is kept for the SiLU backward; the
# activation value itself is recomputed.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(m: int, preferred: int) -> int:
    t = min(preferred, m)
    while m % t != 0:
        t -= 1
    return t


def _fwd_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = g * jax.nn.sigmoid(g) * u_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def silu_mul(gate, up, tile_m: int = 128):
    """silu(gate) ⊙ up, elementwise. gate, up: [M, f]."""
    m, f = gate.shape
    tm = _pick_tile(m, tile_m)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, f), gate.dtype),
        interpret=True,
    )(gate, up)


def _bwd_kernel(g_ref, u_ref, go_ref, dg_ref, du_ref):
    g = g_ref[...]
    go = go_ref[...]
    sig = jax.nn.sigmoid(g)
    silu = g * sig
    dsilu = sig * (1.0 + g * (1.0 - sig))      # paper eq. 23
    dg_ref[...] = go * u_ref[...] * dsilu
    du_ref[...] = go * silu


@functools.partial(jax.jit, static_argnames=("tile_m",))
def silu_mul_bwd(gate, up, g_out, tile_m: int = 128):
    """Backward of silu(gate)⊙up. Returns (d_gate, d_up)."""
    m, f = gate.shape
    tm = _pick_tile(m, tile_m)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
            pl.BlockSpec((tm, f), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, f), gate.dtype),
            jax.ShapeDtypeStruct((m, f), gate.dtype),
        ],
        interpret=True,
    )(gate, up, g_out)
