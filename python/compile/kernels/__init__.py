# L1: Pallas kernels for the paper's compute hot-spots, plus pure-jnp
# oracles (ref.py). All kernels lower with interpret=True so the resulting
# HLO runs on the CPU PJRT client the Rust runtime uses.
#
# Import the submodules, not function re-exports: several kernels share a
# name with their module (lora_grad.lora_grad), and re-exporting the
# functions here would shadow the module attributes on the package.

from . import flash_attn, lora_grad, ref, rmsnorm, silu_mul  # noqa: F401
