# Pure-jnp correctness oracles for the Pallas kernels (L1).
#
# Every kernel in this package is validated against these references by
# python/tests/ (pytest + hypothesis). The references are written in the
# most obvious possible style — no tiling, no fusion — so that a mismatch
# always indicts the kernel, not the oracle.
#
# Shapes follow the paper's notation (Appendix A):
#   x : [M, d_in]   flattened (batch*seq) LoRA-layer input
#   g : [M, d_out]  upstream gradient dL/dy
#   A : [d_in, r]   LoRA down-projection
#   B : [r, d_out]  LoRA up-projection
#   s : alpha / r   LoRA scaling

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- LoRA grad
def lora_grad_ref(x, g, a, b, s):
    """Reference for the fused LoRA gradient (paper eq. 10-13, LoRA part).

    Returns (dA, dB, gx_lora):
      dA = x^T (s·g B^T)          [d_in, r]
      dB = (xA)^T (s·g)           [r, d_out]
      gx_lora = (s·g) B^T A^T     [M, d_in]   (the LoRA branch of dL/dx)
    """
    sg = s * g
    h = x @ a                     # the intermediate the paper recomputes
    dh = sg @ b.T
    da = x.T @ dh
    db = h.T @ sg
    gx = dh @ a.T
    return da, db, gx


def lora_fwd_ref(x, w0, a, b, s):
    """y = x W0 + s · x A B (paper eq. 5)."""
    return x @ w0 + s * ((x @ a) @ b)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_ref(x, w, eps=1e-6):
    """x_hat = x / rms(x) * w, rms over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rmsnorm_bwd_ref(x, w, g, eps=1e-6):
    """dL/dx for RMSNorm with (frozen) weight w (paper eq. 22 + weight).

    With u = x / rms(x) (unweighted normalized input) and gw = g ⊙ w:
      dL/dx = (gw - u · mean(gw ⊙ u)) / rms
    """
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    u = x * inv
    gw = g * w
    return (gw - u * jnp.mean(gw * u, axis=-1, keepdims=True)) * inv


# ---------------------------------------------------------------- SiLU-mul
def silu_mul_ref(gate, up):
    """SwiGLU elementwise core: silu(gate) ⊙ up."""
    return jax.nn.silu(gate) * up


def silu_mul_bwd_ref(gate, up, g):
    """Backward of silu(gate)·up (paper eq. 23 for the SiLU factor).

    Returns (d_gate, d_up).
    """
    sig = jax.nn.sigmoid(gate)
    silu = gate * sig
    dsilu = sig * (1.0 + gate * (1.0 - sig))
    return g * up * dsilu, g * silu


# --------------------------------------------------------------- attention
def attention_ref(q, k, v, causal=True):
    """Plain softmax attention. q,k,v: [H, n, hd] (k/v may have fewer heads
    — callers repeat for GQA before calling). Returns ([H, n, hd], probs)."""
    d = q.shape[-1]
    scores = (q @ jnp.swapaxes(k, -1, -2)) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        n, m = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v, probs


def softmax_bwd_ref(probs, g):
    """dL/dscores given probs = softmax(scores) and g = dL/dprobs
    (paper eq. 19)."""
    return probs * (g - jnp.sum(g * probs, axis=-1, keepdims=True))


def attention_bwd_ref(q, k, v, g_out, causal=True):
    """Full attention backward (paper eq. 17-21). Returns (dq, dk, dv)."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = (q @ jnp.swapaxes(k, -1, -2)) * scale
    if causal:
        n, m = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    dv = jnp.swapaxes(probs, -1, -2) @ g_out            # eq. 17
    dprobs = g_out @ jnp.swapaxes(v, -1, -2)            # eq. 18
    dscores = softmax_bwd_ref(probs, dprobs)            # eq. 19
    dq = (dscores @ k) * scale                          # eq. 20
    dk = (jnp.swapaxes(dscores, -1, -2) @ q) * scale    # eq. 21
    return dq, dk, dv
