# L2: the paper's model — a Qwen2.5-style transformer block (RMSNorm →
# GQA attention with RoPE → RMSNorm → SwiGLU MLP) with LoRA adapters on
# all 7 projections (q, k, v, o, gate, up, down), plus the *manually
# derived* backward passes of the paper's Appendix A.
#
# Everything here is build-time only: aot.py lowers these functions to HLO
# text once; the Rust coordinator (L3) executes them via PJRT with no
# Python on the request path. Weights are function ARGUMENTS (generated in
# Rust), never constants, so one artifact serves every layer of a model.
#
# Function inventory (one HLO artifact each — see aot.py):
#   embed_fwd            tokens → x
#   block_fwd            x → y                      (fwd phase, all engines)
#   block_fwd_saveh      x → (y, h×7)               (store-h ablation fwd)
#   block_fwd_residuals  x → (y, residual set)      (MeBP's autodiff-saved set)
#   block_bwd_mesp       (x, g_y) → (g_x, dA×7, dB×7)   ← THE CONTRIBUTION
#   block_bwd_storeh     (x, g_y, h×7) → (g_x, dA×7, dB×7)
#   block_bwd_residuals  (g_y, residuals…) → (g_x, dA×7, dB×7)
#   block_bwd_autodiff   (x, g_y) → (g_x, dA×7, dB×7)   (jax.vjp oracle)
#   lm_loss_fwd          (h, norm_w, emb, targets) → loss
#   lm_loss_grad         …                          → (loss, g_h)
#
# The MeSP backward is a single fused graph that recomputes the Appendix-E
# minimal intermediate set and never exposes any intermediate to the host:
# at runtime the only live cross-call tensors are the block checkpoints.
# The MeBP backward is deliberately split in two (fwd_residuals → buffers
# held by the host → bwd_residuals), mechanically mirroring how autodiff
# frameworks save residuals at forward-recompute time and consume them at
# backward time; those residuals become real, tracked host-side buffers.

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import flash_attn
from .kernels.lora_grad import lora_grad as lora_grad_kernel
from .kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from .kernels.rmsnorm import rmsnorm_bwd as rmsnorm_bwd_kernel
from .kernels.silu_mul import silu_mul as silu_mul_kernel
from .kernels.silu_mul import silu_mul_bwd as silu_mul_bwd_kernel
from .kernels.ref import (
    attention_bwd_ref,
    attention_ref,
    lora_grad_ref,
    rmsnorm_bwd_ref,
    rmsnorm_ref,
    silu_mul_bwd_ref,
    silu_mul_ref,
)

# LoRA adapter sites, in canonical order. This order is the ABI between
# aot.py, manifest.json and the Rust runtime — never reorder.
PROJS = ("q", "k", "v", "o", "gate", "up", "down")

# Frozen per-block weights, canonical order (same ABI note).
FROZEN = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")

# Residual-set tensor names emitted by block_fwd_residuals (after y), the
# set an autodiff framework retains when re-running a checkpointed block:
# every tensor that feeds a gradient rule, including all seven LoRA h's.
RESIDUALS = (
    "x", "h1", "h2", "x2", "q_rope", "k_rope", "v_heads", "probs",
    "attn_flat", "gate_out", "up_out", "silu_out",
    "h_q", "h_k", "h_v", "h_o", "h_gate", "h_up", "h_down",
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model/runtime shape configuration (one artifact set each)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    seq: int
    batch: int = 1
    rank: int = 8
    alpha: float = 16.0
    rope_theta: float = 10000.0
    eps: float = 1e-6
    # Which stages run as Pallas kernels inside the lowered graphs.
    # "lora" is the paper's hot-spot kernel; the rest are optional and
    # exercised by tests + the kernel-ablation artifacts.
    pallas_ops: Sequence[str] = ("lora",)
    # "probs": recompute scores+softmax in bwd, store probs in MeBP's
    # residual set (matches the paper's Appendix E). "flash": the
    # FlashAttention kernels (extension; no O(n^2) tensor anywhere).
    attention: str = "probs"

    @property
    def scale(self) -> float:
        return self.alpha / self.rank

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def proj_dims(self, p: str) -> tuple:
        """(d_in, d_out) of LoRA site p."""
        d, qd, kvd, f = self.d_model, self.q_dim, self.kv_dim, self.d_ff
        return {
            "q": (d, qd), "k": (d, kvd), "v": (d, kvd), "o": (qd, d),
            "gate": (d, f), "up": (d, f), "down": (f, d),
        }[p]

    def frozen_shapes(self) -> dict:
        d, qd, kvd, f = self.d_model, self.q_dim, self.kv_dim, self.d_ff
        return {
            "ln1": (d,), "wq": (d, qd), "wk": (d, kvd), "wv": (d, kvd),
            "wo": (qd, d), "ln2": (d,), "wg": (d, f), "wu": (d, f),
            "wd": (f, d),
        }

    def lora_shapes(self) -> dict:
        out = {}
        for p in PROJS:
            din, dout = self.proj_dims(p)
            out[f"a_{p}"] = (din, self.rank)
            out[f"b_{p}"] = (self.rank, dout)
        return out


# ------------------------------------------------------------------ helpers
def _unpack(cfg: ModelConfig, frozen, lora):
    fz = dict(zip(FROZEN, frozen))
    lo = {}
    for i, p in enumerate(PROJS):
        lo[f"a_{p}"] = lora[2 * i]
        lo[f"b_{p}"] = lora[2 * i + 1]
    return fz, lo


def _rope_tables(cfg: ModelConfig, dtype):
    """cos/sin tables [n, hd/2]; static shapes → folded to constants."""
    half = cfg.head_dim // 2
    pos = jnp.arange(cfg.seq, dtype=jnp.float32)[:, None]
    freq = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos * freq[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin, inverse: bool = False):
    """Neox-style rotate-half RoPE; x: [b, H, n, hd]. The VJP of a rotation
    is the rotation by -θ, which is what inverse=True applies."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    if inverse:
        return jnp.concatenate([x1 * c + x2 * s, x2 * c - x1 * s], axis=-1)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _rmsnorm(cfg: ModelConfig, x2d, w):
    if "norm" in cfg.pallas_ops:
        return rmsnorm_kernel(x2d, w, eps=cfg.eps)
    return rmsnorm_ref(x2d, w, eps=cfg.eps)


def _rmsnorm_bwd(cfg: ModelConfig, x2d, w, g2d):
    if "norm" in cfg.pallas_ops:
        return rmsnorm_bwd_kernel(x2d, w, g2d, eps=cfg.eps)
    return rmsnorm_bwd_ref(x2d, w, g2d, eps=cfg.eps)


def _silu_mul(cfg: ModelConfig, gate, up):
    if "mlp" in cfg.pallas_ops:
        return silu_mul_kernel(gate, up)
    return silu_mul_ref(gate, up)


def _silu_mul_bwd(cfg: ModelConfig, gate, up, g):
    if "mlp" in cfg.pallas_ops:
        return silu_mul_bwd_kernel(gate, up, g)
    return silu_mul_bwd_ref(gate, up, g)


def _lora_linear(cfg: ModelConfig, x2d, w, a, b):
    """Forward of a LoRA site (paper eq. 5). Returns (y2d, h2d)."""
    h = x2d @ a
    return x2d @ w + cfg.scale * (h @ b), h


def _lora_grad(cfg: ModelConfig, x2d, g2d, a, b):
    """Backward of the LoRA branch, recomputing h (paper eq. 10-13).
    Returns (dA, dB, gx_lora). Routes through the Pallas hot-spot kernel."""
    if "lora" in cfg.pallas_ops:
        return lora_grad_kernel(x2d, g2d, a, b, cfg.scale)
    return lora_grad_ref(x2d, g2d, a, b, cfg.scale)


def _lora_linear_bwd(cfg: ModelConfig, x2d, g2d, w, a, b, h2d=None):
    """Full LoRA-linear backward. If h2d is given (store-h ablation), dB
    uses the stored h; otherwise h is recomputed inside the fused kernel.
    Returns (gx, dA, dB)."""
    if h2d is None:
        da, db, gx_lora = _lora_grad(cfg, x2d, g2d, a, b)
    else:
        sg = cfg.scale * g2d
        dh = sg @ b.T
        da = x2d.T @ dh
        db = h2d.T @ sg                       # stored h — no recompute
        gx_lora = dh @ a.T
    return gx_lora + g2d @ w.T, da, db


def _split_heads(cfg: ModelConfig, x2d, n_heads):
    b, n = cfg.batch, cfg.seq
    return x2d.reshape(b, n, n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(cfg: ModelConfig, x4d):
    b, n = cfg.batch, cfg.seq
    return x4d.transpose(0, 2, 1, 3).reshape(b * n, -1)


def _repeat_kv(cfg: ModelConfig, x4d):
    """[b, KV, n, hd] → [b, H, n, hd] for GQA."""
    rep = cfg.n_heads // cfg.n_kv_heads
    return jnp.repeat(x4d, rep, axis=1)


def _reduce_kv(cfg: ModelConfig, g4d):
    """VJP of _repeat_kv: sum grads over the query-head group."""
    rep = cfg.n_heads // cfg.n_kv_heads
    b, _, n, hd = g4d.shape
    return g4d.reshape(b, cfg.n_kv_heads, rep, n, hd).sum(axis=2)


def _attention_fwd(cfg: ModelConfig, q, k, v):
    """Returns (out [b,H,n,hd], saved) where saved is probs or lse."""
    if cfg.attention == "flash":
        fa = functools.partial(flash_attn.flash_attention, causal=True)
        out, lse = jax.vmap(jax.vmap(fa))(q, k, v)
        return out, lse
    out, probs = jax.vmap(attention_ref)(q, k, v)   # vmap over batch
    return out, probs


def _attention_bwd(cfg: ModelConfig, q, k, v, out, saved, g_out):
    if cfg.attention == "flash":
        fb = functools.partial(flash_attn.flash_attention_bwd, causal=True)
        return jax.vmap(jax.vmap(fb))(q, k, v, out, saved, g_out)
    # `saved` is probs; recompute-free softmax backward (paper eq. 17-21).
    probs = saved
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    dv = jnp.swapaxes(probs, -1, -2) @ g_out
    dprobs = g_out @ jnp.swapaxes(v, -1, -2)
    dscores = probs * (dprobs - jnp.sum(dprobs * probs, axis=-1, keepdims=True))
    dq = (dscores @ k) * scale
    dk = (jnp.swapaxes(dscores, -1, -2) @ q) * scale
    return dq, dk, dv


# --------------------------------------------------------------- block fwd
def _block_core(cfg: ModelConfig, x, frozen, lora):
    """Full block forward. Returns (y, cache) with every intermediate a
    backward pass could need; callers choose what to expose/discard."""
    fz, lo = _unpack(cfg, frozen, lora)
    b, n, d = cfg.batch, cfg.seq, cfg.d_model
    m = b * n
    x2d = x.reshape(m, d)

    h1 = _rmsnorm(cfg, x2d, fz["ln1"])
    q2d, h_q = _lora_linear(cfg, h1, fz["wq"], lo["a_q"], lo["b_q"])
    k2d, h_k = _lora_linear(cfg, h1, fz["wk"], lo["a_k"], lo["b_k"])
    v2d, h_v = _lora_linear(cfg, h1, fz["wv"], lo["a_v"], lo["b_v"])

    cos, sin = _rope_tables(cfg, x.dtype)
    q4 = apply_rope(_split_heads(cfg, q2d, cfg.n_heads), cos, sin)
    k4 = apply_rope(_split_heads(cfg, k2d, cfg.n_kv_heads), cos, sin)
    v4 = _split_heads(cfg, v2d, cfg.n_kv_heads)

    k_rep = _repeat_kv(cfg, k4)
    v_rep = _repeat_kv(cfg, v4)
    attn_out, attn_saved = _attention_fwd(cfg, q4, k_rep, v_rep)
    attn_flat = _merge_heads(cfg, attn_out)

    o2d, h_o = _lora_linear(cfg, attn_flat, fz["wo"], lo["a_o"], lo["b_o"])
    x2 = x2d + o2d

    h2 = _rmsnorm(cfg, x2, fz["ln2"])
    gate_out, h_gate = _lora_linear(cfg, h2, fz["wg"], lo["a_gate"], lo["b_gate"])
    up_out, h_up = _lora_linear(cfg, h2, fz["wu"], lo["a_up"], lo["b_up"])
    silu_out = _silu_mul(cfg, gate_out, up_out)
    d2d, h_down = _lora_linear(cfg, silu_out, fz["wd"], lo["a_down"], lo["b_down"])
    y2d = x2 + d2d

    cache = dict(
        x=x2d, h1=h1, h2=h2, x2=x2, q_rope=q4, k_rope=k4, v_heads=v4,
        attn_out=attn_out, attn_saved=attn_saved, attn_flat=attn_flat,
        gate_out=gate_out, up_out=up_out, silu_out=silu_out,
        h_q=h_q, h_k=h_k, h_v=h_v, h_o=h_o, h_gate=h_gate, h_up=h_up,
        h_down=h_down,
    )
    return y2d.reshape(b, n, d), cache


def block_fwd(cfg: ModelConfig, x, frozen, lora):
    """Forward-only: everything but y is a dead value → XLA frees it.
    This is the MeSP/MeZO forward phase (checkpoint = y only)."""
    y, _ = _block_core(cfg, x, frozen, lora)
    return (y,)


def block_fwd_saveh(cfg: ModelConfig, x, frozen, lora):
    """Forward that additionally emits the 7 LoRA intermediates h = xA —
    the store-h ablation of the paper's Table 5."""
    y, c = _block_core(cfg, x, frozen, lora)
    return (y, c["h_q"], c["h_k"], c["h_v"], c["h_o"], c["h_gate"],
            c["h_up"], c["h_down"])


def block_fwd_residuals(cfg: ModelConfig, x, frozen, lora):
    """Forward that emits the full autodiff-retained residual set (MeBP's
    backward-phase recompute). The host holds these as live buffers until
    the block's backward — exactly the framework behaviour the paper says
    'stores more intermediates than mathematically necessary'."""
    y, c = _block_core(cfg, x, frozen, lora)
    assert cfg.attention == "probs", "residual path stores probs"
    c["probs"] = c["attn_saved"]
    return (y,) + tuple(c[name] for name in RESIDUALS)


# --------------------------------------------------------------- block bwd
def _block_bwd_math(cfg: ModelConfig, g_y, c, fz, lo, stored_h=None):
    """The paper's Appendix-A backward, shared by the mesp / storeh /
    residuals variants; `c` holds whichever intermediates exist (recomputed
    or retrieved), `stored_h` switches dB to stored-h mode (Table 5)."""
    b, n, d = cfg.batch, cfg.seq, cfg.d_model
    m = b * n
    g_y2d = g_y.reshape(m, d)
    sh = (lambda p: stored_h[p]) if stored_h is not None else (lambda p: None)

    # y = x2 + down(silu_mul(gate(h2), up(h2)))
    g_x2 = g_y2d
    g_silu, da_down, db_down = _lora_linear_bwd(
        cfg, c["silu_out"], g_y2d, fz["wd"], lo["a_down"], lo["b_down"],
        h2d=sh("down"))
    g_gate, g_up = _silu_mul_bwd(cfg, c["gate_out"], c["up_out"], g_silu)
    g_h2_a, da_gate, db_gate = _lora_linear_bwd(
        cfg, c["h2"], g_gate, fz["wg"], lo["a_gate"], lo["b_gate"],
        h2d=sh("gate"))
    g_h2_b, da_up, db_up = _lora_linear_bwd(
        cfg, c["h2"], g_up, fz["wu"], lo["a_up"], lo["b_up"], h2d=sh("up"))
    g_x2 = g_x2 + _rmsnorm_bwd(cfg, c["x2"], fz["ln2"], g_h2_a + g_h2_b)

    # x2 = x + o(attn_flat)
    g_attn_flat, da_o, db_o = _lora_linear_bwd(
        cfg, c["attn_flat"], g_x2, fz["wo"], lo["a_o"], lo["b_o"],
        h2d=sh("o"))
    g_attn_out = g_attn_flat.reshape(b, n, cfg.n_heads, cfg.head_dim)
    g_attn_out = g_attn_out.transpose(0, 2, 1, 3)

    k_rep = _repeat_kv(cfg, c["k_rope"])
    v_rep = _repeat_kv(cfg, c["v_heads"])
    g_q4, g_k_rep, g_v_rep = _attention_bwd(
        cfg, c["q_rope"], k_rep, v_rep, c.get("attn_out"), c["attn_saved"],
        g_attn_out)
    g_k4 = _reduce_kv(cfg, g_k_rep)
    g_v4 = _reduce_kv(cfg, g_v_rep)

    cos, sin = _rope_tables(cfg, g_y.dtype)
    g_q2d = _merge_heads(cfg, apply_rope(g_q4, cos, sin, inverse=True))
    g_k2d = _merge_heads(cfg, apply_rope(g_k4, cos, sin, inverse=True))
    g_v2d = _merge_heads(cfg, g_v4)

    g_h1_q, da_q, db_q = _lora_linear_bwd(
        cfg, c["h1"], g_q2d, fz["wq"], lo["a_q"], lo["b_q"], h2d=sh("q"))
    g_h1_k, da_k, db_k = _lora_linear_bwd(
        cfg, c["h1"], g_k2d, fz["wk"], lo["a_k"], lo["b_k"], h2d=sh("k"))
    g_h1_v, da_v, db_v = _lora_linear_bwd(
        cfg, c["h1"], g_v2d, fz["wv"], lo["a_v"], lo["b_v"], h2d=sh("v"))

    g_x = g_x2 + _rmsnorm_bwd(cfg, c["x"], fz["ln1"],
                              g_h1_q + g_h1_k + g_h1_v)
    grads = (da_q, db_q, da_k, db_k, da_v, db_v, da_o, db_o,
             da_gate, db_gate, da_up, db_up, da_down, db_down)
    return (g_x.reshape(b, n, d),) + grads


def block_bwd_mesp(cfg: ModelConfig, x, g_y, frozen, lora):
    """THE paper's contribution: fused recompute-everything backward.
    One call consumes (checkpointed x, upstream g_y) and produces g_x and
    all 14 LoRA grads; every intermediate — including all seven h = xA —
    lives only inside this graph (h only inside a Pallas VMEM tile)."""
    fz, lo = _unpack(cfg, frozen, lora)
    _, c = _block_core(cfg, x, frozen, lora)    # recompute minimal set
    return _block_bwd_math(cfg, g_y, c, fz, lo)


def block_bwd_storeh(cfg: ModelConfig, x, g_y, hs, frozen, lora):
    """Table-5 ablation: identical math, but the seven h tensors were
    stored at forward time and are consumed here instead of recomputed."""
    fz, lo = _unpack(cfg, frozen, lora)
    _, c = _block_core(cfg, x, frozen, lora)
    stored = dict(zip(PROJS, hs))
    return _block_bwd_math(cfg, g_y, c, fz, lo, stored_h=stored)


def block_bwd_residuals(cfg: ModelConfig, g_y, residuals, frozen, lora):
    """MeBP backward half: consumes the host-held residual set emitted by
    block_fwd_residuals (no recompute in this graph — the recompute already
    happened in the paired forward call, as in framework autodiff)."""
    fz, lo = _unpack(cfg, frozen, lora)
    c = dict(zip(RESIDUALS, residuals))
    c["attn_saved"] = c["probs"]
    c["attn_out"] = None                        # probs path never needs it
    stored = {p: c[f"h_{p}"] for p in PROJS}
    return _block_bwd_math(cfg, g_y, c, fz, lo, stored_h=stored)


def block_bwd_autodiff(cfg: ModelConfig, x, g_y, frozen, lora):
    """Gradcheck oracle: jax.vjp over the plain forward. Mathematically
    what MeBP computes; used to assert Appendix-A equivalence in tests and
    from the Rust gradcheck command."""
    # The oracle differentiates the pure-jnp path: no Pallas kernels (jax
    # cannot autodiff through interpret-mode pallas_call) and "probs"
    # attention. Numerically this is the same function, so comparing the
    # mesp/storeh/residual outputs against it validates flash too.
    ref_cfg = dataclasses.replace(cfg, pallas_ops=(), attention="probs")

    def f(x_, lora_):
        y, _ = _block_core(ref_cfg, x_, frozen, lora_)
        return y

    _, vjp = jax.vjp(f, x, tuple(lora))
    g_x, g_lora = vjp(g_y)
    return (g_x,) + tuple(g_lora)


# --------------------------------------------------------------- loss head
def _lm_logits(cfg: ModelConfig, h, norm_w, emb):
    m = cfg.batch * cfg.seq
    h2d = h.reshape(m, cfg.d_model)
    hn = _rmsnorm(cfg, h2d, norm_w)
    return hn, hn @ emb.T                       # tied lm head


def lm_loss_fwd(cfg: ModelConfig, h, norm_w, emb, targets):
    """Mean causal-LM cross-entropy. h: [b,n,d] (last block's output),
    targets: [b,n] int32 (pre-shifted by the Rust data pipeline)."""
    _, logits = _lm_logits(cfg, h, norm_w, emb)
    t = targets.reshape(-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, t[:, None], axis=-1)[:, 0]
    return (jnp.mean(logz - picked),)


def lm_loss_grad(cfg: ModelConfig, h, norm_w, emb, targets):
    """Loss + manual backward to g_h (softmax-CE grad, then lm-head and
    final-RMSNorm VJPs — all Appendix-A style, no autodiff)."""
    m = cfg.batch * cfg.seq
    hn, logits = _lm_logits(cfg, h, norm_w, emb)
    t = targets.reshape(-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, t[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - picked)

    probs = jax.nn.softmax(logits, axis=-1)
    g_logits = (probs - jax.nn.one_hot(t, cfg.vocab, dtype=h.dtype)) / m
    g_hn = g_logits @ emb
    h2d = h.reshape(m, cfg.d_model)
    g_h = _rmsnorm_bwd(cfg, h2d, norm_w, g_hn)
    return loss, g_h.reshape(cfg.batch, cfg.seq, cfg.d_model)


def embed_fwd(cfg: ModelConfig, tokens, emb):
    """Token embedding lookup; tokens: [b,n] int32, emb: [V,d]."""
    return (jnp.take(emb, tokens, axis=0),)


# ------------------------------------------------------- quantized variant
# The paper keeps base weights int4 with on-the-fly dequantization (§4.5).
# This artifact takes the 7 projection matrices as (packed uint8, scales)
# pairs and dequantizes INSIDE the HLO graph: the host never materializes
# f32 base weights. Norm weights stay f32 (they are [d]-sized).
QUANT_MATS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


def block_fwd_q4(cfg: ModelConfig, x, ln1, ln2, qpairs, lora):
    """Forward with int4 base weights. qpairs: flat
    [packed_wq, scales_wq, packed_wk, …] in QUANT_MATS order.

    Packed nibbles travel as uint8 ("u8" in the manifest), matching
    quant.quantize's output and the Rust reference backend's q4 specs.
    (The historical i32 detour for the xla crate's U8 host-buffer bug is
    gone: the Rust client routes Data::U8 through the literal path.)"""
    from . import quant

    deq = {}
    for i, name in enumerate(QUANT_MATS):
        packed, scales = qpairs[2 * i], qpairs[2 * i + 1]
        deq[name] = quant.dequantize(packed, scales)
    frozen = [ln1, deq["wq"], deq["wk"], deq["wv"], deq["wo"], ln2,
              deq["wg"], deq["wu"], deq["wd"]]
    y, _ = _block_core(cfg, x, frozen, lora)
    return (y,)
