# Make `pytest python/tests/` work from the repo root: the tests import
# the `compile` package which lives in this directory.
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
