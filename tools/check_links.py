#!/usr/bin/env python3
"""Fail CI on dead intra-repo markdown links.

Scans the repo's markdown documentation (README.md, docs/*.md, and the
other root-level .md files), extracts inline links and bare backticked
file references of the form [text](target), and verifies every
relative target exists in the tree. External links (http/https/mailto)
are skipped; '#fragment' suffixes are stripped before the existence
check. Exit code 0 = all links resolve, 1 = at least one dead link
(each printed as file:line).
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images is unnecessary; image targets must
# exist too. Nested parens in targets do not occur in this repo.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def doc_files():
    files = sorted(REPO.glob("*.md"))
    docs = REPO / "docs"
    if docs.is_dir():
        files += sorted(docs.glob("*.md"))
    return files


def check_file(path):
    """Return a list of (line_number, target) dead links in one file."""
    dead = []
    text = path.read_text(encoding="utf-8")
    in_fence = False
    for lineno, line in enumerate(text.splitlines(), start=1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for match in LINK.finditer(line):
            target = match.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue  # pure-fragment link into the same file
            resolved = (path.parent / target).resolve()
            try:
                resolved.relative_to(REPO)
            except ValueError:
                dead.append((lineno, target + " (escapes the repo)"))
                continue
            if not resolved.exists():
                dead.append((lineno, target))
    return dead


def main():
    files = doc_files()
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    total_links = 0
    failures = 0
    for path in files:
        dead = check_file(path)
        total_links += 1  # at least count the file as visited
        for lineno, target in dead:
            failures += 1
            rel = path.relative_to(REPO)
            print(f"{rel}:{lineno}: dead link -> {target}", file=sys.stderr)
    if failures:
        print(f"check_links: {failures} dead link(s)", file=sys.stderr)
        return 1
    print(f"check_links: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
