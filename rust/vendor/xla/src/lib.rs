//! API-surface **stub** of the `xla` PJRT crate.
//!
//! The offline build environment cannot fetch (or link) the real XLA/PJRT
//! toolchain, but the `pjrt` cargo feature still has to type-check so the
//! feature-gated runtime stays honest. This crate declares exactly the
//! types and signatures `mesp::runtime::client` consumes; every operation
//! returns an error at runtime. To actually execute HLO artifacts, replace
//! the `xla` path dependency in `rust/Cargo.toml` with the real crate —
//! no code changes are needed on the mesp side.

/// Error returned by every stubbed operation.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: the vendored `xla` crate is an offline stub; link the \
             real xla/PJRT crate to use the pjrt backend"
        ))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the mesp runtime exchanges with PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    U8,
}

#[derive(Debug)]
pub struct PjRtClient;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct Literal;

#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_literal"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable("Literal::array_shape"))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::unavailable("Literal::ty"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
