//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! and the `anyhow!` / `bail!` / `ensure!` macros, plus `?`-conversion from
//! any `std::error::Error`. Swap the path dependency for the real crate in
//! a connected build; nothing in the calling code changes.

use std::fmt;

/// A string-backed error value with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend context, mirroring `anyhow::Context` semantics.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }
}

impl Error {
    /// First cause NOT already rendered in `msg`: `From<E>` copies the
    /// converted error's message into `msg`, so the printable chain
    /// starts at that error's own source.
    fn chain_start(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .and_then(|s| (s as &dyn std::error::Error).source())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        // `{:#}` renders the chain inline like anyhow does.
        if f.alternate() {
            let mut src = self.chain_start();
            while let Some(s) = src {
                write!(f, ": {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.chain_start();
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion (the `?` operator on any std error) stays coherent —
// the same trick the real anyhow uses.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}", stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn io_error_converts_and_chains() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
        assert!(e.source.is_some());
    }

    #[test]
    fn macros_format() {
        let name = "x";
        let e = anyhow!("missing {name} in {}", "manifest");
        assert_eq!(e.to_string(), "missing x in manifest");
        let f = || -> Result<()> { bail!("nope {}", 3) };
        assert_eq!(f().unwrap_err().to_string(), "nope 3");
        let g = |v: usize| -> Result<()> {
            ensure!(v > 2, "v too small: {v}");
            Ok(())
        };
        assert!(g(3).is_ok());
        assert_eq!(g(1).unwrap_err().to_string(), "v too small: 1");
    }

    #[test]
    fn context_prepends() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
