//! Bench: Table 1's time column — full training-step latency per method
//! on the compiled `small` config (and `toy` for fast regressions).
//! The paper's claim to reproduce: MeSP costs ~1.2-1.4x MeBP per step
//! (its 27-31% overhead) while MeZO's two forwards are cheaper per step.

#[path = "harness.rs"]
mod harness;

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;

fn step_bench(config: &str, method: Method, iters: usize)
    -> harness::BenchResult
{
    let cfg = TrainConfig {
        config: config.into(),
        method,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::new(cfg).expect("session");
    // pre-fetch a batch and reuse it so data time is excluded
    let (batch, _g) = sess.loader.next();
    harness::bench(
        &format!("{config}/step/{}", method.name()),
        2,
        iters,
        || {
            sess.engine.step(&batch).expect("step");
        },
    )
}

fn main() {
    println!("== Table 1 (time column): step latency per method ==");
    for config in ["toy", "small"] {
        let mebp = step_bench(config, Method::Mebp, 20);
        let mezo = step_bench(config, Method::Mezo, 20);
        let mesp = step_bench(config, Method::Mesp, 20);
        harness::ratio("MeSP overhead", &mebp, &mesp);
        harness::ratio("MeZO ratio  ", &mebp, &mezo);
        println!("paper @0.5B: MeSP 1.26x, MeZO 0.75x of MeBP\n");
    }
}
