//! Bench: Table 1's time column — full training-step latency per method
//! on the compiled `small` config (and `toy` for fast regressions).
//! The paper's claim to reproduce: MeSP costs ~1.2-1.4x MeBP per step
//! (its 27-31% overhead) while MeZO's two forwards are cheaper per step.
//!
//! Also benches the kernel engine end to end: the same MeSP step under
//! `--kernel naive|tiled|parallel`, recording the speedups (acceptance
//! bar: ≥4x for parallel over naive on `small`) into the
//! `BENCH_kernels.json` record at the repo root.

#[path = "harness.rs"]
mod harness;

use mesp::config::{KernelKind, Method, QuantMode, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::util::Json;

fn step_bench(config: &str, method: Method, kernel: KernelKind, iters: usize)
    -> harness::BenchResult
{
    step_bench_q(config, method, kernel, QuantMode::F32, iters)
}

fn step_bench_q(
    config: &str,
    method: Method,
    kernel: KernelKind,
    quant: QuantMode,
    iters: usize,
) -> harness::BenchResult {
    let cfg = TrainConfig {
        config: config.into(),
        method,
        kernel,
        quant,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().expect("session");
    // pre-fetch a batch and reuse it so data time is excluded
    let (batch, _g) = sess.loader.next();
    harness::bench(
        &format!("{config}/step/{}/{}/{}", method.name(), kernel.name(),
                 quant.name()),
        2,
        iters,
        || {
            sess.engine.step(&batch).expect("step");
        },
    )
}

fn main() {
    println!("== Table 1 (time column): step latency per method ==");
    for config in ["toy", "small"] {
        let kernel = KernelKind::Parallel; // production default
        let mebp = step_bench(config, Method::Mebp, kernel, 20);
        let mezo = step_bench(config, Method::Mezo, kernel, 20);
        let mesp = step_bench(config, Method::Mesp, kernel, 20);
        harness::ratio("MeSP overhead", &mebp, &mesp);
        harness::ratio("MeZO ratio  ", &mebp, &mezo);
        println!("paper @0.5B: MeSP 1.26x, MeZO 0.75x of MeBP\n");
    }

    println!("== kernel engine: MeSP step under each GEMM kernel ==");
    for config in ["toy", "small"] {
        let iters = if config == "toy" { 20 } else { 10 };
        let naive = step_bench(config, Method::Mesp, KernelKind::Naive, iters);
        let tiled = step_bench(config, Method::Mesp, KernelKind::Tiled, iters);
        let parallel =
            step_bench(config, Method::Mesp, KernelKind::Parallel, iters);
        let s_tiled = naive.mean_ms / tiled.mean_ms;
        let s_parallel = naive.mean_ms / parallel.mean_ms;
        println!(
            "{config}: step speedup over naive — tiled {s_tiled:.2}x, \
             parallel {s_parallel:.2}x\n"
        );
        harness::write_bench_json(
            &format!("table1_step_time_{config}"),
            vec![
                ("naive_ms".to_string(), Json::num(naive.mean_ms)),
                ("tiled_ms".to_string(), Json::num(tiled.mean_ms)),
                ("parallel_ms".to_string(), Json::num(parallel.mean_ms)),
                ("speedup_tiled".to_string(), Json::num(s_tiled)),
                ("speedup_parallel".to_string(), Json::num(s_parallel)),
                (
                    "threads".to_string(),
                    Json::num(mesp::runtime::kernels::auto_threads() as u32),
                ),
            ],
        );
    }

    println!("== q4 path: MeSP step, f32 vs int4-resident base weights ==");
    for config in ["toy", "small"] {
        let iters = if config == "toy" { 20 } else { 10 };
        let f32_step = step_bench_q(
            config, Method::Mesp, KernelKind::Parallel, QuantMode::F32, iters,
        );
        let q4_step = step_bench_q(
            config, Method::Mesp, KernelKind::Parallel, QuantMode::Q4, iters,
        );
        harness::ratio("q4 step vs f32", &f32_step, &q4_step);
        println!(
            "{config}: q4/f32 step-time ratio {:.2} (fused panel dequant \
             overhead)\n",
            q4_step.mean_ms / f32_step.mean_ms
        );
        harness::write_bench_json(
            &format!("table1_step_time_q4_{config}"),
            vec![
                ("f32_ms".to_string(), Json::num(f32_step.mean_ms)),
                ("q4_ms".to_string(), Json::num(q4_step.mean_ms)),
                (
                    "q4_over_f32".to_string(),
                    Json::num(q4_step.mean_ms / f32_step.mean_ms),
                ),
            ],
        );
    }
}
