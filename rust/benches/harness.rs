//! Minimal benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p95 reporting, matching the output
//! conventions the EXPERIMENTS.md perf section records — plus the
//! machine-readable `BENCH_kernels.json` emitter that records the perf
//! trajectory PR-over-PR at the repo root.
#![allow(dead_code)] // shared by several bench binaries; not all use every helper

use std::collections::BTreeMap;
use std::time::Instant;

use mesp::util::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Run `f` for `warmup` unrecorded + `iters` recorded iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
    -> BenchResult
{
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((q * (samples.len() - 1) as f64) as usize)
        .min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        p50_ms: p(0.5),
        p95_ms: p(0.95),
    };
    println!(
        "{:<38} {:>5} iters  mean {:>9.3} ms  p50 {:>9.3} ms  p95 {:>9.3} ms",
        r.name, r.iters, r.mean_ms, r.p50_ms, r.p95_ms
    );
    r
}

/// Print a comparison line between a baseline and a candidate.
pub fn ratio(label: &str, base: &BenchResult, cand: &BenchResult) {
    println!(
        "{label}: {:.2}x vs {} ({:.3} ms vs {:.3} ms)",
        cand.mean_ms / base.mean_ms, base.name, cand.mean_ms, base.mean_ms
    );
}

/// Path of the machine-readable bench record at the repo root.
pub fn bench_json_path() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").to_string()
}

/// Parse the committed `BENCH_kernels.json` (empty object when absent or
/// malformed). Call BEFORE `write_bench_json` merges the current run in:
/// `--check` gates against the committed baseline, not the numbers the
/// run just measured.
pub fn read_bench_json() -> Json {
    std::fs::read_to_string(bench_json_path())
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .filter(|j| matches!(j, Json::Obj(_)))
        .unwrap_or(Json::Obj(BTreeMap::new()))
}

/// Committed baseline number `section.key`, if the record has one.
pub fn baseline_f64(root: &Json, section: &str, key: &str) -> Option<f64> {
    root.get(section)?.get(key)?.as_f64()
}

/// Merge `entries` into the `section` object of `BENCH_kernels.json`,
/// creating the file if absent and preserving every other section — so
/// the kernel microbench and the step-time bench each own a section and
/// the perf trajectory accumulates run-over-run.
pub fn write_bench_json(section: &str, entries: Vec<(String, Json)>) {
    let path = bench_json_path();
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or(Json::Obj(BTreeMap::new()));
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(BTreeMap::new());
    }
    if let Json::Obj(m) = &mut root {
        m.insert(
            section.to_string(),
            Json::Obj(entries.into_iter().collect()),
        );
    }
    match std::fs::write(&path, root.to_string()) {
        Ok(()) => println!("(recorded section '{section}' in {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
