//! Bench: Table 5 — store-h vs recompute-h step latency. The paper
//! measures recompute-h ~6% slower than store-h (3B: 4.09s vs 3.85s);
//! the ordering (recompute ≥ store ≥ plain MeBP is NOT implied — MeBP's
//! two-phase backward pays residual traffic) is what we verify here.

#[path = "harness.rs"]
mod harness;

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;

fn main() {
    println!("== Table 5: h-strategy step latency (config small) ==");
    let mut results = Vec::new();
    for method in [Method::Mebp, Method::StoreH, Method::Mesp] {
        let cfg = TrainConfig {
            config: "small".into(),
            method,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        results.push(harness::bench(
            &format!("small/step/{}", method.name()),
            2,
            25,
            || {
                sess.engine.step(&batch).expect("step");
            },
        ));
    }
    harness::ratio("store-h vs MeBP   ", &results[0], &results[1]);
    harness::ratio("recompute-h vs MeBP", &results[0], &results[2]);
    println!("paper @3B: store-h 1.20x, recompute-h 1.27x of MeBP");
}
