//! Bench: Table 5 — store-h vs recompute-h step latency. The paper
//! measures recompute-h ~6% slower than store-h (3B: 4.09s vs 3.85s);
//! the ordering (recompute ≥ store ≥ plain MeBP is NOT implied — MeBP's
//! two-phase backward pays residual traffic) is what we verify here.
//!
//! Second section: the loss-head scratch peak on the `longctx` preset
//! (vocab 32768 over d_model 128 at seq 512 — the regime where the
//! `m×vocab` logits dwarf every block intermediate), comparing
//! `--loss-chunk {full, 256, 64}`. Peaks are tracked with a
//! single-threaded tiled engine so GEMM packing panels stay negligible;
//! latency uses the parallel engine. Smoke gates: chunked < unchunked,
//! and chunk 64 cuts the tracked loss-phase scratch ≥4× (the acceptance
//! bar for the chunked lm head). Results land in `BENCH_kernels.json`
//! under `table5_loss_head`.

#[path = "harness.rs"]
mod harness;

use mesp::config::{presets, KernelKind, Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::memory::MemoryTracker;
use mesp::runtime::{refmath, KernelOptions, Kernels};
use mesp::util::{Json, Rng};

/// Tracked `scratch`-tag peak and mean latency of one full
/// loss-and-grad pass at the given chunk (0 = unchunked oracle).
fn loss_head_run(chunk: usize) -> (u64, f64) {
    let dims = presets::compiled("longctx").expect("longctx preset");
    let (m, d, v) = (dims.batch * dims.seq, dims.d_model, dims.vocab);
    let mut rng = Rng::new(0x1055);
    let h = rng.normal_vec(m * d, 0.5);
    let norm_w = vec![1.0f32; d];
    let emb = rng.normal_vec(v * d, 0.02);
    let targets: Vec<i32> = (0..m).map(|i| (i * 97 % v) as i32).collect();

    let grad = |ks: &Kernels| match chunk {
        0 => refmath::lm_loss_grad(ks, &h, &norm_w, &emb, &targets, m, d, v),
        c => refmath::lm_loss_grad_chunked(
            ks, &h, &norm_w, &emb, &targets, m, d, v, c,
        ),
    };

    // Peak: tiled single-thread keeps packing panels out of the picture.
    let tracker = MemoryTracker::new();
    let ks = Kernels::new(
        KernelOptions { kind: KernelKind::Tiled, threads: 1 },
        tracker.clone(),
    );
    grad(&ks).expect("loss grad");
    let peak = tracker.tag_peak("scratch");

    // Latency: the production parallel engine.
    let ks = Kernels::new(
        KernelOptions { kind: KernelKind::Parallel, threads: 0 },
        MemoryTracker::new(),
    );
    let label = if chunk == 0 { "full".into() } else { chunk.to_string() };
    let r = harness::bench(
        &format!("longctx/loss_head/chunk_{label}"),
        1,
        5,
        || {
            grad(&ks).expect("loss grad");
        },
    );
    (peak, r.mean_ms)
}

fn main() {
    println!("== Table 5: h-strategy step latency (config small) ==");
    let mut results = Vec::new();
    for method in [Method::Mebp, Method::StoreH, Method::Mesp] {
        let cfg = TrainConfig {
            config: "small".into(),
            method,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        results.push(harness::bench(
            &format!("small/step/{}", method.name()),
            2,
            25,
            || {
                sess.engine.step(&batch).expect("step");
            },
        ));
    }
    harness::ratio("store-h vs MeBP   ", &results[0], &results[1]);
    harness::ratio("recompute-h vs MeBP", &results[0], &results[2]);
    println!("paper @3B: store-h 1.20x, recompute-h 1.27x of MeBP");

    println!("\n== loss-head scratch peak: longctx, chunked lm head ==");
    let (peak_full, ms_full) = loss_head_run(0);
    let (peak_256, ms_256) = loss_head_run(256);
    let (peak_64, ms_64) = loss_head_run(64);
    let mb = |b: u64| b as f64 / (1024.0 * 1024.0);
    println!(
        "loss-phase scratch peak: full {:.1} MB, chunk 256 {:.1} MB, \
         chunk 64 {:.1} MB ({:.1}x reduction)",
        mb(peak_full),
        mb(peak_256),
        mb(peak_64),
        peak_full as f64 / peak_64 as f64
    );
    // Smoke gates: chunked must beat the oracle, and chunk 64 must cut
    // the loss-phase scratch by the acceptance bar.
    assert!(
        peak_256 < peak_full && peak_64 < peak_256,
        "chunked loss-head peak must shrink monotonically: \
         {peak_full} / {peak_256} / {peak_64}"
    );
    assert!(
        peak_64 * 4 <= peak_full,
        "chunk 64 must cut loss scratch >=4x on longctx: \
         {peak_64} vs {peak_full}"
    );
    harness::write_bench_json(
        "table5_loss_head",
        vec![
            ("full_peak_mb".to_string(), Json::num(mb(peak_full))),
            ("chunk256_peak_mb".to_string(), Json::num(mb(peak_256))),
            ("chunk64_peak_mb".to_string(), Json::num(mb(peak_64))),
            (
                "chunk64_reduction".to_string(),
                Json::num(peak_full as f64 / peak_64 as f64),
            ),
            ("full_ms".to_string(), Json::num(ms_full)),
            ("chunk256_ms".to_string(), Json::num(ms_256)),
            ("chunk64_ms".to_string(), Json::num(ms_64)),
        ],
    );
}
