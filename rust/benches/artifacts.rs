//! Bench: single-artifact execution latency — the hot spots as the
//! backend sees them. Separates the fused MeSP backward (one call) from
//! MeBP's two-phase backward (fwd_residuals + bwd_residuals) and shows
//! where the recompute-vs-store tradeoff lands at call granularity.
//! Runs on whichever backend `TrainConfig::default()` selects.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use mesp::config::{presets, QuantMode, TrainConfig};
use mesp::coordinator::make_backend;
use mesp::memory::MemoryTracker;
use mesp::model::ModelSpec;
use mesp::obs::TraceSink;
use mesp::runtime::{Arg, Backend};
use mesp::tensor::HostTensor;
use mesp::util::Rng;

fn main() {
    let tracker = MemoryTracker::new();
    for config in ["toy", "small"] {
        println!("== artifact exec latency, config {config} ==");
        let cfg = TrainConfig { config: config.into(), ..Default::default() };
        let dims = Arc::new(presets::compiled(config).expect("dims"));
        let rt: Arc<dyn Backend> = make_backend(
            &cfg, dims.clone(), tracker.clone(), TraceSink::disabled(),
        )
        .expect("backend");
        let dims = rt.dims().clone();
        let (frozen, adapters) =
            ModelSpec::new(dims.clone(), 1, QuantMode::F32).build(&tracker);
        let mut rng = Rng::new(2);
        let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model],
                                  0.5, &mut rng);
        let gy = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model],
                                   0.5, &mut rng);

        let fwd_args = |lead: Vec<&HostTensor>| -> Vec<HostTensor> {
            // materialize owned clones so the closure below is simple
            let mut v: Vec<HostTensor> = lead.into_iter().cloned().collect();
            for t in frozen.block_tensors(0) {
                v.push(t.clone());
            }
            for t in &adapters.lora[0].tensors {
                v.push(t.clone());
            }
            v
        };

        for (name, leads) in [
            ("block_fwd", vec![&x]),
            ("block_fwd_saveh", vec![&x]),
            ("block_fwd_residuals", vec![&x]),
            ("block_bwd_mesp", vec![&x, &gy]),
            ("block_bwd_autodiff", vec![&x, &gy]),
        ] {
            if !rt.has_artifact(name) {
                continue;
            }
            let args = fwd_args(leads);
            let refs: Vec<Arg> = args.iter().map(Arg::Host).collect();
            rt.warmup(&[name]).unwrap();
            harness::bench(&format!("{config}/{name}"), 3, 30, || {
                rt.execute(name, &refs).expect("exec");
            });
        }

        // MeBP's backward = residual fwd + residual bwd chained
        if rt.has_artifact("block_bwd_residuals") {
            let args = fwd_args(vec![&x]);
            let refs: Vec<Arg> = args.iter().map(Arg::Host).collect();
            rt.warmup(&["block_fwd_residuals", "block_bwd_residuals"])
                .unwrap();
            harness::bench(
                &format!("{config}/mebp_two_phase_bwd"), 3, 30, || {
                    let mut outs =
                        rt.execute("block_fwd_residuals", &refs).unwrap();
                    let residuals: Vec<HostTensor> = outs.drain(1..).collect();
                    let mut bwd_owned: Vec<HostTensor> = vec![gy.clone()];
                    bwd_owned.extend(residuals);
                    for t in frozen.block_tensors(0) {
                        bwd_owned.push(t.clone());
                    }
                    for t in &adapters.lora[0].tensors {
                        bwd_owned.push(t.clone());
                    }
                    let bwd_args: Vec<Arg> =
                        bwd_owned.iter().map(Arg::Host).collect();
                    rt.execute("block_bwd_residuals", &bwd_args).unwrap();
                });
        }
        println!();
    }
}
