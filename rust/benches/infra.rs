//! Bench: L3 infrastructure hot paths — memory tracker, checkpoint store,
//! tokenizer, corpus generation, JSON parsing. None of these may become a
//! bottleneck relative to artifact execution (DESIGN.md §9: L3 overhead
//! < 10% of step time).

#[path = "harness.rs"]
mod harness;

use mesp::data::tokenizer::{for_vocab, Tokenizer};
use mesp::data::{BatchSource, CorpusGen};
use mesp::memory::MemoryTracker;
use mesp::tensor::HostTensor;
use mesp::train::CheckpointStore;
use mesp::util::{Json, Rng};

fn main() {
    println!("== L3 infrastructure micro-benchmarks ==");

    let tracker = MemoryTracker::new();
    harness::bench("tracker/track+drop x1000", 3, 50, || {
        for _ in 0..1000 {
            let _g = tracker.track("bench", 4096);
        }
    });

    let tr2 = MemoryTracker::new();
    harness::bench("checkpoint_store/8-layer cycle", 3, 50, || {
        let mut s = CheckpointStore::new(tr2.clone(), 0);
        for l in 0..8 {
            s.store(l, HostTensor::f32(&[4096], vec![0.5; 4096])).unwrap();
        }
        for l in (0..8).rev() {
            let _ = s.take(l).unwrap();
        }
    });

    let mut gen = CorpusGen::new(1, 1000);
    let text = gen.generate(20_000);
    println!("(corpus: {} chars)", text.len());
    harness::bench("corpus/generate 20k words", 1, 10, || {
        let mut g = CorpusGen::new(2, 1000);
        let _ = g.generate(20_000);
    });

    let tok = for_vocab(16384);
    harness::bench("tokenizer/hash-word 20k words", 2, 20, || {
        let _ = tok.encode(&text);
    });
    let btok = for_vocab(256);
    harness::bench("tokenizer/byte 100k chars", 2, 20, || {
        let _ = btok.encode(&text[..100_000.min(text.len())]);
    });

    harness::bench("batch_source/seq128 x32", 2, 20, || {
        let mut src = BatchSource::new(16384, 1, 128, 3);
        for _ in 0..32 {
            let _ = src.next_batch();
        }
    });

    // Parse a manifest.json if artifacts exist (pjrt builds); otherwise
    // synthesize a comparable JSON document so the bench runs everywhere.
    let manifest = std::fs::read_to_string("artifacts/toy/manifest.json")
        .unwrap_or_else(|_| synthetic_manifest());
    harness::bench("json/parse manifest", 3, 100, || {
        let _ = Json::parse(&manifest).unwrap();
    });

    let mut rng = Rng::new(1);
    harness::bench("rng/normal_vec 1M", 1, 10, || {
        let _ = rng.normal_vec(1_000_000, 1.0);
    });
}

/// A manifest-shaped JSON document of realistic size (≈ the toy config's
/// 10 artifacts × 25 arg specs) for the parse bench.
fn synthetic_manifest() -> String {
    let mut s = String::from(
        r#"{"config":{"name":"toy","vocab":256,"d_model":64,"n_layers":2,
"n_heads":4,"n_kv_heads":2,"head_dim":16,"d_ff":128,"seq":32,"batch":1,
"rank":4,"alpha":8.0,"scale":2.0,"param_count":368000,
"lora_param_count":9216},"artifacts":{"#,
    );
    for a in 0..10 {
        if a > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            r#""artifact_{a}":{{"file":"artifact_{a}.hlo.txt","args":["#
        ));
        for i in 0..25 {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                r#"{{"name":"arg_{i}","shape":[1,32,64],"dtype":"f32"}}"#
            ));
        }
        s.push_str(r#"],"outputs":15}"#);
    }
    s.push_str("}}");
    s
}
