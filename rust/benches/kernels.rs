//! Bench: the kernel engine's GEMM variants (naive oracle vs tiled vs
//! parallel) over the exact GEMM shapes a preset's training step issues —
//! the seven LoRA projection GEMMs plus the tied-lm-head GEMMs — and the
//! q4 fused-dequant variants over the same frozen-projection shapes
//! (naive-q4 host-dequantizes per call; tiled/parallel-q4 dequantize
//! panels inside packing).
//!
//! Emits machine-readable sections into `BENCH_kernels.json` at the repo
//! root so the perf trajectory is recorded PR-over-PR, and supports
//! `--check` (used by the CI bench-smoke job) which exits nonzero if the
//! tiled kernel fails to beat the naive oracle — f32 AND q4 — on the
//! selected preset.
//!
//! Usage: cargo bench --bench kernels -- [--preset toy|small] [--check]

#[path = "harness.rs"]
mod harness;

use mesp::config::{presets, KernelKind, ModelDims, PROJS};
use mesp::memory::MemoryTracker;
use mesp::model::quant;
use mesp::runtime::kernels::{simd, Q4View};
use mesp::runtime::{KernelOptions, Kernels};
use mesp::util::{Json, Rng};

/// One GEMM shape of the step: out = [m, n] with depth k.
struct Shape {
    m: usize,
    k: usize,
    n: usize,
}

/// The projection + loss-head GEMM inventory of one preset.
fn shapes(d: &ModelDims) -> Vec<Shape> {
    let m = d.m();
    let mut v: Vec<Shape> = PROJS
        .iter()
        .map(|p| {
            let (din, dout) = d.proj_dims(p);
            Shape { m, k: din, n: dout }
        })
        .collect();
    // tied lm head: logits [m, vocab] and its backward [m, d_model]
    v.push(Shape { m, k: d.d_model, n: d.vocab });
    v.push(Shape { m, k: d.vocab, n: d.d_model });
    v
}

/// The result for one kernel kind, looked up by kind (NOT by position,
/// so reordering `KernelKind::ALL` can never mislabel a column).
fn by_kind<'a>(
    results: &'a [(KernelKind, harness::BenchResult)],
    kind: KernelKind,
) -> &'a harness::BenchResult {
    &results.iter().find(|(k, _)| *k == kind).unwrap().1
}

/// Run the full GEMM set once on `ks` (matmul + both transposed forms on
/// the first shape, so every packing path is exercised).
fn run_set(ks: &Kernels, shapes: &[Shape], data: &[(Vec<f32>, Vec<f32>)]) {
    for (s, (a, b)) in shapes.iter().zip(data) {
        std::hint::black_box(&ks.matmul(a, b, s.m, s.k, s.n)[..]);
    }
    let (s, (a, b)) = (&shapes[0], &data[0]);
    // a reinterpreted as [k, m] for aᵀ@b; b reinterpreted as [n, k] for a@bᵀ
    std::hint::black_box(&ks.matmul_at(a, b, s.k, s.m, s.n)[..]);
    std::hint::black_box(&ks.matmul_bt(a, b, s.m, s.k, s.n)[..]);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut preset = "toy".to_string();
    let mut check = false;
    let mut it = argv.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--preset" => {
                preset = it.next().cloned().unwrap_or_else(|| "toy".into());
            }
            "--check" => check = true,
            "--bench" => {} // appended by `cargo bench`
            other => eprintln!("ignoring unknown arg '{other}'"),
        }
    }
    // Committed baseline, read before any section is rewritten: the
    // --check gate compares against what the repo has, not this run.
    let committed = harness::read_bench_json();
    let dims = presets::compiled(&preset).expect("preset");
    let shapes = shapes(&dims);
    let mut rng = Rng::new(7);
    let data: Vec<(Vec<f32>, Vec<f32>)> = shapes
        .iter()
        .map(|s| (rng.normal_vec(s.m * s.k, 0.5), rng.normal_vec(s.k * s.n, 0.5)))
        .collect();
    let madds: usize = shapes.iter().map(|s| s.m * s.k * s.n).sum::<usize>()
        + 2 * shapes[0].m * shapes[0].k * shapes[0].n;

    println!(
        "== kernel microbench: preset {preset}, {} GEMMs, {:.1} MFLOP/set ==",
        shapes.len() + 2,
        2.0 * madds as f64 / 1e6
    );
    let iters = if preset == "toy" { 60 } else { 30 };
    let mut results = Vec::new();
    for kind in KernelKind::ALL {
        let ks = Kernels::new(
            KernelOptions { kind, threads: 0 },
            MemoryTracker::new(),
        );
        let label = format!("{preset}/gemm-set/{}", kind.name());
        let r = harness::bench(&label, 3, iters, || run_set(&ks, &shapes, &data));
        results.push((kind, r));
    }
    let naive = by_kind(&results, KernelKind::Naive);
    let tiled = by_kind(&results, KernelKind::Tiled);
    let parallel = by_kind(&results, KernelKind::Parallel);
    harness::ratio("tiled    vs naive", naive, tiled);
    harness::ratio("parallel vs naive", naive, parallel);
    let speedup_tiled = naive.mean_ms / tiled.mean_ms;
    let speedup_parallel = naive.mean_ms / parallel.mean_ms;
    let set_gflop = 2.0 * madds as f64 / 1e9;
    let tiled_gflops = set_gflop / (tiled.mean_ms / 1e3);
    let parallel_gflops = set_gflop / (parallel.mean_ms / 1e3);
    println!(
        "speedup over naive: tiled {speedup_tiled:.2}x, parallel \
         {speedup_parallel:.2}x ({} threads); achieved tiled \
         {tiled_gflops:.2} / parallel {parallel_gflops:.2} GFLOP/s",
        mesp::runtime::kernels::auto_threads()
    );

    harness::write_bench_json(
        &format!("kernels_microbench_{preset}"),
        vec![
            ("naive_ms".to_string(), Json::num(naive.mean_ms)),
            ("tiled_ms".to_string(), Json::num(tiled.mean_ms)),
            ("parallel_ms".to_string(), Json::num(parallel.mean_ms)),
            ("speedup_tiled".to_string(), Json::num(speedup_tiled)),
            ("speedup_parallel".to_string(), Json::num(speedup_parallel)),
            (
                "threads".to_string(),
                Json::num(mesp::runtime::kernels::auto_threads() as u32),
            ),
            ("gflop_per_set".to_string(), Json::num(set_gflop)),
            ("tiled_gflops".to_string(), Json::num(tiled_gflops)),
            ("parallel_gflops".to_string(), Json::num(parallel_gflops)),
        ],
    );

    // ---- q4 fused-dequant GEMMs over the frozen-projection shapes ----
    // (the lm-head GEMMs stay f32 in training, so only the 7 projections)
    let q4_shapes = &shapes[..PROJS.len()];
    let q4_data: Vec<(Vec<f32>, Vec<u8>, Vec<f32>)> = q4_shapes
        .iter()
        .map(|s| {
            let x = rng.normal_vec(s.m * s.k, 0.5);
            let w = rng.normal_vec(s.k * s.n, 0.02);
            let (packed, scales) = quant::quantize(&w, s.k, s.n);
            (x, packed, scales)
        })
        .collect();
    let q4_madds: usize = q4_shapes.iter().map(|s| s.m * s.k * s.n).sum::<usize>() * 2;
    println!(
        "\n== q4 kernel microbench: preset {preset}, {} fused-dequant GEMMs \
         (fwd + bwd form), {:.1} MFLOP/set ==",
        2 * q4_shapes.len(),
        2.0 * q4_madds as f64 / 1e6
    );
    let mut q4_results = Vec::new();
    // g operands for the backward form, one per shape: [m, n]
    let q4_g: Vec<Vec<f32>> = {
        let mut r2 = Rng::new(17);
        q4_shapes.iter().map(|s| r2.normal_vec(s.m * s.n, 0.5)).collect()
    };
    for kind in KernelKind::ALL {
        let ks = Kernels::new(
            KernelOptions { kind, threads: 0 },
            MemoryTracker::new(),
        );
        let label = format!("{preset}/q4-gemm-set/{}", kind.name());
        let r = harness::bench(&label, 3, iters, || {
            for ((s, (x, packed, scales)), g) in
                q4_shapes.iter().zip(&q4_data).zip(&q4_g)
            {
                let w = Q4View::new(packed, scales, s.k, s.n);
                std::hint::black_box(&ks.matmul_q4(x, w, s.m)[..]);
                std::hint::black_box(&ks.matmul_bt_q4(g, w, s.m)[..]);
            }
        });
        q4_results.push((kind, r));
    }
    let naive_q4 = by_kind(&q4_results, KernelKind::Naive);
    let tiled_q4 = by_kind(&q4_results, KernelKind::Tiled);
    let parallel_q4 = by_kind(&q4_results, KernelKind::Parallel);
    harness::ratio("tiled-q4    vs naive-q4", naive_q4, tiled_q4);
    harness::ratio("parallel-q4 vs naive-q4", naive_q4, parallel_q4);
    let speedup_tiled_q4 = naive_q4.mean_ms / tiled_q4.mean_ms;
    let speedup_parallel_q4 = naive_q4.mean_ms / parallel_q4.mean_ms;
    let q4_set_gflop = 2.0 * q4_madds as f64 / 1e9;
    let tiled_q4_gflops = q4_set_gflop / (tiled_q4.mean_ms / 1e3);
    let parallel_q4_gflops = q4_set_gflop / (parallel_q4.mean_ms / 1e3);
    println!(
        "q4 speedup over naive-q4 (host dequant): tiled {speedup_tiled_q4:.2}x, \
         parallel {speedup_parallel_q4:.2}x; achieved tiled \
         {tiled_q4_gflops:.2} / parallel {parallel_q4_gflops:.2} GFLOP/s"
    );

    harness::write_bench_json(
        &format!("kernels_microbench_q4_{preset}"),
        vec![
            ("naive_q4_ms".to_string(), Json::num(naive_q4.mean_ms)),
            ("tiled_q4_ms".to_string(), Json::num(tiled_q4.mean_ms)),
            ("parallel_q4_ms".to_string(), Json::num(parallel_q4.mean_ms)),
            ("speedup_tiled_q4".to_string(), Json::num(speedup_tiled_q4)),
            (
                "speedup_parallel_q4".to_string(),
                Json::num(speedup_parallel_q4),
            ),
            ("gflop_per_set".to_string(), Json::num(q4_set_gflop)),
            ("tiled_q4_gflops".to_string(), Json::num(tiled_q4_gflops)),
            (
                "parallel_q4_gflops".to_string(),
                Json::num(parallel_q4_gflops),
            ),
        ],
    );

    // ---- scalar vs SIMD micro-kernel, same tiled blocking ----
    // Forced-ISA engines isolate the micro-kernel win from blocking and
    // threading: both runs use identical tiles and k-order (so their
    // outputs are bitwise equal — pinned by tests/simd.rs), only the
    // inner mr×nr kernel and the q4 pack dequant change.
    let best_isa = simd::detect();
    let forced = |isa| {
        Kernels::new(
            KernelOptions { kind: KernelKind::Tiled, threads: 1 },
            MemoryTracker::new(),
        )
        .with_isa(isa)
    };
    println!(
        "\n== simd microbench: preset {preset}, scalar vs {} micro-kernel ==",
        best_isa.name()
    );
    let bench_isa = |isa: simd::Isa| {
        let ks = forced(isa);
        let f32_r = harness::bench(
            &format!("{preset}/simd/{}", isa.name()),
            3,
            iters,
            || run_set(&ks, &shapes, &data),
        );
        let q4_r = harness::bench(
            &format!("{preset}/simd-q4/{}", isa.name()),
            3,
            iters,
            || {
                for ((s, (x, packed, scales)), g) in
                    q4_shapes.iter().zip(&q4_data).zip(&q4_g)
                {
                    let w = Q4View::new(packed, scales, s.k, s.n);
                    std::hint::black_box(&ks.matmul_q4(x, w, s.m)[..]);
                    std::hint::black_box(&ks.matmul_bt_q4(g, w, s.m)[..]);
                }
            },
        );
        (set_gflop / (f32_r.mean_ms / 1e3), q4_set_gflop / (q4_r.mean_ms / 1e3))
    };
    let (scalar_gflops, scalar_q4_gflops) = bench_isa(simd::Isa::Scalar);
    let (simd_gflops, simd_q4_gflops) = if best_isa == simd::Isa::Scalar {
        (scalar_gflops, scalar_q4_gflops)
    } else {
        bench_isa(best_isa)
    };
    let simd_speedup = simd_gflops / scalar_gflops;
    let simd_q4_speedup = simd_q4_gflops / scalar_q4_gflops;
    println!(
        "simd ({}) over scalar, same blocking: f32 {simd_speedup:.2}x \
         ({scalar_gflops:.2} -> {simd_gflops:.2} GFLOP/s), q4 \
         {simd_q4_speedup:.2}x ({scalar_q4_gflops:.2} -> \
         {simd_q4_gflops:.2} GFLOP/s)",
        best_isa.name()
    );
    harness::write_bench_json(
        &format!("kernels_simd_{preset}"),
        vec![
            ("isa".to_string(), Json::str(best_isa.name())),
            ("scalar_gflops".to_string(), Json::num(scalar_gflops)),
            ("simd_gflops".to_string(), Json::num(simd_gflops)),
            ("simd_speedup".to_string(), Json::num(simd_speedup)),
            ("scalar_q4_gflops".to_string(), Json::num(scalar_q4_gflops)),
            ("simd_q4_gflops".to_string(), Json::num(simd_q4_gflops)),
            ("simd_q4_speedup".to_string(), Json::num(simd_q4_speedup)),
        ],
    );

    if check {
        // CI gate, two tiers. Primary: REGRESSION gate against the
        // committed BENCH_kernels.json — the tiled kernel's achieved
        // GFLOP/s must stay within TOLERANCE of the committed baseline.
        // The committed numbers are themselves conservative floors
        // (roughly a third of a dev-box measurement), so 0.8x of them
        // still catches a lost-SIMD-path or broken-blocking regression
        // without flaking on slower CI machines. Fallback when the
        // committed record has no baseline for this preset: the original
        // oracle check, tiled must beat naive (and fused panel dequant
        // must beat full host dequant).
        const TOLERANCE: f64 = 0.8;
        let mut ok = true;
        let gates = [
            (
                "tiled f32",
                format!("kernels_microbench_{preset}"),
                "tiled_gflops",
                tiled_gflops,
                speedup_tiled,
            ),
            (
                "tiled q4",
                format!("kernels_microbench_q4_{preset}"),
                "tiled_q4_gflops",
                tiled_q4_gflops,
                speedup_tiled_q4,
            ),
        ];
        for (label, section, key, measured, speedup_vs_naive) in &gates {
            match harness::baseline_f64(&committed, section, key) {
                Some(base) => {
                    let floor = TOLERANCE * base;
                    if *measured < floor {
                        eprintln!(
                            "CHECK FAILED: {label} {measured:.2} GFLOP/s \
                             below {floor:.2} (= {TOLERANCE} x committed \
                             baseline {base:.2} in {section}.{key})"
                        );
                        ok = false;
                    } else {
                        println!(
                            "check: {label} {measured:.2} GFLOP/s >= \
                             {floor:.2} floor (committed {base:.2}, \
                             tolerance {TOLERANCE})"
                        );
                    }
                }
                None => {
                    if *speedup_vs_naive < 1.0 {
                        eprintln!(
                            "CHECK FAILED: no committed {section}.{key} \
                             baseline and {label} is slower than its naive \
                             oracle ({speedup_vs_naive:.2}x)"
                        );
                        ok = false;
                    } else {
                        println!(
                            "check: no committed {section}.{key} baseline — \
                             fell back to the oracle gate, {label} beats \
                             naive ({speedup_vs_naive:.2}x)"
                        );
                    }
                }
            }
        }
        // The tentpole's own gate: with AVX2 available, the vectorized
        // micro-kernel must hold at least 2x over the scalar one at the
        // same blocking (the dev-box measurement is >6x, so this has
        // wide margin). Other ISAs vary too much across CI hardware to
        // gate hard; their speedups are still recorded in the JSON.
        if best_isa == simd::Isa::Avx2 {
            if simd_speedup < 2.0 {
                eprintln!(
                    "CHECK FAILED: avx2 micro-kernel only {simd_speedup:.2}x \
                     over scalar (need >= 2.0x)"
                );
                ok = false;
            } else {
                println!(
                    "check: avx2 micro-kernel {simd_speedup:.2}x over scalar \
                     (>= 2.0x)"
                );
            }
        }
        if !ok {
            std::process::exit(1);
        }
        println!(
            "check passed: tiled {tiled_gflops:.2} GFLOP/s f32, \
             {tiled_q4_gflops:.2} GFLOP/s q4, simd {simd_speedup:.2}x \
             over scalar ({})",
            best_isa.name()
        );
    }
}
