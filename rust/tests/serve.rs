//! Integration tests for `mesp serve`: the daemon lifecycle over a real
//! Unix socket, the JSONL protocol's error surface, and the crash-
//! recovery contract (SIGKILL mid-run, restart, bitwise-identical final
//! adapter).

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use mesp::config::TrainConfig;
use mesp::fleet::loadgen::Client;
use mesp::fleet::protocol::{self, code};
use mesp::fleet::{job_cost_bytes, job_weight_class, JobSpec, ServeOptions, Server};
use mesp::util::{Json, Rng};

/// A unique scratch dir per test (tests run in parallel).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mesp-serve-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Connect to a daemon socket, retrying while it comes up.
fn connect(socket: &Path) -> Client {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match Client::connect(socket) {
            Ok(c) => return c,
            Err(e) => {
                assert!(
                    Instant::now() < deadline,
                    "daemon never came up on {}: {e:#}",
                    socket.display()
                );
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn submit_sim(client: &mut Client, tenant: &str, steps: usize, sim_us: u64) -> u64 {
    let mut fields = vec![
        ("spec", Json::obj(vec![("steps", Json::num(steps as f64))])),
        ("tenant", Json::str(tenant)),
        ("sim", Json::Bool(true)),
    ];
    if sim_us > 0 {
        fields.push(("sim_us", Json::num(sim_us as f64)));
    }
    let r = client.call("submit", fields).unwrap();
    assert!(r.ok, "submit rejected: {:?}", r.error);
    r.data.get("job").and_then(|v| v.as_f64()).unwrap() as u64
}

fn in_process_server(dir: &Path, opts_mut: impl FnOnce(&mut ServeOptions)) -> Server {
    let mut opts = ServeOptions {
        socket: dir.join("d.sock"),
        snapshot_dir: dir.join("state"),
        budget_bytes: 256 << 20,
        workers: 2,
        ..ServeOptions::default()
    };
    opts_mut(&mut opts);
    Server::start(opts, TrainConfig::default()).unwrap()
}

// ---------------------------------------------------------------------
// In-process daemon lifecycle.
// ---------------------------------------------------------------------

#[test]
fn daemon_smoke_submit_status_drain() {
    let dir = scratch("smoke");
    let server = in_process_server(&dir, |_| {});
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);

    for i in 0..6u64 {
        let tenant = if i % 2 == 0 { "alice" } else { "bob" };
        let id = submit_sim(&mut client, tenant, 3, 0);
        assert_eq!(id, i, "ids are sequential from 0");
    }

    // set-budget round-trips (budget unchanged, ceiling preserved).
    let r = client
        .call(
            "set-budget",
            vec![("budget_bytes", Json::num((256u64 << 20) as f64))],
        )
        .unwrap();
    assert!(r.ok, "set-budget rejected: {:?}", r.error);

    // Aggregate status carries both tenants.
    let r = client.call("status", vec![]).unwrap();
    assert!(r.ok);
    let tenants = r.data.get("tenants").unwrap();
    assert!(tenants.get("alice").is_some() && tenants.get("bob").is_some());

    // Per-job status: poll until job 0 is done (sim jobs are fast).
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let r = client
            .call("status", vec![("job", Json::num(0.0))])
            .unwrap();
        assert!(r.ok);
        let state = r.data.get("state").and_then(|v| v.as_str()).unwrap().to_string();
        if state == "done" {
            assert!(
                r.data.get("latency_s").and_then(|v| v.as_f64()).is_some(),
                "done jobs report latency"
            );
            break;
        }
        assert!(Instant::now() < deadline, "job 0 stuck in state {state}");
        std::thread::sleep(Duration::from_millis(5));
    }

    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.submitted, 6);
    assert_eq!(summary.done, 6);
    assert_eq!(summary.failed, 0);
    assert_eq!(summary.pending, 0);
    assert!(!socket.exists(), "socket removed on clean exit");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cancel_queued_and_running_jobs() {
    let dir = scratch("cancel");
    let server = in_process_server(&dir, |o| o.workers = 1);
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);

    // Job 0 runs ~1s (200 virtual steps x 5ms) — plenty of margin for
    // the cancel to land mid-run; job 1 queues behind it on the single
    // worker.
    let slow = submit_sim(&mut client, "t", 200, 5000);
    let queued = submit_sim(&mut client, "t", 200, 5000);
    let r = client
        .call("cancel", vec![("job", Json::num(queued as f64))])
        .unwrap();
    assert!(r.ok, "cancel rejected: {:?}", r.error);
    let r = client
        .call("cancel", vec![("job", Json::num(slow as f64))])
        .unwrap();
    assert!(r.ok, "cancel rejected: {:?}", r.error);
    // Idempotent: cancelling again reports the terminal state instead of
    // erroring (the job may need a step boundary to settle first).
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let r = client
            .call("cancel", vec![("job", Json::num(slow as f64))])
            .unwrap();
        assert!(r.ok);
        if r.data.get("state").and_then(|v| v.as_str()) == Some("cancelled") {
            break;
        }
        assert!(Instant::now() < deadline, "job never settled cancelled");
        std::thread::sleep(Duration::from_millis(5));
    }

    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.cancelled, 2);
    assert_eq!(summary.done, 0);
    assert_eq!(summary.failed, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn submit_refusals_are_diagnosed_at_submit_time() {
    let spec = JobSpec::from_base(&TrainConfig::default());
    let cost = job_cost_bytes(&spec).unwrap();
    let solo = cost + job_weight_class(&spec).unwrap().bytes;

    // Daemon 1: a ceiling below any toy job's solo footprint.
    let dir = scratch("refuse-budget");
    let server = in_process_server(&dir, |o| o.budget_bytes = (solo / 2).max(1));
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);
    let r = client
        .call(
            "submit",
            vec![("spec", Json::obj(vec![])), ("sim", Json::Bool(true))],
        )
        .unwrap();
    assert!(!r.ok, "a can-never-fit job must be refused at submit");
    assert_eq!(r.error.as_ref().unwrap().0, code::OVER_BUDGET);
    let r = client.call("shutdown", vec![]).unwrap();
    assert!(r.ok);
    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.submitted, 0, "refused jobs never enter the table");
    let _ = std::fs::remove_dir_all(&dir);

    // Daemon 2: a roomy budget but one tenant's quota below the job cost.
    let dir = scratch("refuse-quota");
    let server = in_process_server(&dir, |o| {
        o.quotas = vec![("capped".to_string(), (cost / 2).max(1))];
    });
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);
    let r = client
        .call(
            "submit",
            vec![
                ("spec", Json::obj(vec![])),
                ("tenant", Json::str("capped")),
                ("sim", Json::Bool(true)),
            ],
        )
        .unwrap();
    assert!(!r.ok, "a job over its tenant quota must be refused at submit");
    assert_eq!(r.error.as_ref().unwrap().0, code::QUOTA_EXCEEDED);
    // Another tenant with no quota sails through the same daemon.
    let id = submit_sim(&mut client, "free", 2, 0);
    assert_eq!(id, 0);
    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.submitted, 1);
    assert_eq!(summary.done, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn draining_daemon_refuses_new_work() {
    let dir = scratch("drainref");
    let server = in_process_server(&dir, |_| {});
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);
    let mut other = connect(&socket);

    // Keep one job in flight (~500ms) so the daemon is still up when the
    // post-drain submit arrives.
    let _slow = submit_sim(&mut client, "t", 100, 5000);
    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    assert!(matches!(r.data.get("draining"), Some(Json::Bool(true))));
    // New submits — on any connection — bounce with the draining code.
    let r = other
        .call(
            "submit",
            vec![("spec", Json::obj(vec![])), ("sim", Json::Bool(true))],
        )
        .unwrap();
    assert!(!r.ok);
    assert_eq!(r.error.as_ref().unwrap().0, code::DRAINING);

    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.done, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Protocol error surface over the real socket.
// ---------------------------------------------------------------------

#[test]
fn protocol_errors_over_the_socket() {
    let dir = scratch("proto");
    let server = in_process_server(&dir, |_| {});
    let socket = server.socket().to_path_buf();
    let handle = std::thread::spawn(move || server.run());
    let mut client = connect(&socket);

    // Garbage: answered (id null), connection stays usable.
    let resp = client.call_raw("this is not json").unwrap();
    let r = protocol::parse_response(&resp).unwrap();
    assert!(!r.ok);
    assert_eq!(r.id, None);
    assert_eq!(r.error.as_ref().unwrap().0, code::BAD_JSON);

    // Version skew: named code, id recovered for correlation.
    let resp = client
        .call_raw(r#"{"v":2,"id":7,"verb":"status"}"#)
        .unwrap();
    let r = protocol::parse_response(&resp).unwrap();
    assert!(!r.ok);
    assert_eq!(r.id, Some(7));
    assert_eq!(r.error.as_ref().unwrap().0, code::BAD_VERSION);

    // Unknown verb.
    let resp = client
        .call_raw(r#"{"v":1,"id":8,"verb":"frobnicate"}"#)
        .unwrap();
    let r = protocol::parse_response(&resp).unwrap();
    assert_eq!(r.error.as_ref().unwrap().0, code::UNKNOWN_VERB);

    // Missing verb.
    let resp = client.call_raw(r#"{"v":1,"id":9}"#).unwrap();
    let r = protocol::parse_response(&resp).unwrap();
    assert_eq!(r.error.as_ref().unwrap().0, code::MISSING_FIELD);

    // Unknown job id.
    let r = client
        .call("status", vec![("job", Json::num(99999.0))])
        .unwrap();
    assert!(!r.ok);
    assert_eq!(r.error.as_ref().unwrap().0, code::UNKNOWN_JOB);

    // Bad spec: unknown key inside the spec object.
    let r = client
        .call(
            "submit",
            vec![
                ("spec", Json::obj(vec![("flux", Json::num(1.0))])),
                ("sim", Json::Bool(true)),
            ],
        )
        .unwrap();
    assert!(!r.ok);
    assert_eq!(r.error.as_ref().unwrap().0, code::BAD_SPEC);

    // Oversized frame: answered with the named code, then the (desynced)
    // connection is closed.
    let huge = format!(
        r#"{{"v":1,"id":10,"verb":"status","pad":"{}"}}"#,
        "A".repeat(protocol::MAX_FRAME_BYTES + 100)
    );
    let resp = client.call_raw(&huge).unwrap();
    let r = protocol::parse_response(&resp).unwrap();
    assert!(!r.ok);
    assert_eq!(r.error.as_ref().unwrap().0, code::OVERSIZED_FRAME);
    assert!(
        client.call_raw(r#"{"v":1,"id":11,"verb":"status"}"#).is_err(),
        "connection is closed after an oversized frame"
    );

    // A fresh connection still works — the daemon is unharmed.
    let mut fresh = connect(&socket);
    let r = fresh.call("status", vec![]).unwrap();
    assert!(r.ok);
    let r = fresh.call("shutdown", vec![]).unwrap();
    assert!(r.ok);
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Protocol property tests: no input may panic the parser.
// ---------------------------------------------------------------------

/// Run `cases` random cases of a property, reporting the failing seed
/// (same in-house pattern as tests/proptests.rs — no proptest crate in
/// the offline build).
fn forall(seed0: u64, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for c in 0..cases {
        let mut rng = Rng::new(seed0 ^ c.wrapping_mul(0x9e3779b97f4a7c15));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = r {
            panic!("property failed at case {c} (seed0 {seed0}): {e:?}");
        }
    }
}

fn valid_frames() -> Vec<String> {
    vec![
        r#"{"v":1,"id":0,"verb":"status"}"#.to_string(),
        r#"{"v":1,"id":1,"verb":"status","job":3}"#.to_string(),
        r#"{"v":1,"id":2,"verb":"cancel","job":0}"#.to_string(),
        r#"{"v":1,"id":3,"verb":"drain"}"#.to_string(),
        r#"{"v":1,"id":4,"verb":"shutdown"}"#.to_string(),
        r#"{"v":1,"id":5,"verb":"set-budget","budget_bytes":1048576}"#
            .to_string(),
        concat!(
            r#"{"v":1,"id":6,"verb":"submit","tenant":"alice","sim":true,"#,
            r#""sim_us":50,"spec":{"steps":4,"priority":2,"method":"mesp"}}"#
        )
        .to_string(),
    ]
}

#[test]
fn prop_truncated_frames_never_panic_and_never_parse() {
    forall(0xC0FFEE, 300, |rng| {
        let frames = valid_frames();
        let f = &frames[rng.below(frames.len())];
        let cut = rng.below(f.len()); // strictly shorter than the frame
        let truncated = String::from_utf8_lossy(&f.as_bytes()[..cut]);
        let r = protocol::parse_request(&truncated);
        // Truncating valid JSON cannot yield a different valid frame:
        // every prefix is rejected, with a named code, never a panic.
        assert!(r.is_err(), "prefix of len {cut} parsed: {truncated}");
    });
}

#[test]
fn prop_mutated_frames_never_panic() {
    forall(0xBADF00D, 300, |rng| {
        let frames = valid_frames();
        let mut bytes = frames[rng.below(frames.len())].clone().into_bytes();
        for _ in 0..1 + rng.below(6) {
            let i = rng.below(bytes.len());
            match rng.below(3) {
                0 => bytes[i] = (rng.next_u64() & 0xFF) as u8,
                1 => {
                    bytes.insert(i, (rng.next_u64() & 0x7F) as u8);
                }
                _ => {
                    bytes.remove(i);
                }
            }
            if bytes.is_empty() {
                bytes.push(b'{');
            }
        }
        let line = String::from_utf8_lossy(&bytes);
        // Must not panic; Ok is allowed (a mutation can be harmless).
        let _ = protocol::parse_request(&line);
    });
}

#[test]
fn prop_version_skew_is_always_named() {
    forall(0x5EED, 200, |rng| {
        let v = rng.below(1000) as u64;
        if v == protocol::PROTOCOL_VERSION {
            return;
        }
        let line = format!(r#"{{"v":{v},"id":1,"verb":"status"}}"#);
        let e = protocol::parse_request(&line).unwrap_err();
        assert_eq!(e.code, code::BAD_VERSION);
    });
}

// ---------------------------------------------------------------------
// The spawned binary: exit codes and SIGKILL crash recovery.
// ---------------------------------------------------------------------

fn mesp() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_mesp"));
    c.stdout(Stdio::null()).stderr(Stdio::null());
    c
}

fn spawn_serve(dir: &Path, socket: &Path) -> Child {
    mesp()
        .current_dir(dir) // keep artifacts/ out of the repo tree
        .args([
            "serve",
            "--config",
            "toy",
            "--budget-mb",
            "256",
            "--workers",
            "1",
            "--checkpoint-every",
            "1",
        ])
        .arg("--snapshot-dir")
        .arg(dir)
        .arg("--socket")
        .arg(socket)
        .spawn()
        .unwrap()
}

/// Submit one REAL toy job (no pinned seed: the daemon derives it from
/// the job id, identically on every daemon life).
fn submit_real(client: &mut Client, steps: usize) -> u64 {
    let r = client
        .call(
            "submit",
            vec![("spec", Json::obj(vec![("steps", Json::num(steps as f64))]))],
        )
        .unwrap();
    assert!(r.ok, "submit rejected: {:?}", r.error);
    r.data.get("job").and_then(|v| v.as_f64()).unwrap() as u64
}

fn wait_exit(mut child: Child, what: &str) -> i32 {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status.code().unwrap_or(-1);
        }
        assert!(Instant::now() < deadline, "{what} never exited");
        std::thread::sleep(Duration::from_millis(10));
    }
}

const RECOVERY_STEPS: usize = 40;

#[test]
fn sigkill_recovery_resumes_bitwise() {
    // Control: an uninterrupted daemon runs job 0 to completion.
    let c_dir = scratch("ctl");
    let c_sock = c_dir.join("d.sock");
    let control = spawn_serve(&c_dir, &c_sock);
    let mut client = connect(&c_sock);
    assert_eq!(submit_real(&mut client, RECOVERY_STEPS), 0);
    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    drop(client);
    assert_eq!(wait_exit(control, "control daemon"), 0, "clean drain exits 0");
    let control_final = std::fs::read(c_dir.join("job-0-final.snap")).unwrap();

    // Crash run: SIGKILL the daemon once the first checkpoint lands.
    let k_dir = scratch("kill");
    let k_sock = k_dir.join("d.sock");
    let mut victim = spawn_serve(&k_dir, &k_sock);
    let mut client = connect(&k_sock);
    assert_eq!(submit_real(&mut client, RECOVERY_STEPS), 0);
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let has_snap = std::fs::read_dir(&k_dir).unwrap().flatten().any(|e| {
            let n = e.file_name();
            let n = n.to_string_lossy().into_owned();
            n.starts_with("job-0-step-") && n.ends_with(".snap")
        });
        if has_snap {
            break;
        }
        assert!(Instant::now() < deadline, "no checkpoint ever appeared");
        std::thread::sleep(Duration::from_millis(1));
    }
    victim.kill().unwrap(); // SIGKILL on unix: no cleanup of any kind
    victim.wait().unwrap();
    drop(client);
    assert!(
        k_dir.join("job-0.json").exists(),
        "the sidecar journal survives the kill"
    );

    // Restart on the same snapshot dir: the job is re-admitted from its
    // newest checkpoint and runs to the SAME final bits.
    let revived = spawn_serve(&k_dir, &k_sock);
    let mut client = connect(&k_sock);
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let r = client
            .call("status", vec![("job", Json::num(0.0))])
            .unwrap();
        assert!(r.ok, "recovered daemon must know job 0: {:?}", r.error);
        assert!(
            matches!(r.data.get("recovered"), Some(Json::Bool(true))),
            "job 0 must be marked recovered"
        );
        let state = r.data.get("state").and_then(|v| v.as_str()).unwrap();
        if state == "done" {
            let resumes =
                r.data.get("resumes").and_then(|v| v.as_f64()).unwrap() as u64;
            assert!(resumes >= 1, "job must have resumed from its snapshot");
            break;
        }
        assert!(
            state != "failed" && state != "cancelled",
            "recovered job ended {state}"
        );
        assert!(Instant::now() < deadline, "recovered job stuck in {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
    let r = client.call("drain", vec![]).unwrap();
    assert!(r.ok);
    drop(client);
    assert_eq!(wait_exit(revived, "revived daemon"), 0);

    let revived_final = std::fs::read(k_dir.join("job-0-final.snap")).unwrap();
    assert_eq!(
        control_final, revived_final,
        "final adapter bits after SIGKILL + recovery must match an \
         uninterrupted run bitwise"
    );
    let _ = std::fs::remove_dir_all(&c_dir);
    let _ = std::fs::remove_dir_all(&k_dir);
}

#[test]
fn serve_startup_failure_exits_3() {
    // A socket path past the sun_path limit can never bind.
    let dir = scratch("exit3");
    let long = dir.join("x".repeat(150)).with_extension("sock");
    let status = mesp()
        .current_dir(&dir)
        .args(["serve", "--budget-mb", "64"])
        .arg("--snapshot-dir")
        .arg(dir.join("state"))
        .arg("--socket")
        .arg(long)
        .status()
        .unwrap();
    assert_eq!(status.code(), Some(3));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_bad_job_file_exits_3() {
    let dir = scratch("exit3f");
    let status = mesp()
        .current_dir(&dir)
        .args(["fleet", "--job-file", "/definitely/not/here.jsonl"])
        .status()
        .unwrap();
    assert_eq!(status.code(), Some(3), "fleet startup failure exits 3");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_job_failures_exit_2() {
    let dir = scratch("exit2");
    let jobs = dir.join("jobs.jsonl");
    // Parses fine, fails at runtime: no such model config.
    std::fs::write(&jobs, "{\"config\": \"no-such-config\"}\n").unwrap();
    let status = mesp()
        .current_dir(&dir)
        .args(["fleet", "--budget-mb", "64"])
        .arg("--job-file")
        .arg(&jobs)
        .status()
        .unwrap();
    assert_eq!(status.code(), Some(2), "completed-with-failures exits 2");
    let _ = std::fs::remove_dir_all(&dir);
}
