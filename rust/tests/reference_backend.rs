//! ReferenceBackend-specific coverage (ISSUE 1 satellite):
//!
//! 1. MeSP vs MeBP gradient parity with cosine similarity == 1.0 on the
//!    2-layer toy config — on the reference backend the fused-recompute
//!    and residual backward paths share one implementation of the
//!    Appendix-A VJPs, so the gradients must be bitwise identical.
//! 2. Finite-difference spot checks on the LoRA dA/dB VJPs through the
//!    full `block_bwd_mesp` call, where `h = xA` is recomputed in the
//!    backward rather than stored.

use std::sync::Arc;

use mesp::config::{presets, Method, QuantMode, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::memory::MemoryTracker;
use mesp::model::ModelSpec;
use mesp::runtime::{Arg, Backend, ReferenceBackend};
use mesp::tensor::HostTensor;
use mesp::util::{stats, Rng};

fn grads_for(method: Method, seed: u64) -> Vec<Vec<f32>> {
    let cfg = TrainConfig {
        config: "toy".into(),
        method,
        seed,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().expect("session");
    let (batch, _g) = sess.loader.next();
    sess.engine.gradients(&batch).expect("gradients")
}

#[test]
fn mesp_mebp_cosine_is_exactly_one_on_toy() {
    for seed in [1, 2] {
        let mesp = grads_for(Method::Mesp, seed);
        let mebp = grads_for(Method::Mebp, seed);
        assert_eq!(mesp.len(), 2, "toy has 2 layers");
        for (l, (a, b)) in mesp.iter().zip(&mebp).enumerate() {
            // Bitwise identity is the strongest form of the paper's
            // "mathematically identical gradients" claim...
            assert_eq!(a, b, "seed {seed} layer {l}: gradients not bitwise equal");
            // ...and implies cosine similarity of exactly 1.0.
            let cos = stats::cosine(a, b);
            assert!(cos >= 1.0 - 1e-12, "layer {l}: cosine {cos} != 1.0");
        }
    }
}

#[test]
fn storeh_matches_mesp_bitwise() {
    let mesp = grads_for(Method::Mesp, 5);
    let sh = grads_for(Method::StoreH, 5);
    for (l, (a, b)) in mesp.iter().zip(&sh).enumerate() {
        assert_eq!(a, b, "layer {l}: store-h differs from recompute-h");
    }
}

/// Scalar probe loss L = Σ block_fwd(x; θ) ⊙ G for a fixed random G, so
/// that dL/dθ is exactly what block_bwd_mesp returns for g_y = G.
struct Probe {
    rt: Arc<dyn Backend>,
    x: HostTensor,
    g: HostTensor,
    frozen: Vec<HostTensor>,
    lora: Vec<HostTensor>,
}

impl Probe {
    fn new() -> Probe {
        let tracker = MemoryTracker::new();
        let dims = presets::compiled("toy").unwrap();
        let rt: Arc<dyn Backend> =
            Arc::new(ReferenceBackend::new(dims.clone(), tracker.clone()));
        let (model, adapters) =
            ModelSpec::new(dims.clone(), 11, QuantMode::F32).build(&tracker);
        let frozen: Vec<HostTensor> = model.block_tensors(0).to_vec();
        // LoRA B matrices init to zero, which would zero out the dA
        // gradients; give every adapter tensor random values instead.
        let mut rng = Rng::new(99);
        let lora: Vec<HostTensor> = adapters.lora[0]
            .tensors
            .iter()
            .map(|t| HostTensor::randn(&t.shape, 0.1, &mut rng))
            .collect();
        let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5,
                                  &mut rng);
        let g = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 1.0,
                                  &mut rng);
        Probe { rt, x, g, frozen, lora }
    }

    /// L(θ) with one LoRA tensor replaced.
    fn loss(&self, replace_idx: usize, replaced: &HostTensor) -> f64 {
        let mut args: Vec<Arg> = vec![Arg::Host(&self.x)];
        for t in &self.frozen {
            args.push(Arg::Host(t));
        }
        for (i, t) in self.lora.iter().enumerate() {
            args.push(Arg::Host(if i == replace_idx { replaced } else { t }));
        }
        let y = self.rt.execute("block_fwd", &args).unwrap()
            .into_iter().next().unwrap();
        y.as_f32()
            .iter()
            .zip(self.g.as_f32())
            .map(|(yv, gv)| (*yv as f64) * (*gv as f64))
            .sum()
    }

    /// Analytic LoRA grads from the fused MeSP backward (dA/dB ×7).
    fn analytic_grads(&self) -> Vec<HostTensor> {
        let mut args: Vec<Arg> = vec![Arg::Host(&self.x), Arg::Host(&self.g)];
        for t in &self.frozen {
            args.push(Arg::Host(t));
        }
        for t in &self.lora {
            args.push(Arg::Host(t));
        }
        let mut outs = self.rt.execute("block_bwd_mesp", &args).unwrap();
        outs.remove(0); // drop g_x; keep the 14 LoRA grads
        outs
    }
}

#[test]
fn lora_vjps_match_finite_differences() {
    let probe = Probe::new();
    let grads = probe.analytic_grads();
    assert_eq!(grads.len(), 14);
    // Directional derivative along the gradient itself: the analytic
    // value is |dθ|² (maximal signal-to-noise for an f32 forward), the
    // finite difference is (L(θ+εu) − L(θ−εu)) / 2ε with u = dθ/|dθ|.
    // Spot-check dA and dB of the q site and of the down site (the two
    // ends of the block: pre-attention and post-MLP).
    for idx in [0usize, 1, 12, 13] {
        let dtheta = &grads[idx];
        let norm: f64 = dtheta.as_f32().iter()
            .map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
        assert!(norm > 1e-4, "grad {idx} suspiciously small: {norm}");
        let eps = 2e-2f64;
        let perturb = |sign: f64| -> HostTensor {
            let data: Vec<f32> = probe.lora[idx]
                .as_f32()
                .iter()
                .zip(dtheta.as_f32())
                .map(|(p, d)| p + (sign * eps * (*d as f64) / norm) as f32)
                .collect();
            HostTensor::f32(&probe.lora[idx].shape, data)
        };
        let lp = probe.loss(idx, &perturb(1.0));
        let lm = probe.loss(idx, &perturb(-1.0));
        let fd = (lp - lm) / (2.0 * eps);
        // 5% relative plus a small absolute floor for f32 forward noise.
        let tol = 0.05 * norm + 0.02;
        assert!(
            (fd - norm).abs() < tol,
            "lora tensor {idx}: finite diff {fd:.6} vs analytic |g| {norm:.6} \
             (tol {tol:.4})"
        );
    }
}

#[test]
fn gx_chains_through_blocks() {
    // The g_x output must itself be a valid block input gradient: run a
    // 2-block chain through the engine API and check that gradients of
    // layer 0 (which only see g_x from layer 1) are finite and nonzero.
    let g = grads_for(Method::Mesp, 9);
    let l0_sum: f64 = g[0].iter().map(|v| (*v as f64).abs()).sum();
    assert!(l0_sum.is_finite() && l0_sum > 1e-6, "layer-0 grads: {l0_sum}");
}
