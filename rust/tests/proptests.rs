//! Property-based tests over the coordinator's invariants. The offline
//! build has no proptest crate, so properties are checked with an
//! in-house seeded case generator (util::Rng) — hundreds of random cases
//! per property, deterministic by seed, with the failing seed printed.

use mesp::config::{Method, OptimizerKind, QuantMode};
use mesp::data::tokenizer::for_vocab;
use mesp::data::BatchSource;
use mesp::memory::MemoryTracker;
use mesp::model::quant;
use mesp::persist::{Reader, RngStreams, Snapshot, Writer};
use mesp::tensor::{Data, HostTensor};
use mesp::train::CheckpointStore;
use mesp::util::{Json, Rng};

/// Run `cases` random cases of a property, reporting the failing seed.
fn forall(seed0: u64, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for c in 0..cases {
        let mut rng = Rng::new(seed0 ^ c.wrapping_mul(0x9e3779b97f4a7c15));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = r {
            panic!("property failed at case {c} (seed0 {seed0}): {e:?}");
        }
    }
}

#[test]
fn prop_tracker_live_never_negative_peak_monotone() {
    forall(1, 200, |rng| {
        let t = MemoryTracker::new();
        let mut guards = Vec::new();
        let mut peak_seen = 0u64;
        for _ in 0..rng.below(100) {
            if rng.uniform() < 0.6 || guards.is_empty() {
                guards.push(t.track("x", rng.below(10_000) as u64));
            } else {
                let i = rng.below(guards.len());
                guards.swap_remove(i);
            }
            let live = t.live();
            let peak = t.peak();
            assert!(peak >= live, "peak {peak} < live {live}");
            assert!(peak >= peak_seen, "peak decreased");
            peak_seen = peak;
        }
        drop(guards);
        assert_eq!(t.live(), 0);
    });
}

#[test]
fn prop_checkpoint_store_is_exact_once_per_layer() {
    // Invariant: every stored layer is retrievable exactly once with its
    // exact contents, in ANY order, regardless of spill budget.
    forall(2, 100, |rng| {
        let tr = MemoryTracker::new();
        let n_layers = 1 + rng.below(12);
        let len = 8 + rng.below(64);
        let budget = if rng.uniform() < 0.5 {
            0
        } else {
            (len * 4 * (1 + rng.below(n_layers))) as u64
        };
        let mut store = CheckpointStore::new(tr.clone(), budget);
        let mut expected = Vec::new();
        for l in 0..n_layers {
            let val = rng.uniform() * 100.0;
            store.store(l, HostTensor::f32(&[len], vec![val; len])).unwrap();
            expected.push(val);
        }
        // consume in random order
        let mut order: Vec<usize> = (0..n_layers).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.below(i + 1));
        }
        for l in order {
            let t = store.take(l).unwrap();
            assert_eq!(t.as_f32()[len / 2], expected[l], "layer {l}");
            assert!(store.take(l).is_err(), "double take layer {l}");
        }
        assert_eq!(tr.live(), 0, "all checkpoint bytes released");
    });
}

#[test]
fn prop_quant_roundtrip_error_bounded() {
    forall(3, 60, |rng| {
        let din = 64 * (1 + rng.below(4));
        let dout = 1 + rng.below(24);
        let std = 0.05 + rng.uniform();
        let w = rng.normal_vec(din * dout, std);
        let (packed, scales) = quant::quantize(&w, din, dout);
        let w2 = quant::dequantize(&packed, &scales, din, dout);
        for r in 0..din {
            for c in 0..dout {
                let s = scales[(r / quant::GROUP) * dout + c];
                let err = (w2[r * dout + c] - w[r * dout + c]).abs();
                assert!(err <= s / 2.0 + 1e-6);
            }
        }
    });
}

#[test]
fn prop_quant_degenerate_rows_stay_finite() {
    // Degenerate inputs — all-zero groups, constant rows, one huge
    // outlier, subnormal magnitudes — must quantize to finite scales and
    // dequantize to finite values; all-zero groups must come back as
    // exact zeros (scale 0.0, no 0/0 anywhere).
    forall(8, 80, |rng| {
        let din = 64 * (1 + rng.below(3));
        let dout = 1 + rng.below(12);
        let mut w = vec![0.0f32; din * dout];
        for g in 0..din / quant::GROUP {
            match rng.below(5) {
                0 => {} // all-zero group
                1 => {
                    // constant rows
                    let v = rng.uniform() * 2.0 - 1.0;
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] = v;
                        }
                    }
                }
                2 => {
                    // one huge outlier in an otherwise-zero group
                    let r = rng.below(quant::GROUP);
                    let c = rng.below(dout);
                    w[(g * quant::GROUP + r) * dout + c] = 1e30;
                }
                3 => {
                    // subnormal magnitudes
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] =
                                1e-40 * (rng.uniform() * 2.0 - 1.0);
                        }
                    }
                }
                _ => {
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] =
                                rng.uniform() * 2.0 - 1.0;
                        }
                    }
                }
            }
        }
        let (packed, scales) = quant::quantize(&w, din, dout);
        assert!(scales.iter().all(|s| s.is_finite() && *s >= 0.0));
        let w2 = quant::dequantize(&packed, &scales, din, dout);
        assert!(w2.iter().all(|v| v.is_finite()));
        for r in 0..din {
            let g = r / quant::GROUP;
            for c in 0..dout {
                if scales[g * dout + c] == 0.0 {
                    assert_eq!(w2[r * dout + c], 0.0,
                               "zero-scale group must dequantize to 0");
                } else {
                    let err = (w2[r * dout + c] - w[r * dout + c]).abs();
                    assert!(err <= scales[g * dout + c] / 2.0 + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_tokenizer_ids_in_range() {
    forall(4, 100, |rng| {
        let vocab = [256usize, 1024, 4096, 151_936][rng.below(4)];
        let tok = for_vocab(vocab);
        let mut text = String::new();
        for _ in 0..rng.below(200) {
            text.push((32 + rng.below(95) as u8) as char);
        }
        for id in tok.encode(&text) {
            assert!((0..vocab as i32).contains(&id), "{id} !in 0..{vocab}");
        }
    });
}

#[test]
fn prop_batch_source_shapes_and_shift() {
    forall(5, 40, |rng| {
        let batch = 1 + rng.below(3);
        let seq = 8 * (1 + rng.below(8));
        let vocab = [256usize, 2048][rng.below(2)];
        let mut src = BatchSource::new(vocab, batch, seq, rng.next_u64());
        for _ in 0..3 {
            let b = src.next_batch();
            assert_eq!(b.tokens.shape, vec![batch, seq]);
            assert_eq!(b.targets.shape, vec![batch, seq]);
            let toks = b.tokens.as_i32();
            let tgts = b.targets.as_i32();
            for i in 0..batch * seq - 1 {
                assert_eq!(tgts[i], toks[i + 1], "next-token shift");
            }
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    // random JSON trees survive serialize → parse → serialize
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        if depth == 0 || rng.uniform() < 0.4 {
            match rng.below(4) {
                0 => Json::Null,
                1 => Json::Bool(rng.uniform() < 0.5),
                2 => Json::Num((rng.below(100_000) as f64) / 8.0),
                _ => Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
            }
        } else if rng.uniform() < 0.5 {
            Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect())
        } else {
            Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            )
        }
    }
    forall(6, 150, |rng| {
        let v = gen(rng, 3);
        let s = v.to_string();
        let re = Json::parse(&s).expect("parse own output");
        assert_eq!(re.to_string(), s, "stable serialization");
    });
}

/// A random f32 value mixing ordinary magnitudes with the nasty corners
/// (NaN payloads, infinities, signed zero, subnormals) — snapshot
/// round-trips must preserve every one of them bit-for-bit.
fn arb_f32(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => f32::from_bits(0x7fc0_0001), // NaN with payload
        1 => f32::INFINITY,
        2 => f32::NEG_INFINITY,
        3 => -0.0,
        4 => 1e-40, // subnormal
        _ => (rng.uniform() - 0.5) * 10f32.powi(rng.below(20) as i32 - 10),
    }
}

/// A random tensor: f32 (adapters, scales, optimizer moments) or u8
/// (q4-packed nibbles) with a random small shape.
fn arb_tensor(rng: &mut Rng) -> HostTensor {
    let ndim = 1 + rng.below(3);
    let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
    let len: usize = shape.iter().product();
    if rng.uniform() < 0.3 {
        HostTensor::u8(&shape, (0..len).map(|_| rng.below(256) as u8).collect())
    } else {
        HostTensor::f32(&shape, (0..len).map(|_| arb_f32(rng)).collect())
    }
}

fn arb_snapshot(rng: &mut Rng) -> Snapshot {
    let methods = Method::ALL;
    let optimizers = [
        OptimizerKind::Sgd,
        OptimizerKind::Momentum { beta: arb_f32(rng) },
        OptimizerKind::Adam {
            beta1: arb_f32(rng),
            beta2: arb_f32(rng),
            eps: arb_f32(rng),
        },
    ];
    let seed = rng.next_u64();
    let mut lora = Vec::new();
    for _ in 0..rng.below(4) {
        let mut layer = Vec::new();
        for _ in 0..rng.below(5) {
            layer.push(arb_tensor(rng));
        }
        lora.push(layer);
    }
    let mut groups = |rng: &mut Rng| {
        let mut out = Vec::new();
        for _ in 0..rng.below(4) {
            let mut g = Vec::new();
            for _ in 0..rng.below(20) {
                g.push(arb_f32(rng));
            }
            out.push(g);
        }
        out
    };
    Snapshot {
        config: format!("cfg-{}", rng.below(1000)),
        method: methods[rng.below(4)],
        quant: QuantMode::ALL[rng.below(2)],
        optimizer: optimizers[rng.below(3)],
        lr: arb_f32(rng),
        seed,
        step: rng.next_u64(),
        batches_consumed: rng.next_u64(),
        rng: RngStreams::derive_from(seed),
        weights_fingerprint: rng.next_u64(),
        lora,
        opt_t: rng.next_u64(),
        opt_m1: groups(rng),
        opt_m2: groups(rng),
    }
}

fn tensors_bitwise_eq(a: &HostTensor, b: &HostTensor) -> bool {
    a.shape == b.shape
        && match (&a.data, &b.data) {
            (Data::F32(x), Data::F32(y)) => {
                x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
            }
            (Data::I32(x), Data::I32(y)) => x == y,
            (Data::U8(x), Data::U8(y)) => x == y,
            _ => false,
        }
}

#[test]
fn prop_snapshot_serialize_deserialize_is_identity() {
    // Arbitrary adapter tensors (f32 AND u8/q4-packed), optimizer
    // moments and rng/counter states survive encode → decode exactly.
    forall(9, 80, |rng| {
        let s = arb_snapshot(rng);
        let d = Snapshot::decode(&s.encode()).expect("decode own encoding");
        assert_eq!(d.config, s.config);
        assert_eq!(d.method, s.method);
        assert_eq!(d.quant, s.quant);
        assert_eq!(d.seed, s.seed);
        assert_eq!(d.step, s.step);
        assert_eq!(d.batches_consumed, s.batches_consumed);
        assert_eq!(d.rng, s.rng);
        assert_eq!(d.weights_fingerprint, s.weights_fingerprint);
        assert_eq!(d.opt_t, s.opt_t);
        assert_eq!(d.lora.len(), s.lora.len());
        for (la, lb) in s.lora.iter().zip(&d.lora) {
            assert_eq!(la.len(), lb.len());
            for (ta, tb) in la.iter().zip(lb) {
                assert!(tensors_bitwise_eq(ta, tb));
            }
        }
        for (ga, gb) in s.opt_m1.iter().zip(&d.opt_m1) {
            assert!(ga.iter().zip(gb).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
        for (ga, gb) in s.opt_m2.iter().zip(&d.opt_m2) {
            assert!(ga.iter().zip(gb).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    });
}

#[test]
fn prop_snapshot_any_single_bit_flip_is_rejected() {
    // Whatever byte of the file a bit flip lands in — magic, version,
    // length, checksum or payload — decode must fail, never return a
    // silently different snapshot.
    forall(10, 120, |rng| {
        let s = arb_snapshot(rng);
        let mut bytes = s.encode();
        let i = rng.below(bytes.len());
        bytes[i] ^= 1u8 << rng.below(8);
        assert!(
            Snapshot::decode(&bytes).is_err(),
            "bit flip at byte {i} of {} went undetected",
            bytes.len()
        );
    });
}

#[test]
fn prop_q4_packed_weights_roundtrip_through_the_codec() {
    // The q4 pack round-trip: quantized (packed, scales) pairs pass
    // through tensor serialization unchanged, so a q4 snapshot's packed
    // residents dequantize to exactly the same values after reload.
    forall(11, 40, |rng| {
        let din = 64 * (1 + rng.below(3));
        let dout = 1 + rng.below(16);
        let w = rng.normal_vec(din * dout, 0.1 + rng.uniform());
        let (packed, scales) = quant::quantize(&w, din, dout);
        let pt = HostTensor::u8(&[din / 2, dout], packed.clone());
        let st = HostTensor::f32(&[din / quant::GROUP, dout], scales.clone());
        let mut wtr = Writer::new();
        wtr.tensor(&pt);
        wtr.tensor(&st);
        let bytes = wtr.into_bytes();
        let mut r = Reader::new(&bytes);
        let pt2 = r.tensor().unwrap();
        let st2 = r.tensor().unwrap();
        assert!(tensors_bitwise_eq(&pt, &pt2));
        assert!(tensors_bitwise_eq(&st, &st2));
        let deq_a = quant::dequantize(&packed, &scales, din, dout);
        let deq_b =
            quant::dequantize(pt2.as_u8(), st2.as_f32(), din, dout);
        assert!(deq_a
            .iter()
            .zip(&deq_b)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    });
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    forall(7, 30, |rng| {
        let base = Rng::new(rng.next_u64());
        let mut a = base.fork(rng.below(1000) as u64);
        let mut b = base.fork(1000 + rng.below(1000) as u64);
        let collisions =
            (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(collisions, 0);
    });
}
