//! Property-based tests over the coordinator's invariants. The offline
//! build has no proptest crate, so properties are checked with an
//! in-house seeded case generator (util::Rng) — hundreds of random cases
//! per property, deterministic by seed, with the failing seed printed.

use mesp::data::tokenizer::for_vocab;
use mesp::data::BatchSource;
use mesp::memory::MemoryTracker;
use mesp::model::quant;
use mesp::tensor::HostTensor;
use mesp::train::CheckpointStore;
use mesp::util::{Json, Rng};

/// Run `cases` random cases of a property, reporting the failing seed.
fn forall(seed0: u64, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for c in 0..cases {
        let mut rng = Rng::new(seed0 ^ c.wrapping_mul(0x9e3779b97f4a7c15));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = r {
            panic!("property failed at case {c} (seed0 {seed0}): {e:?}");
        }
    }
}

#[test]
fn prop_tracker_live_never_negative_peak_monotone() {
    forall(1, 200, |rng| {
        let t = MemoryTracker::new();
        let mut guards = Vec::new();
        let mut peak_seen = 0u64;
        for _ in 0..rng.below(100) {
            if rng.uniform() < 0.6 || guards.is_empty() {
                guards.push(t.track("x", rng.below(10_000) as u64));
            } else {
                let i = rng.below(guards.len());
                guards.swap_remove(i);
            }
            let live = t.live();
            let peak = t.peak();
            assert!(peak >= live, "peak {peak} < live {live}");
            assert!(peak >= peak_seen, "peak decreased");
            peak_seen = peak;
        }
        drop(guards);
        assert_eq!(t.live(), 0);
    });
}

#[test]
fn prop_checkpoint_store_is_exact_once_per_layer() {
    // Invariant: every stored layer is retrievable exactly once with its
    // exact contents, in ANY order, regardless of spill budget.
    forall(2, 100, |rng| {
        let tr = MemoryTracker::new();
        let n_layers = 1 + rng.below(12);
        let len = 8 + rng.below(64);
        let budget = if rng.uniform() < 0.5 {
            0
        } else {
            (len * 4 * (1 + rng.below(n_layers))) as u64
        };
        let mut store = CheckpointStore::new(tr.clone(), budget);
        let mut expected = Vec::new();
        for l in 0..n_layers {
            let val = rng.uniform() * 100.0;
            store.store(l, HostTensor::f32(&[len], vec![val; len])).unwrap();
            expected.push(val);
        }
        // consume in random order
        let mut order: Vec<usize> = (0..n_layers).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.below(i + 1));
        }
        for l in order {
            let t = store.take(l).unwrap();
            assert_eq!(t.as_f32()[len / 2], expected[l], "layer {l}");
            assert!(store.take(l).is_err(), "double take layer {l}");
        }
        assert_eq!(tr.live(), 0, "all checkpoint bytes released");
    });
}

#[test]
fn prop_quant_roundtrip_error_bounded() {
    forall(3, 60, |rng| {
        let din = 64 * (1 + rng.below(4));
        let dout = 1 + rng.below(24);
        let std = 0.05 + rng.uniform();
        let w = rng.normal_vec(din * dout, std);
        let (packed, scales) = quant::quantize(&w, din, dout);
        let w2 = quant::dequantize(&packed, &scales, din, dout);
        for r in 0..din {
            for c in 0..dout {
                let s = scales[(r / quant::GROUP) * dout + c];
                let err = (w2[r * dout + c] - w[r * dout + c]).abs();
                assert!(err <= s / 2.0 + 1e-6);
            }
        }
    });
}

#[test]
fn prop_quant_degenerate_rows_stay_finite() {
    // Degenerate inputs — all-zero groups, constant rows, one huge
    // outlier, subnormal magnitudes — must quantize to finite scales and
    // dequantize to finite values; all-zero groups must come back as
    // exact zeros (scale 0.0, no 0/0 anywhere).
    forall(8, 80, |rng| {
        let din = 64 * (1 + rng.below(3));
        let dout = 1 + rng.below(12);
        let mut w = vec![0.0f32; din * dout];
        for g in 0..din / quant::GROUP {
            match rng.below(5) {
                0 => {} // all-zero group
                1 => {
                    // constant rows
                    let v = rng.uniform() * 2.0 - 1.0;
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] = v;
                        }
                    }
                }
                2 => {
                    // one huge outlier in an otherwise-zero group
                    let r = rng.below(quant::GROUP);
                    let c = rng.below(dout);
                    w[(g * quant::GROUP + r) * dout + c] = 1e30;
                }
                3 => {
                    // subnormal magnitudes
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] =
                                1e-40 * (rng.uniform() * 2.0 - 1.0);
                        }
                    }
                }
                _ => {
                    for r in 0..quant::GROUP {
                        for c in 0..dout {
                            w[(g * quant::GROUP + r) * dout + c] =
                                rng.uniform() * 2.0 - 1.0;
                        }
                    }
                }
            }
        }
        let (packed, scales) = quant::quantize(&w, din, dout);
        assert!(scales.iter().all(|s| s.is_finite() && *s >= 0.0));
        let w2 = quant::dequantize(&packed, &scales, din, dout);
        assert!(w2.iter().all(|v| v.is_finite()));
        for r in 0..din {
            let g = r / quant::GROUP;
            for c in 0..dout {
                if scales[g * dout + c] == 0.0 {
                    assert_eq!(w2[r * dout + c], 0.0,
                               "zero-scale group must dequantize to 0");
                } else {
                    let err = (w2[r * dout + c] - w[r * dout + c]).abs();
                    assert!(err <= scales[g * dout + c] / 2.0 + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_tokenizer_ids_in_range() {
    forall(4, 100, |rng| {
        let vocab = [256usize, 1024, 4096, 151_936][rng.below(4)];
        let tok = for_vocab(vocab);
        let mut text = String::new();
        for _ in 0..rng.below(200) {
            text.push((32 + rng.below(95) as u8) as char);
        }
        for id in tok.encode(&text) {
            assert!((0..vocab as i32).contains(&id), "{id} !in 0..{vocab}");
        }
    });
}

#[test]
fn prop_batch_source_shapes_and_shift() {
    forall(5, 40, |rng| {
        let batch = 1 + rng.below(3);
        let seq = 8 * (1 + rng.below(8));
        let vocab = [256usize, 2048][rng.below(2)];
        let mut src = BatchSource::new(vocab, batch, seq, rng.next_u64());
        for _ in 0..3 {
            let b = src.next_batch();
            assert_eq!(b.tokens.shape, vec![batch, seq]);
            assert_eq!(b.targets.shape, vec![batch, seq]);
            let toks = b.tokens.as_i32();
            let tgts = b.targets.as_i32();
            for i in 0..batch * seq - 1 {
                assert_eq!(tgts[i], toks[i + 1], "next-token shift");
            }
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    // random JSON trees survive serialize → parse → serialize
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        if depth == 0 || rng.uniform() < 0.4 {
            match rng.below(4) {
                0 => Json::Null,
                1 => Json::Bool(rng.uniform() < 0.5),
                2 => Json::Num((rng.below(100_000) as f64) / 8.0),
                _ => Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
            }
        } else if rng.uniform() < 0.5 {
            Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect())
        } else {
            Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            )
        }
    }
    forall(6, 150, |rng| {
        let v = gen(rng, 3);
        let s = v.to_string();
        let re = Json::parse(&s).expect("parse own output");
        assert_eq!(re.to_string(), s, "stable serialization");
    });
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    forall(7, 30, |rng| {
        let base = Rng::new(rng.next_u64());
        let mut a = base.fork(rng.below(1000) as u64);
        let mut b = base.fork(1000 + rng.below(1000) as u64);
        let collisions =
            (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(collisions, 0);
    });
}
