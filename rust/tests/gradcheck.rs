//! THE reproduction-critical integration test: the paper's central claim
//! that MeSP computes gradients *mathematically identical* to framework
//! autodiff (MeBP), across the whole stack — Rust-generated weights →
//! backend execution → gradient readback.
//!
//! Runs on the default (reference) backend from a clean checkout; the
//! same assertions exercise the PJRT artifact runtime when built with
//! `--features pjrt` and TrainConfig selects it.

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::util::stats;

fn base(config: &str, seed: u64) -> TrainConfig {
    TrainConfig {
        config: config.into(),
        seed,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn grads_for(config: &str, method: Method, seed: u64) -> Vec<Vec<f32>> {
    let mut cfg = base(config, seed);
    cfg.method = method;
    let mut sess = TrainSession::new(cfg).expect("session");
    let (batch, _g) = sess.loader.next();
    sess.engine.gradients(&batch).expect("gradients")
}

fn assert_layers_close(a: &[Vec<f32>], b: &[Vec<f32>], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len());
    for (l, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{what} layer {l} length");
        let err = stats::rel_error(x, y);
        assert!(err < tol, "{what} layer {l}: rel err {err:.3e} >= {tol:.0e}");
        let cos = stats::cosine(x, y);
        assert!(cos > 0.999999, "{what} layer {l}: cosine {cos}");
    }
}

#[test]
fn mesp_equals_mebp_exact_gradients() {
    for seed in [11, 42] {
        let mesp = grads_for("toy", Method::Mesp, seed);
        let mebp = grads_for("toy", Method::Mebp, seed);
        assert_layers_close(&mesp, &mebp, 2e-4, "MeSP vs MeBP");
    }
}

#[test]
fn storeh_equals_mesp() {
    let mesp = grads_for("toy", Method::Mesp, 7);
    let sh = grads_for("toy", Method::StoreH, 7);
    assert_layers_close(&mesp, &sh, 2e-4, "store-h vs MeSP");
}

#[test]
fn flash_all_pallas_config_matches() {
    // toy_flash selects the flash-attention/all-Pallas artifact set on the
    // pjrt backend (same math on the reference backend); same seeds →
    // same model → same grads.
    let plain = grads_for("toy", Method::Mesp, 3);
    let flash = grads_for("toy_flash", Method::Mesp, 3);
    assert_layers_close(&plain, &flash, 5e-4, "flash vs probs");
}

#[test]
fn gradients_are_nonzero_and_finite() {
    let g = grads_for("toy", Method::Mesp, 1);
    let mut total = 0.0f64;
    for layer in &g {
        for v in layer {
            assert!(v.is_finite(), "non-finite gradient");
            total += (*v as f64).abs();
        }
    }
    assert!(total > 1e-3, "gradients suspiciously zero: {total}");
}

#[test]
fn mezo_estimate_uncorrelated_with_truth() {
    // Paper Table 3: cosine ≈ 0, sign agreement ≈ 50%.
    let exact = grads_for("toy", Method::Mesp, 21);
    let est = grads_for("toy", Method::Mezo, 21);
    for (l, (e, t)) in est.iter().zip(&exact).enumerate() {
        let cos = stats::cosine(e, t).abs();
        let sign = stats::sign_agreement(e, t);
        assert!(cos < 0.25, "layer {l}: |cosine| {cos:.3} too high for SPSA");
        assert!((sign - 0.5).abs() < 0.15, "layer {l}: sign agree {sign:.3}");
    }
}

#[test]
fn training_step_changes_params_deterministically() {
    // Two sessions, same seed: after one step the LoRA params match
    // bit-for-bit; a third with another seed differs.
    let run = |seed: u64| -> Vec<f32> {
        let mut cfg = base("toy", seed);
        cfg.method = Method::Mesp;
        cfg.lr = 1e-2;
        let mut sess = TrainSession::new(cfg).unwrap();
        sess.run(1).unwrap();
        sess.engine.ctx().model.lora[0].flatten()
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a, b, "same seed, same params");
    let c = run(6);
    assert_ne!(a, c, "different seed, different params");
}
