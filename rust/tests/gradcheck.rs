//! THE reproduction-critical integration test: the paper's central claim
//! that MeSP computes gradients *mathematically identical* to framework
//! autodiff (MeBP), across the whole stack — Rust-generated weights →
//! backend execution → gradient readback.
//!
//! Runs on the default (reference) backend from a clean checkout; the
//! same assertions exercise the PJRT artifact runtime when built with
//! `--features pjrt` and TrainConfig selects it.

use mesp::config::{presets, ActCompress, KernelKind, Method, QuantMode, TrainConfig, PROJS};
use mesp::model::actquant;
use mesp::coordinator::TrainSession;
use mesp::memory::MemoryTracker;
use mesp::model::{quant, ModelSpec};
use mesp::runtime::{Arg, Backend, KernelOptions, ReferenceBackend};
use mesp::tensor::HostTensor;
use mesp::util::{stats, Rng};

fn base(config: &str, seed: u64) -> TrainConfig {
    TrainConfig {
        config: config.into(),
        seed,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn grads_for(config: &str, method: Method, seed: u64) -> Vec<Vec<f32>> {
    let mut cfg = base(config, seed);
    cfg.method = method;
    let mut sess = TrainSession::builder(cfg).build().expect("session");
    let (batch, _g) = sess.loader.next();
    sess.engine.gradients(&batch).expect("gradients")
}

fn assert_layers_close(a: &[Vec<f32>], b: &[Vec<f32>], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len());
    for (l, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{what} layer {l} length");
        let err = stats::rel_error(x, y);
        assert!(err < tol, "{what} layer {l}: rel err {err:.3e} >= {tol:.0e}");
        let cos = stats::cosine(x, y);
        assert!(cos > 0.999999, "{what} layer {l}: cosine {cos}");
    }
}

#[test]
fn mesp_equals_mebp_exact_gradients() {
    for seed in [11, 42] {
        let mesp = grads_for("toy", Method::Mesp, seed);
        let mebp = grads_for("toy", Method::Mebp, seed);
        assert_layers_close(&mesp, &mebp, 2e-4, "MeSP vs MeBP");
    }
}

#[test]
fn storeh_equals_mesp() {
    let mesp = grads_for("toy", Method::Mesp, 7);
    let sh = grads_for("toy", Method::StoreH, 7);
    assert_layers_close(&mesp, &sh, 2e-4, "store-h vs MeSP");
}

#[test]
fn flash_all_pallas_config_matches() {
    // toy_flash selects the flash-attention/all-Pallas artifact set on the
    // pjrt backend (same math on the reference backend); same seeds →
    // same model → same grads.
    let plain = grads_for("toy", Method::Mesp, 3);
    let flash = grads_for("toy_flash", Method::Mesp, 3);
    assert_layers_close(&plain, &flash, 5e-4, "flash vs probs");
}

#[test]
fn gradients_are_nonzero_and_finite() {
    let g = grads_for("toy", Method::Mesp, 1);
    let mut total = 0.0f64;
    for layer in &g {
        for v in layer {
            assert!(v.is_finite(), "non-finite gradient");
            total += (*v as f64).abs();
        }
    }
    assert!(total > 1e-3, "gradients suspiciously zero: {total}");
}

#[test]
fn mezo_estimate_uncorrelated_with_truth() {
    // Paper Table 3: cosine ≈ 0, sign agreement ≈ 50%.
    let exact = grads_for("toy", Method::Mesp, 21);
    let est = grads_for("toy", Method::Mezo, 21);
    for (l, (e, t)) in est.iter().zip(&exact).enumerate() {
        let cos = stats::cosine(e, t).abs();
        let sign = stats::sign_agreement(e, t);
        assert!(cos < 0.25, "layer {l}: |cosine| {cos:.3} too high for SPSA");
        assert!((sign - 0.5).abs() < 0.15, "layer {l}: sign agree {sign:.3}");
    }
}

#[test]
fn q4_gradient_parity_via_session_api() {
    // The `mesp gradcheck --quant q4` path in miniature: exact-gradient
    // methods agree through the quantized forward too.
    let grads_q4 = |method: Method| -> Vec<Vec<f32>> {
        let mut cfg = base("toy", 13);
        cfg.method = method;
        cfg.quant = QuantMode::Q4;
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        sess.engine.gradients(&batch).expect("gradients")
    };
    let mesp = grads_q4(Method::Mesp);
    let mebp = grads_q4(Method::Mebp);
    let sh = grads_q4(Method::StoreH);
    assert_layers_close(&mesp, &mebp, 1e-6, "q4 MeSP vs MeBP");
    assert_layers_close(&mesp, &sh, 1e-6, "q4 MeSP vs store-h");
}

/// Finite-difference gradcheck of dA/dB THROUGH the q4 forward. The
/// probe loss is L(θ) = Σ y(θ) ⊙ G; the oracle loss is computed through
/// host-dequantized weights (`block_fwd` on `quant::dequantize` output),
/// which the fused path must match bitwise — so the finite differences
/// of the oracle check the analytic grads of the packed path.
#[test]
fn q4_finite_difference_gradcheck_da_db() {
    let dims = presets::compiled("toy").unwrap();
    let tracker = MemoryTracker::new();
    let rt = ReferenceBackend::with_kernels(
        dims.clone(),
        tracker.clone(),
        KernelOptions { kind: KernelKind::Tiled, threads: 1 },
    );
    let (model, adapters) =
        ModelSpec::new(dims.clone(), 11, QuantMode::Q4).build(&tracker);
    let qblock: Vec<HostTensor> = model.block_tensors(0).to_vec();
    // Host-dequantized twin of the packed block (the oracle's weights).
    let deq_frozen = quant::dequantize_block(&dims, &qblock);
    // Random nonzero LoRA state (a zero B would zero out every dA).
    let mut rng = Rng::new(99);
    let lora: Vec<HostTensor> = adapters.lora[0]
        .tensors
        .iter()
        .map(|t| HostTensor::randn(&t.shape, 0.1, &mut rng))
        .collect();
    let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5, &mut rng);
    let g = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

    // Oracle loss: f32 forward through the host-dequantized weights.
    let oracle_loss = |replace_idx: usize, replaced: &HostTensor| -> f64 {
        let mut args: Vec<Arg> = vec![Arg::Host(&x)];
        for t in &deq_frozen {
            args.push(Arg::Host(t));
        }
        for (i, t) in lora.iter().enumerate() {
            args.push(Arg::Host(if i == replace_idx { replaced } else { t }));
        }
        let y = rt.execute("block_fwd", &args).unwrap()
            .into_iter().next().unwrap();
        y.as_f32().iter().zip(g.as_f32())
            .map(|(yv, gv)| (*yv as f64) * (*gv as f64)).sum()
    };

    // The packed forward IS the oracle forward, bitwise.
    {
        let mut q_args: Vec<Arg> = vec![Arg::Host(&x)];
        for t in &qblock {
            q_args.push(Arg::Host(t));
        }
        for t in &lora {
            q_args.push(Arg::Host(t));
        }
        let y_q4 = rt.execute("block_fwd_q4", &q_args).unwrap()
            .into_iter().next().unwrap();
        let y_oracle_probe = oracle_loss(usize::MAX, &x); // no replacement
        let y_q4_probe: f64 = y_q4.as_f32().iter().zip(g.as_f32())
            .map(|(yv, gv)| (*yv as f64) * (*gv as f64)).sum();
        assert_eq!(y_q4_probe, y_oracle_probe,
                   "fused q4 forward diverged from the host-dequant oracle");
    }

    // Analytic dA/dB from the fused q4 MeSP backward.
    let mut args: Vec<Arg> = vec![Arg::Host(&x), Arg::Host(&g)];
    for t in &qblock {
        args.push(Arg::Host(t));
    }
    for t in &lora {
        args.push(Arg::Host(t));
    }
    let mut outs = rt.execute("block_bwd_mesp_q4", &args).unwrap();
    outs.remove(0); // drop g_x; keep the 14 LoRA grads
    assert_eq!(outs.len(), 14);

    // Directional finite differences along each gradient: fd ≈ |dθ|.
    for idx in [0usize, 1, 6, 13] {
        let dtheta = &outs[idx];
        let norm: f64 = dtheta.as_f32().iter()
            .map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
        assert!(norm > 1e-4, "q4 grad {idx} suspiciously small: {norm}");
        let eps = 2e-2f64;
        let perturb = |sign: f64| -> HostTensor {
            let data: Vec<f32> = lora[idx]
                .as_f32()
                .iter()
                .zip(dtheta.as_f32())
                .map(|(p, d)| p + (sign * eps * (*d as f64) / norm) as f32)
                .collect();
            HostTensor::f32(&lora[idx].shape, data)
        };
        let lp = oracle_loss(idx, &perturb(1.0));
        let lm = oracle_loss(idx, &perturb(-1.0));
        let fd = (lp - lm) / (2.0 * eps);
        let tol = 0.05 * norm + 0.02;
        assert!(
            (fd - norm).abs() < tol,
            "q4 lora tensor {idx}: finite diff {fd:.6} vs analytic |g| \
             {norm:.6} (tol {tol:.4})"
        );
    }
}

/// Finite-difference gradcheck of dA/dB THROUGH `--act-compress int8`:
/// the stored h = xA set is round-tripped through the engine's exact
/// compression path (one flat blob per layer, PROJS order), fed to the
/// stored-h backward, and the resulting analytic grads are checked by
/// directional finite differences of the f32 oracle loss. dA never reads
/// the stored h, so it must stay BITWISE equal to the recompute (MeSP)
/// path; dB absorbs the ≲1% int8 round-trip error, which the fd
/// tolerance covers.
#[test]
fn int8_act_compress_finite_difference_gradcheck_da_db() {
    let dims = presets::compiled("toy").unwrap();
    let tracker = MemoryTracker::new();
    let rt = ReferenceBackend::with_kernels(
        dims.clone(),
        tracker.clone(),
        KernelOptions { kind: KernelKind::Tiled, threads: 1 },
    );
    let (model, adapters) =
        ModelSpec::new(dims.clone(), 17, QuantMode::F32).build(&tracker);
    let frozen: Vec<HostTensor> = model.block_tensors(0).to_vec();
    let mut rng = Rng::new(77);
    let lora: Vec<HostTensor> = adapters.lora[0]
        .tensors
        .iter()
        .map(|t| HostTensor::randn(&t.shape, 0.1, &mut rng))
        .collect();
    let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5, &mut rng);
    let g = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

    // Capture the seven h = xA, then round-trip them exactly the way
    // StoreHEngine does under --act-compress int8.
    let mut args: Vec<Arg> = vec![Arg::Host(&x)];
    for t in frozen.iter().chain(&lora) {
        args.push(Arg::Host(t));
    }
    let mut outs = rt.execute("block_fwd_saveh", &args).unwrap();
    drop(args);
    let hs: Vec<HostTensor> = outs.drain(1..).collect();
    let mut flat = Vec::new();
    for t in &hs {
        flat.extend_from_slice(t.as_f32());
    }
    let blob = actquant::compress(&flat);
    assert!(
        blob.bytes() * 2 < (flat.len() * 4) as u64,
        "the int8 blob must be well under half of f32"
    );
    let rest = actquant::decompress(&blob);
    let (m, r) = (dims.m(), dims.rank);
    let hs_i8: Vec<HostTensor> = (0..PROJS.len())
        .map(|i| HostTensor::f32(&[m, r], rest[i * m * r..(i + 1) * m * r].to_vec()))
        .collect();

    let run_bwd = |name: &str, hs: Option<&[HostTensor]>| -> Vec<HostTensor> {
        let mut args: Vec<Arg> = vec![Arg::Host(&x), Arg::Host(&g)];
        if let Some(hs) = hs {
            for t in hs {
                args.push(Arg::Host(t));
            }
        }
        for t in frozen.iter().chain(&lora) {
            args.push(Arg::Host(t));
        }
        let mut outs = rt.execute(name, &args).unwrap();
        outs.remove(0); // drop g_x; keep the 14 LoRA grads
        outs
    };
    let int8_grads = run_bwd("block_bwd_storeh", Some(&hs_i8));
    let mesp_grads = run_bwd("block_bwd_mesp", None);
    assert_eq!(int8_grads.len(), 14);

    // dA (even indices) never consumes stored h: compression-blind.
    for i in (0..14).step_by(2) {
        assert_eq!(
            int8_grads[i].as_f32(),
            mesp_grads[i].as_f32(),
            "dA tensor {i} must not feel the compression"
        );
    }
    // dB (odd indices): close to the exact twin, direction preserved.
    for i in (1..14).step_by(2) {
        let cos = stats::cosine(int8_grads[i].as_f32(), mesp_grads[i].as_f32());
        assert!(cos > 0.999, "dB tensor {i}: cosine {cos} vs exact");
    }

    // Directional finite differences of the f32 oracle along each
    // analytic int8 gradient: fd ≈ |dθ| within fd noise + int8 error.
    let oracle_loss = |replace_idx: usize, replaced: &HostTensor| -> f64 {
        let mut args: Vec<Arg> = vec![Arg::Host(&x)];
        for t in &frozen {
            args.push(Arg::Host(t));
        }
        for (i, t) in lora.iter().enumerate() {
            args.push(Arg::Host(if i == replace_idx { replaced } else { t }));
        }
        let y = rt.execute("block_fwd", &args).unwrap()
            .into_iter().next().unwrap();
        y.as_f32().iter().zip(g.as_f32())
            .map(|(yv, gv)| (*yv as f64) * (*gv as f64)).sum()
    };
    for idx in [0usize, 1, 7, 13] {
        let dtheta = &int8_grads[idx];
        let norm: f64 = dtheta.as_f32().iter()
            .map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
        assert!(norm > 1e-4, "int8 grad {idx} suspiciously small: {norm}");
        let eps = 2e-2f64;
        let perturb = |sign: f64| -> HostTensor {
            let data: Vec<f32> = lora[idx]
                .as_f32()
                .iter()
                .zip(dtheta.as_f32())
                .map(|(p, d)| p + (sign * eps * (*d as f64) / norm) as f32)
                .collect();
            HostTensor::f32(&lora[idx].shape, data)
        };
        let lp = oracle_loss(idx, &perturb(1.0));
        let lm = oracle_loss(idx, &perturb(-1.0));
        let fd = (lp - lm) / (2.0 * eps);
        let tol = 0.05 * norm + 0.02;
        assert!(
            (fd - norm).abs() < tol,
            "int8 lora tensor {idx}: finite diff {fd:.6} vs analytic |g| \
             {norm:.6} (tol {tol:.4})"
        );
    }
}

#[test]
fn storeh_int8_session_grads_match_f32_within_quant_tolerance() {
    // Whole-stack version of the unit check above: a store-h session
    // under --act-compress int8 produces gradients within the int8
    // round-trip error of its uncompressed twin — close, but NOT
    // bitwise (the compression must actually engage).
    let run = |ac: ActCompress| -> Vec<Vec<f32>> {
        let mut cfg = base("toy", 31);
        cfg.method = Method::StoreH;
        cfg.act_compress = ac;
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        sess.engine.gradients(&batch).expect("gradients")
    };
    let f32_g = run(ActCompress::None);
    let i8_g = run(ActCompress::Int8);
    assert_ne!(f32_g, i8_g, "int8 compression silently disabled");
    for (l, (a, b)) in f32_g.iter().zip(&i8_g).enumerate() {
        let err = stats::rel_error(a, b);
        assert!(err < 2e-2, "layer {l}: int8 rel err {err:.3e}");
        let cos = stats::cosine(a, b);
        assert!(cos > 0.999, "layer {l}: int8 cosine {cos}");
    }
}

#[test]
fn loss_chunk_session_parity_is_bitwise() {
    // --loss-chunk is a pure memory shape: gradients and the training
    // trajectory must be BITWISE identical to the unchunked oracle, for
    // chunk sizes that divide m, leave a ragged tail, and exceed m.
    let run = |chunk: usize| -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut cfg = base("toy", 23);
        cfg.method = Method::Mesp;
        cfg.loss_chunk = chunk;
        cfg.lr = 1e-2;
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        let grads = sess.engine.gradients(&batch).expect("gradients");
        sess.run(2).expect("steps");
        (grads, sess.engine.ctx().adapters.lora[0].flatten())
    };
    let (g0, p0) = run(0);
    for chunk in [1, 5, 16, 1 << 20] {
        let (gc, pc) = run(chunk);
        for (l, (a, b)) in g0.iter().zip(&gc).enumerate() {
            assert_eq!(a, b, "layer {l} grads differ at chunk {chunk}");
        }
        assert_eq!(p0, pc, "params diverged at chunk {chunk}");
    }

    // Same claim through the q4 forward: the loss head sees only the
    // final hidden state, so quantized weights change nothing about
    // chunking parity.
    let run_q4 = |chunk: usize| -> Vec<Vec<f32>> {
        let mut cfg = base("toy", 23);
        cfg.method = Method::Mesp;
        cfg.quant = QuantMode::Q4;
        cfg.loss_chunk = chunk;
        let mut sess = TrainSession::builder(cfg).build().expect("session");
        let (batch, _g) = sess.loader.next();
        sess.engine.gradients(&batch).expect("gradients")
    };
    assert_eq!(run_q4(0), run_q4(5), "q4 chunk parity broken");
}

#[test]
fn training_step_changes_params_deterministically() {
    // Two sessions, same seed: after one step the LoRA params match
    // bit-for-bit; a third with another seed differs.
    let run = |seed: u64| -> Vec<f32> {
        let mut cfg = base("toy", seed);
        cfg.method = Method::Mesp;
        cfg.lr = 1e-2;
        let mut sess = TrainSession::builder(cfg).build().unwrap();
        sess.run(1).unwrap();
        sess.engine.ctx().adapters.lora[0].flatten()
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a, b, "same seed, same params");
    let c = run(6);
    assert_ne!(a, c, "different seed, different params");
}
