//! Telemetry integration tier: the obs subsystem must be OBSERVE-ONLY
//! (tracing on vs off is bitwise identical in losses and adapter
//! params), the trace of a real session must contain the span hierarchy
//! the module promises (step ⊃ fwd/bwd/opt ⊃ artifact ⊃ gemm), the
//! Chrome export must survive a file round-trip, and the metrics
//! registry's deterministic slice (counters, FLOPs, losses) must be
//! identical across kernel variants.

use mesp::config::{KernelKind, Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::obs::{MetricsRegistry, TraceSink};
use mesp::util::Json;

fn base() -> TrainConfig {
    TrainConfig {
        config: "toy".into(),
        method: Method::Mesp,
        lr: 5e-3,
        seed: 42,
        log_every: usize::MAX,
        ..Default::default()
    }
}

/// Run `steps` steps and return (per-step loss bits, adapter param bits).
fn run_bits(
    cfg: TrainConfig,
    trace: Option<TraceSink>,
    steps: usize,
) -> (Vec<u64>, Vec<u32>) {
    let mut b = TrainSession::builder(cfg);
    if let Some(t) = trace {
        b = b.trace(t);
    }
    let mut sess = b.build().unwrap();
    sess.run(steps).unwrap();
    let loss_bits = sess.losses().iter().map(|l| l.to_bits()).collect();
    let adapter_bits = sess
        .engine
        .ctx()
        .adapters
        .lora
        .iter()
        .flat_map(|a| a.flatten())
        .map(f32::to_bits)
        .collect();
    (loss_bits, adapter_bits)
}

#[test]
fn tracing_on_off_bitwise_identical() {
    let sink = TraceSink::enabled();
    let (loss_on, params_on) = run_bits(base(), Some(sink.clone()), 6);
    let (loss_off, params_off) = run_bits(base(), None, 6);
    assert!(!sink.events().is_empty(), "enabled sink saw no events");
    assert_eq!(loss_on, loss_off, "telemetry perturbed the loss stream");
    assert_eq!(params_on, params_off, "telemetry perturbed the params");
}

#[test]
fn session_trace_contains_expected_span_hierarchy() {
    let steps = 3;
    let sink = TraceSink::enabled();
    let mut sess = TrainSession::builder(base())
        .trace(sink.clone())
        .build()
        .unwrap();
    let layers = sess.engine.ctx().rt.dims().n_layers;
    sess.run(steps).unwrap();
    let evs = sink.events();
    let count = |name: &str, cat: &str| {
        evs.iter()
            .filter(|e| e.name == name && e.cat == cat && e.ph == 'X')
            .count()
    };
    assert_eq!(count("step", "train"), steps);
    assert_eq!(count("fwd", "train"), steps);
    assert_eq!(count("bwd", "train"), steps);
    assert_eq!(count("opt", "train"), steps * layers, "one opt span per layer");
    assert!(
        evs.iter().any(|e| e.cat == "artifact"),
        "no artifact spans recorded"
    );
    let gemm = evs.iter().find(|e| e.cat == "gemm").expect("no GEMM spans");
    for key in ["m", "k", "n", "flops", "isa", "tiles"] {
        assert!(
            gemm.args.iter().any(|(k, _)| *k == key),
            "GEMM span lacks '{key}' arg: {:?}",
            gemm.args
        );
    }
    // Single-threaded session: every train-phase span pair on the main
    // thread must be disjoint or properly nested.
    let train: Vec<_> = evs.iter().filter(|e| e.cat == "train").collect();
    for a in &train {
        for b in &train {
            if a.tid != b.tid {
                continue;
            }
            let (a0, a1) = (a.ts_us, a.ts_us + a.dur_us);
            let (b0, b1) = (b.ts_us, b.ts_us + b.dur_us);
            let disjoint = a1 <= b0 || b1 <= a0;
            let nested = (a0 >= b0 && a1 <= b1) || (b0 >= a0 && b1 <= a1);
            assert!(
                disjoint || nested,
                "partially overlapping spans: {} vs {}",
                a.name,
                b.name
            );
        }
    }
}

#[test]
fn chrome_export_round_trips_from_real_session() {
    let sink = TraceSink::enabled();
    let mut sess = TrainSession::builder(base())
        .trace(sink.clone())
        .build()
        .unwrap();
    sess.run(2).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "mesp-obs-test-{}",
        std::process::id()
    ));
    let path = dir.join("trace.json");
    sink.export_chrome(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let parsed = Json::parse(&text).expect("exported trace must be valid JSON");
    let evs = parsed.req("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(evs.len(), sink.events().len());
    let steps = evs
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("step")
                && e.get("ph").and_then(Json::as_str) == Some("X")
        })
        .count();
    assert_eq!(steps, 2, "exported trace lost step spans");
}

/// The deterministic slice of a registry snapshot: counters (step and
/// artifact-call counts), total FLOPs per artifact, and the final loss
/// gauge. Timing metrics are excluded — they legitimately differ.
fn deterministic_lines(reg: &MetricsRegistry) -> Vec<String> {
    reg.snapshot_lines()
        .into_iter()
        .filter_map(|j| {
            let kind = j.get("kind")?.as_str()?.to_string();
            let name = j.get("name")?.as_str()?.to_string();
            let keep = kind == "counter"
                || name == "step/loss"
                || (name.starts_with("artifact/") && name.ends_with("/flops"));
            if keep {
                Some(j.to_string())
            } else {
                None
            }
        })
        .collect()
}

#[test]
fn registry_deterministic_slice_identical_tiled_vs_parallel() {
    let steps = 4;
    let run = |kind: KernelKind| {
        let mut cfg = base();
        cfg.kernel = kind;
        let mut sess = TrainSession::builder(cfg).build().unwrap();
        sess.run(steps).unwrap();
        // folds artifact/* and memory/* gauges into the registry
        // (writes no files: no --trace/--metrics-out paths are set)
        sess.export_telemetry().unwrap();
        assert_eq!(sess.registry.counter("step/count"), steps as u64);
        deterministic_lines(&sess.registry)
    };
    let tiled = run(KernelKind::Tiled);
    let parallel = run(KernelKind::Parallel);
    assert!(
        tiled.iter().any(|l| l.contains("artifact/")),
        "no artifact metrics recorded: {tiled:?}"
    );
    assert_eq!(
        tiled, parallel,
        "counters/FLOPs/losses must not depend on the kernel variant"
    );
}
