//! End-to-end CLI tests: drive the actual `mesp` binary the way a user
//! would (launcher behaviour, flag validation, output contracts).

use std::process::Command;

fn mesp(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mesp"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("run mesp");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_prints_usage() {
    let (ok, text) = mesp(&["help"]);
    assert!(ok);
    assert!(text.contains("USAGE"));
    assert!(text.contains("reproduce"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, text) = mesp(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn unknown_flag_is_rejected() {
    let (ok, text) = mesp(&["train", "--confg", "toy"]);
    assert!(!ok, "typo flags must fail loudly");
    assert!(text.contains("unknown flag"));
    assert!(text.contains("USAGE"), "typo error must print usage:\n{text}");
}

#[test]
fn fleet_typo_flag_is_rejected_with_usage() {
    let (ok, text) = mesp(&["fleet", "--budegt-mb", "64"]);
    assert!(!ok);
    assert!(text.contains("unknown flag --budegt-mb"), "{text}");
    assert!(text.contains("USAGE"));
}

#[test]
fn fleet_runs_a_toy_grid_and_reports() {
    let (ok, text) = mesp(&[
        "fleet", "--config", "toy", "--budget-mb", "64", "--jobs", "4",
        "--steps", "2", "--workers", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet report"), "{text}");
    assert!(text.contains("MeSP"), "{text}");
    assert!(text.contains("MeBP"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
}

#[test]
fn fleet_rejects_bad_method_list() {
    let (ok, text) = mesp(&["fleet", "--methods", "mesp,frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown method"), "{text}");
}

#[test]
fn simulate_outputs_all_methods() {
    let (ok, text) = mesp(&["simulate", "--model", "0.5b", "--seq", "256"]);
    assert!(ok, "{text}");
    for m in ["MeBP", "MeZO", "MeSP", "Store-h"] {
        assert!(text.contains(m), "missing {m} in:\n{text}");
    }
    assert!(text.contains("% vs MeBP"));
}

#[test]
fn simulate_breakdown_table() {
    let (ok, text) = mesp(&["simulate", "--model", "3b", "--breakdown"]);
    assert!(ok, "{text}");
    assert!(text.contains("checkpoints"));
    assert!(text.contains("dequant_buffers"));
    assert!(text.contains("TOTAL"));
}

#[test]
fn train_toy_runs_and_reports() {
    let (ok, text) = mesp(&[
        "train", "--config", "toy", "--method", "mesp", "--steps", "3",
        "--log-every", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final loss"));
    assert!(text.contains("block_bwd_mesp"), "exec stats listed");
}

#[test]
fn gradcheck_command_passes() {
    let (ok, text) = mesp(&["gradcheck", "--config", "toy", "--seeds", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("gradcheck PASSED"));
}

#[test]
fn train_q4_reports_shrunken_residents() {
    let (ok, text) = mesp(&[
        "train", "--config", "toy", "--quant", "q4", "--steps", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("quant=q4"), "{text}");
    assert!(text.contains("resident base weights (q4)"), "{text}");
    assert!(text.contains("block_bwd_mesp_q4"), "q4 exec stats listed: {text}");
}

#[test]
fn gradcheck_q4_passes() {
    let (ok, text) = mesp(&[
        "gradcheck", "--config", "toy", "--quant", "q4", "--seeds", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("gradcheck PASSED"));
}

#[test]
fn train_rejects_bad_quant_mode() {
    let (ok, text) = mesp(&["train", "--quant", "q8"]);
    assert!(!ok);
    assert!(text.contains("unknown quant mode"), "{text}");
}

#[test]
fn inspect_lists_artifacts() {
    let (ok, text) = mesp(&["inspect", "--config", "toy"]);
    assert!(ok, "{text}");
    assert!(text.contains("block_bwd_mesp"));
    assert!(text.contains("15 outputs"));
}

#[test]
fn reproduce_memory_table_prints_paper_and_model() {
    let (ok, text) = mesp(&["reproduce", "--table", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("Table 2"));
    assert!(text.contains("paper"));
    assert!(text.contains("model"));
    assert!(text.contains("1024"));
}

#[test]
fn simulate_rejects_unknown_model() {
    let (ok, text) = mesp(&["simulate", "--model", "7b"]);
    assert!(!ok);
    assert!(text.contains("unknown sim preset"));
}
