//! End-to-end CLI tests: drive the actual `mesp` binary the way a user
//! would (launcher behaviour, flag validation, output contracts).

use std::process::Command;

fn mesp(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mesp"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("run mesp");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_prints_usage() {
    let (ok, text) = mesp(&["help"]);
    assert!(ok);
    assert!(text.contains("USAGE"));
    assert!(text.contains("reproduce"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, text) = mesp(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn unknown_flag_is_rejected() {
    let (ok, text) = mesp(&["train", "--confg", "toy"]);
    assert!(!ok, "typo flags must fail loudly");
    assert!(text.contains("unknown flag"));
    assert!(text.contains("USAGE"), "typo error must print usage:\n{text}");
}

#[test]
fn fleet_typo_flag_is_rejected_with_usage() {
    let (ok, text) = mesp(&["fleet", "--budegt-mb", "64"]);
    assert!(!ok);
    assert!(text.contains("unknown flag --budegt-mb"), "{text}");
    assert!(text.contains("USAGE"));
}

#[test]
fn fleet_runs_a_toy_grid_and_reports() {
    let (ok, text) = mesp(&[
        "fleet", "--config", "toy", "--budget-mb", "64", "--jobs", "4",
        "--steps", "2", "--workers", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet report"), "{text}");
    assert!(text.contains("MeSP"), "{text}");
    assert!(text.contains("MeBP"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
}

#[test]
fn fleet_rejects_bad_method_list() {
    let (ok, text) = mesp(&["fleet", "--methods", "mesp,frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown method"), "{text}");
}

#[test]
fn simulate_outputs_all_methods() {
    let (ok, text) = mesp(&["simulate", "--model", "0.5b", "--seq", "256"]);
    assert!(ok, "{text}");
    for m in ["MeBP", "MeZO", "MeSP", "Store-h"] {
        assert!(text.contains(m), "missing {m} in:\n{text}");
    }
    assert!(text.contains("% vs MeBP"));
}

#[test]
fn simulate_breakdown_table() {
    let (ok, text) = mesp(&["simulate", "--model", "3b", "--breakdown"]);
    assert!(ok, "{text}");
    assert!(text.contains("checkpoints"));
    assert!(text.contains("dequant_buffers"));
    assert!(text.contains("TOTAL"));
}

#[test]
fn train_toy_runs_and_reports() {
    let (ok, text) = mesp(&[
        "train", "--config", "toy", "--method", "mesp", "--steps", "3",
        "--log-every", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final loss"));
    assert!(text.contains("block_bwd_mesp"), "exec stats listed");
}

#[test]
fn gradcheck_command_passes() {
    let (ok, text) = mesp(&["gradcheck", "--config", "toy", "--seeds", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("gradcheck PASSED"));
}

#[test]
fn train_q4_reports_shrunken_residents() {
    let (ok, text) = mesp(&[
        "train", "--config", "toy", "--quant", "q4", "--steps", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("quant=q4"), "{text}");
    assert!(text.contains("resident base weights (q4)"), "{text}");
    assert!(text.contains("block_bwd_mesp_q4"), "q4 exec stats listed: {text}");
}

#[test]
fn gradcheck_q4_passes() {
    let (ok, text) = mesp(&[
        "gradcheck", "--config", "toy", "--quant", "q4", "--seeds", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("gradcheck PASSED"));
}

#[test]
fn train_rejects_bad_quant_mode() {
    let (ok, text) = mesp(&["train", "--quant", "q8"]);
    assert!(!ok);
    assert!(text.contains("unknown quant mode"), "{text}");
}

#[test]
fn inspect_lists_artifacts() {
    let (ok, text) = mesp(&["inspect", "--config", "toy"]);
    assert!(ok, "{text}");
    assert!(text.contains("block_bwd_mesp"));
    assert!(text.contains("15 outputs"));
}

#[test]
fn reproduce_memory_table_prints_paper_and_model() {
    let (ok, text) = mesp(&["reproduce", "--table", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("Table 2"));
    assert!(text.contains("paper"));
    assert!(text.contains("model"));
    assert!(text.contains("1024"));
}

fn extract_loss_bits(text: &str) -> &str {
    let start = text
        .find("final loss bits: ")
        .expect("train must print exact final-loss bits")
        + "final loss bits: ".len();
    let rest = &text[start..];
    let end = rest.find(' ').unwrap_or(rest.len());
    &rest[..end]
}

#[test]
fn train_suspend_resume_reproduces_final_loss_bitwise() {
    // The CI resume tier in miniature: a 6-step run with --save-every 3
    // and a resume from the step-3 snapshot must print IDENTICAL final
    // loss bits.
    let dir = std::env::temp_dir().join("mesp-test-cli-resume");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();
    let (ok, full) = mesp(&[
        "train", "--config", "toy", "--steps", "6", "--save-every", "3",
        "--snapshot-dir", dirs,
    ]);
    assert!(ok, "{full}");
    assert!(full.contains("snapshot: "), "{full}");
    let snap = dir.join("step-3.snap");
    assert!(snap.exists(), "step-3 snapshot must exist");
    let (ok, resumed) = mesp(&[
        "train", "--config", "toy", "--steps", "6", "--resume",
        snap.to_str().unwrap(),
    ]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("resumed"), "{resumed}");
    assert_eq!(
        extract_loss_bits(&full),
        extract_loss_bits(&resumed),
        "resume must be bitwise\nfull:\n{full}\nresumed:\n{resumed}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_resume_from_garbage_fails_loudly() {
    let dir = std::env::temp_dir().join("mesp-test-cli-badsnap");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.snap");
    std::fs::write(&bad, b"not a snapshot").unwrap();
    let (ok, text) = mesp(&["train", "--resume", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("truncated") || text.contains("bad magic"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_budget_schedule_preempts_and_resumes() {
    // Budget shrinks after 8 fleet-wide steps to fit only one of the two
    // running jobs: the report must show at least one preempt + resume
    // and still complete everything. The budgets bracket the toy MeSP
    // job cost (machine-dependent via the packing-panel term), exactly
    // the way the CI smoke sizes them with `fleet --print-cost`.
    let base = mesp::config::TrainConfig::default();
    let cost =
        mesp::fleet::job_cost_bytes(&mesp::fleet::JobSpec::from_base(&base))
            .unwrap();
    let one_job_mb = cost.div_ceil(1 << 20); // ceil: fits one, not two
    let budget_mb = 3 * one_job_mb;
    let dir = std::env::temp_dir().join("mesp-test-cli-preempt");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, text) = mesp(&[
        "fleet", "--config", "toy", "--methods", "mesp", "--jobs", "2",
        "--steps", "25", "--workers", "2", "--budget-mb",
        &budget_mb.to_string(), "--budget-schedule",
        &format!("8:{one_job_mb}"), "--snapshot-dir",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("preemption on"), "{text}");
    assert!(text.contains("fleet report"), "{text}");
    let line = text
        .lines()
        .find(|l| l.starts_with("preempts "))
        .unwrap_or_else(|| panic!("no preempts line in:\n{text}"));
    assert!(
        !line.starts_with("preempts 0"),
        "budget shrink must preempt: {line}\n{text}"
    );
    assert!(!line.contains("resumes 0"), "parked job must resume: {line}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_rejects_bad_budget_schedule() {
    let (ok, text) = mesp(&["fleet", "--budget-schedule", "20"]);
    assert!(!ok);
    assert!(text.contains("step:mb"), "{text}");
}

#[test]
fn simulate_rejects_unknown_model() {
    let (ok, text) = mesp(&["simulate", "--model", "7b"]);
    assert!(!ok);
    assert!(text.contains("unknown sim preset"));
}
