//! Fleet scheduler integration: the paper's budget argument made
//! operational. With a budget sized to admit exactly one MeBP toy job,
//! MeBP jobs serialize while ≥2 MeSP jobs run concurrently; every job
//! completes with finite losses; and the fleet-wide aggregate tracked
//! peak never exceeds the budget.

use mesp::config::{presets, ActCompress, Method, QuantMode, TrainConfig};
use mesp::fleet::{
    grid, job_cost_bytes, job_weight_class, BudgetChange, FleetOptions, Job,
    JobSpec, Scheduler,
};
use mesp::memory::resident_weight_bytes;

fn base(steps: usize) -> TrainConfig {
    TrainConfig {
        config: "toy".into(),
        steps,
        log_every: usize::MAX,
        ..Default::default()
    }
}

/// Per-job activation/scratch cost — weight bytes are a separate,
/// once-per-base charge ([`job_weight_class`]).
fn cost(base: &TrainConfig, method: Method) -> u64 {
    let mut spec = JobSpec::from_base(base);
    spec.method = method;
    job_cost_bytes(&spec).unwrap()
}

/// Resident bytes of the base-weight class every grid job shares.
fn wbytes(base: &TrainConfig) -> u64 {
    job_weight_class(&JobSpec::from_base(base)).unwrap().bytes
}

#[test]
fn one_mebp_budget_serializes_mebp_but_overlaps_mesp() {
    let base = base(40);
    let mebp_cost = cost(&base, Method::Mebp);
    let mesp_cost = cost(&base, Method::Mesp);
    assert!(mesp_cost < mebp_cost, "MeSP must cost less than MeBP");

    // "Sized to admit exactly one MeBP job": the shared base plus one
    // MeBP activation cost fits, a second MeBP job does not (grid jobs
    // share one weight class, so the base is charged once).
    let w = wbytes(&base);
    let budget = 2 * mebp_cost + w - 1;
    assert!(
        budget >= 2 * mesp_cost + w,
        "premise: ≥2 MeSP jobs ({mesp_cost} B each) must fit where one \
         MeBP ({mebp_cost} B) does"
    );
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 4,
        ..FleetOptions::default()
    };

    // All-MeBP fleet: admission must serialize the jobs.
    let report = Scheduler::run(&opts, &base, grid(&base, &[Method::Mebp], 4))
        .unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert_eq!(
        report.peak_concurrent, 1,
        "a one-MeBP budget must run MeBP one at a time\n{}",
        report.render()
    );
    assert!(
        report.aggregate_peak <= budget,
        "aggregate tracked peak {} exceeds budget {}",
        report.aggregate_peak,
        budget
    );
    assert!(report.peak_committed <= budget);
    for o in &report.outcomes {
        let r = o.result.as_ref().unwrap();
        assert!(r.summary.healthy(), "job {} diverged", o.job.id);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert_eq!(r.summary.steps, 40);
    }

    // All-MeSP fleet under the SAME budget: jobs overlap.
    let report = Scheduler::run(&opts, &base, grid(&base, &[Method::Mesp], 6))
        .unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(
        report.peak_concurrent >= 2,
        "≥2 MeSP jobs should have been admitted concurrently, got {}\n{}",
        report.peak_concurrent,
        report.render()
    );
    assert!(
        report.aggregate_peak <= budget,
        "aggregate tracked peak {} exceeds budget {}",
        report.aggregate_peak,
        budget
    );
    assert!(report.peak_committed <= budget);
    for o in &report.outcomes {
        let r = o.result.as_ref().unwrap();
        assert!(r.summary.healthy(), "job {} diverged", o.job.id);
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }
}

#[test]
fn f32_serializing_budget_overlaps_q4_jobs() {
    // The concurrency headroom the q4 path buys: a budget sized to admit
    // exactly one f32 MeSP job must overlap ≥2 q4 MeSP jobs, because
    // admission charges the packed resident-weight footprint. Jobs get
    // PRIVATE bases (distinct model seeds) so the weight class is paid
    // per job, isolating the quantization effect from weight sharing.
    let private = |base: &TrainConfig, n: usize| {
        let mut jobs = grid(base, &[Method::Mesp], n);
        for j in &mut jobs {
            j.spec.model_seed = Some(0x5eed_0000 + j.id as u64);
        }
        jobs
    };
    let base_f32 = base(30);
    let mut base_q4 = base_f32.clone();
    base_q4.quant = QuantMode::Q4;
    // Full per-job footprint: activation cost + this job's private base.
    let f32_full = cost(&base_f32, Method::Mesp) + wbytes(&base_f32);
    let q4_full = cost(&base_q4, Method::Mesp) + wbytes(&base_q4);
    assert!(q4_full < f32_full, "q4 job must cost less than its f32 twin");
    let dims = presets::compiled("toy").unwrap();
    let saved = resident_weight_bytes(&dims, QuantMode::F32)
        - resident_weight_bytes(&dims, QuantMode::Q4);
    // The charge delta is the resident saving minus the q4 oracle-dequant
    // scratch term — the bulk of the saving must survive.
    assert!(
        f32_full - q4_full >= saved / 2,
        "cost delta {} must reflect the resident-weight saving {}",
        f32_full - q4_full,
        saved
    );

    // One-f32-job budget: f32 MeSP jobs serialize...
    let budget = 2 * f32_full - 1;
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 4,
        ..FleetOptions::default()
    };
    let report =
        Scheduler::run(&opts, &base_f32, private(&base_f32, 4)).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert_eq!(
        report.peak_concurrent, 1,
        "a one-f32-MeSP budget must serialize f32 jobs\n{}",
        report.render()
    );

    // ...while q4 MeSP jobs overlap under the SAME budget.
    assert!(2 * q4_full <= budget, "premise: two q4 jobs must fit");
    let report =
        Scheduler::run(&opts, &base_q4, private(&base_q4, 6)).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(
        report.peak_concurrent >= 2,
        "≥2 q4 MeSP jobs should have been admitted concurrently, got {}\n{}",
        report.peak_concurrent,
        report.render()
    );
    assert!(
        report.aggregate_peak <= budget,
        "aggregate tracked peak {} exceeds budget {}",
        report.aggregate_peak,
        budget
    );
    assert!(report.peak_committed <= budget);
    for o in &report.outcomes {
        let r = o.result.as_ref().unwrap();
        assert!(r.summary.healthy(), "q4 job {} diverged", o.job.id);
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }
}

#[test]
fn storeh_f32_serializing_budget_overlaps_int8_jobs() {
    // The concurrency headroom --act-compress int8 buys for the store-h
    // ablation: a budget sized to admit exactly ONE uncompressed store-h
    // job must overlap ≥2 int8-compressed jobs, because admission charges
    // the packed per-layer blob instead of 7 bucket-rounded f32 buffers
    // per layer. Private bases isolate the effect from weight sharing.
    let private = |base: &TrainConfig, n: usize| {
        let mut jobs = grid(base, &[Method::StoreH], n);
        for j in &mut jobs {
            j.spec.model_seed = Some(0xac7_0000 + j.id as u64);
        }
        jobs
    };
    let base_f32 = base(30);
    let mut base_i8 = base_f32.clone();
    base_i8.act_compress = ActCompress::Int8;
    let f32_full = cost(&base_f32, Method::StoreH) + wbytes(&base_f32);
    let i8_full = cost(&base_i8, Method::StoreH) + wbytes(&base_i8);
    assert!(
        i8_full < f32_full,
        "int8 store-h job must cost less than its f32 twin: {i8_full} vs \
         {f32_full}"
    );

    // One-f32-job budget: uncompressed store-h jobs serialize...
    let budget = 2 * f32_full - 1;
    assert!(2 * i8_full <= budget, "premise: two int8 jobs must fit");
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 4,
        ..FleetOptions::default()
    };
    let report =
        Scheduler::run(&opts, &base_f32, private(&base_f32, 4)).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert_eq!(
        report.peak_concurrent, 1,
        "a one-store-h budget must serialize uncompressed jobs\n{}",
        report.render()
    );

    // ...while int8 jobs overlap under the SAME budget.
    let report =
        Scheduler::run(&opts, &base_i8, private(&base_i8, 6)).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(
        report.peak_concurrent >= 2,
        "≥2 int8 store-h jobs should overlap, got {}\n{}",
        report.peak_concurrent,
        report.render()
    );
    assert!(
        report.aggregate_peak <= budget,
        "aggregate tracked peak {} exceeds budget {}",
        report.aggregate_peak,
        budget
    );
    assert!(report.peak_committed <= budget);
    for o in &report.outcomes {
        let r = o.result.as_ref().unwrap();
        assert!(r.summary.healthy(), "int8 job {} diverged", o.job.id);
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }
}

#[test]
fn predicted_cost_bounds_chunked_and_compressed_sessions() {
    // The admission bound must hold at the new run shapes too: a chunked
    // loss head and int8-compressed stored h lower both sides of the
    // inequality, and the lowered prediction must still cover the
    // lowered measurement.
    let base = base(3);
    for (chunk, ac, method) in [
        (8usize, ActCompress::None, Method::Mesp),
        (8, ActCompress::None, Method::Mebp),
        (0, ActCompress::Int8, Method::StoreH),
        (8, ActCompress::Int8, Method::StoreH),
    ] {
        let mut cfg = base.clone();
        cfg.method = method;
        cfg.loss_chunk = chunk;
        cfg.act_compress = ac;
        let spec = JobSpec::from_base(&cfg);
        let predicted = job_cost_bytes(&spec).unwrap()
            + job_weight_class(&spec).unwrap().bytes;
        let mut sess = mesp::coordinator::TrainSession::builder(cfg.clone())
            .build()
            .unwrap();
        let summary = sess.run(3).unwrap();
        let measured = summary.peak_bytes.max(sess.tracker.peak());
        assert!(
            measured <= predicted,
            "{}/chunk {chunk}/{}: measured peak {measured} B exceeds \
             predicted cost {predicted} B",
            method.name(),
            ac.name()
        );
    }
    // And chunking must actually LOWER the charged cost where the loss
    // head matters (MeSP's loss head is the full logits without it).
    let mut chunked = JobSpec::from_base(&base);
    chunked.loss_chunk = 8;
    let unchunked = JobSpec::from_base(&base);
    assert!(
        job_cost_bytes(&chunked).unwrap() < job_cost_bytes(&unchunked).unwrap(),
        "a chunked job must be cheaper to admit"
    );
}

#[test]
fn q4_resident_tag_matches_quantized_bytes() {
    // The admission charge is honest: a live q4 session's tracked
    // `weights:shared` tag (the cached host copy) equals the analytical
    // packed resident term.
    let cfg = TrainConfig {
        config: "toy".into(),
        method: Method::Mesp,
        quant: QuantMode::Q4,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = mesp::coordinator::TrainSession::builder(cfg)
        .build()
        .unwrap();
    sess.run(1).unwrap();
    let tag = sess.tracker.tag_bytes("weights:shared");
    let dims = presets::compiled("toy").unwrap();
    assert_eq!(tag, resident_weight_bytes(&dims, QuantMode::Q4));
}

#[test]
fn predicted_cost_bounds_measured_session_peak() {
    // The admission invariant hangs on this: a session's tracked peak —
    // which now includes the kernel engine's arena scratch (recompute
    // caches, GEMM packing panels) under the `scratch` tag — must stay
    // under its predicted cost for every method.
    let mut base = base(3);
    for quant in QuantMode::ALL {
        base.quant = quant;
        for method in Method::ALL {
            let mut cfg = base.clone();
            cfg.method = method;
            // A standalone session's tracker also holds the cached base
            // weights, so the bound is cost + weight class.
            let predicted = cost(&base, method) + wbytes(&base);
            let mut sess = mesp::coordinator::TrainSession::builder(cfg)
                .build()
                .unwrap();
            let summary = sess.run(3).unwrap();
            // max per-step peak; construction transients are below it
            let measured = summary.peak_bytes.max(sess.tracker.peak());
            assert!(
                measured <= predicted,
                "{}/{}: measured peak {measured} B exceeds predicted cost \
                 {predicted} B — admission would overcommit",
                method.name(),
                quant.name()
            );
            assert!(
                sess.tracker.tag_peak("scratch") > 0,
                "{}: tracked peak must include a nonzero scratch tag",
                method.name()
            );
        }
    }
}

#[test]
fn outcomes_are_in_job_id_order_with_distinct_seeds() {
    let base = base(2);
    let jobs = grid(&base, &[Method::Mesp, Method::Mebp], 5);
    let opts = FleetOptions {
        budget_bytes: u64::MAX,
        workers: 3,
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    let ids: Vec<usize> = report.outcomes.iter().map(|o| o.job.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    let mut seeds: Vec<u64> =
        report.outcomes.iter().map(|o| o.job.spec.seed).collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), 5, "every job trains on its own seed stream");
    // two jobs of the same method with different seeds see different data
    let losses_0 = &report.outcomes[0].result.as_ref().unwrap().losses;
    let losses_2 = &report.outcomes[2].result.as_ref().unwrap().losses;
    assert_ne!(losses_0, losses_2, "distinct seeds ⇒ distinct trajectories");
}

#[test]
fn oversized_job_fails_without_sinking_the_fleet() {
    let base = base(2);
    let mesp_cost = cost(&base, Method::Mesp);
    // Budget fits the shared base plus a MeSP job but not a MeBP job.
    let budget = (mesp_cost + cost(&base, Method::Mebp)) / 2 + wbytes(&base);
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 2,
        ..FleetOptions::default()
    };
    let jobs = grid(&base, &[Method::Mesp, Method::Mebp], 4);
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.completed(), 2, "{}", report.render());
    assert_eq!(report.failed(), 2);
    for o in &report.outcomes {
        match o.job.spec.method {
            Method::Mesp => assert!(o.result.is_ok()),
            _ => {
                let err = o.result.as_ref().unwrap_err();
                assert!(err.contains("exceeds the fleet budget"), "{err}");
            }
        }
    }
}

#[test]
fn priority_9_job_preempts_priority_1_job_under_one_job_budget() {
    // A long-running priority-1 job is admitted first (arrival order);
    // the priority-9 job cannot fit under a one-job budget, so the gate
    // parks the p1 job: snapshot → requeue → resume after the p9 job is
    // done. Everything completes; nobody is killed.
    let base = base(200);
    let one_job = cost(&base, Method::Mesp);
    // Shared base + one job's cost fits; a second job's cost does not.
    let budget = one_job + one_job / 2 + wbytes(&base);
    let dir = std::env::temp_dir().join("mesp-test-fleet-preempt");
    let _ = std::fs::remove_dir_all(&dir);

    let mut low = JobSpec::from_base(&base);
    low.priority = 1;
    low.steps = 200;
    let mut high = JobSpec::from_base(&base);
    high.priority = 9;
    high.steps = 5;
    let jobs = vec![Job { id: 0, spec: low }, Job { id: 1, spec: high }];

    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 2,
        preempt: true,
        snapshot_dir: Some(dir.clone()),
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(report.preempts >= 1, "p1 must be parked\n{}", report.render());
    assert!(report.resumes >= 1, "p1 must come back\n{}", report.render());
    assert!(
        report.outcomes[0].preempts >= 1,
        "the LOW-priority job is the victim\n{}",
        report.render()
    );
    assert_eq!(
        report.outcomes[1].preempts, 0,
        "the high-priority job is never preempted\n{}",
        report.render()
    );
    assert!(
        report.snapshot_peak_bytes > 0,
        "parked bytes must be charged to the snapshot tag"
    );
    // parked snapshots are consumed on resume — nothing left on disk
    let leftovers = std::fs::read_dir(&dir)
        .map(|d| d.count())
        .unwrap_or(0);
    assert_eq!(leftovers, 0, "snapshot files must be removed on resume");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn budget_schedule_shrink_parks_one_job_and_resume_stays_bitwise() {
    // Two overlapping jobs; after 10 fleet-wide steps the budget shrinks
    // to fit only one, so one parks and finishes later. Each job's final
    // state must be bitwise-identical to a standalone uninterrupted run
    // of the same spec — preemption costs time, never correctness.
    let steps = 30;
    let base = base(steps);
    let one_job = cost(&base, Method::Mesp);
    // Both jobs share one base: start with room for base + two jobs,
    // shrink to base + one and a half.
    let shrunk = one_job + one_job / 2 + wbytes(&base);
    let dir = std::env::temp_dir().join("mesp-test-fleet-shrink");
    let _ = std::fs::remove_dir_all(&dir);

    let opts = FleetOptions {
        budget_bytes: 2 * one_job + wbytes(&base),
        workers: 2,
        snapshot_dir: Some(dir.clone()),
        budget_schedule: vec![BudgetChange {
            at_step: 10,
            budget_bytes: shrunk,
        }],
        ..FleetOptions::default()
    };
    let jobs = grid(&base, &[Method::Mesp], 2);
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(report.preempts >= 1, "shrink must park a job\n{}", report.render());
    assert!(report.resumes >= 1, "{}", report.render());
    assert_eq!(report.final_budget_bytes, shrunk);
    let dims = presets::compiled("toy").unwrap();
    assert!(
        report.snapshot_peak_bytes
            >= mesp::memory::snapshot_bytes(&dims, base.optimizer),
        "parked snapshot tag must cover at least the analytical size"
    );

    for o in &report.outcomes {
        let r = o.result.as_ref().unwrap();
        assert!(r.summary.healthy(), "job {} diverged", o.job.id);
        // Standalone uninterrupted twin of the same spec.
        let cfg = o.job.spec.to_train_config(&base);
        let mut solo =
            mesp::coordinator::TrainSession::builder(cfg).build().unwrap();
        solo.run(steps).unwrap();
        let solo_losses = solo.losses();
        assert_eq!(
            r.summary.final_loss.to_bits(),
            solo_losses.last().unwrap().to_bits(),
            "job {}: fleet resume diverged from the uninterrupted run\n{}",
            o.job.id,
            report.render()
        );
        // The recorded final segment is a bitwise suffix of the solo run.
        let tail = &solo_losses[solo_losses.len() - r.losses.len()..];
        for (a, b) in r.losses.iter().zip(tail) {
            assert_eq!(a.to_bits(), b.to_bits(), "job {} segment", o.job.id);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plain_fleets_never_preempt() {
    // No --preempt, no schedule: the preemption counters stay zero even
    // under a tight budget (jobs serialize instead).
    let base = base(3);
    let one_job = cost(&base, Method::Mesp);
    // Shared base + one job fits; a second job's cost does not.
    let budget = one_job + one_job / 2 + wbytes(&base);
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 3,
        ..FleetOptions::default()
    };
    let report =
        Scheduler::run(&opts, &base, grid(&base, &[Method::Mesp], 3)).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert_eq!(report.preempts, 0);
    assert_eq!(report.resumes, 0);
    assert_eq!(report.snapshot_peak_bytes, 0);
}

/// Wait until a tracker's live bytes stop changing (the session's
/// prefetch producer tracks queued batches asynchronously until the
/// bounded channel fills and it blocks).
fn settle(t: &mesp::memory::MemoryTracker) -> u64 {
    let mut prev = t.live();
    for _ in 0..200 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        let cur = t.live();
        if cur == prev {
            return cur;
        }
        prev = cur;
    }
    prev
}

#[test]
fn fleet_aggregate_tracker_equals_sum_of_sessions() {
    // Two live sessions on children of one aggregate: the aggregate's
    // live bytes equal the sum of the sessions' live bytes.
    let aggregate = mesp::memory::MemoryTracker::new();
    let mk = |method: Method| {
        let cfg = TrainConfig {
            config: "toy".into(),
            method,
            log_every: usize::MAX,
            ..Default::default()
        };
        mesp::coordinator::TrainSession::builder(cfg)
            .tracker(aggregate.child())
            .build()
            .unwrap()
    };
    let mut a = mk(Method::Mesp);
    let mut b = mk(Method::Mebp);
    a.run(1).unwrap();
    b.run(1).unwrap();
    let (live_a, live_b) = (settle(&a.tracker), settle(&b.tracker));
    assert_eq!(aggregate.live(), live_a + live_b);
    assert!(aggregate.peak() >= a.tracker.peak().max(b.tracker.peak()));
    drop(a);
    assert_eq!(aggregate.live(), live_b);
    drop(b);
    assert_eq!(aggregate.live(), 0, "all session bytes returned");
}
