//! Convergence behaviour (paper §5.5, Fig 2): MeSP and MeBP produce the
//! SAME loss trajectory step-for-step with identical seeds; training
//! reduces loss; MeZO's trajectory differs (uncorrelated estimates).
//!
//! Runs on the `toy` compiled config to stay fast; the full Fig-2 curves
//! at `small`/`e2e100m` scale are produced by `mesp reproduce --fig 2`
//! and examples/train_100m.rs (see EXPERIMENTS.md).

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::{sweep_methods, TrainSession};
use mesp::util::stats;

fn base() -> TrainConfig {
    TrainConfig {
        config: "toy".into(),
        lr: 5e-3,
        seed: 42,
        log_every: usize::MAX,
        ..Default::default()
    }
}

#[test]
fn mesp_and_mebp_losses_identical_stepwise() {
    let runs =
        sweep_methods(&base(), &[Method::Mesp, Method::Mebp], 12).unwrap();
    let mesp = &runs[0].2;
    let mebp = &runs[1].2;
    assert_eq!(mesp.len(), 12);
    for (i, (a, b)) in mesp.iter().zip(mebp).enumerate() {
        let diff = (a - b).abs();
        assert!(
            diff < 1e-4,
            "step {i}: MeSP {a:.6} vs MeBP {b:.6} (diff {diff:.2e}) — \
             the paper's equivalence claim"
        );
    }
}

#[test]
fn training_reduces_loss() {
    let mut cfg = base();
    cfg.method = Method::Mesp;
    cfg.lr = 1e-2;
    let mut sess = TrainSession::builder(cfg).build().unwrap();
    sess.run(40).unwrap();
    let losses = sess.losses();
    let first5 = stats::mean(&losses[..5]);
    let last5 = stats::mean(&losses[losses.len() - 5..]);
    assert!(
        last5 < first5 - 0.05,
        "no learning: first5 {first5:.4} -> last5 {last5:.4}"
    );
}

#[test]
fn mezo_trajectory_differs_from_exact() {
    let runs =
        sweep_methods(&base(), &[Method::Mesp, Method::Mezo], 10).unwrap();
    let mesp = &runs[0].2;
    let mezo = &runs[1].2;
    let max_diff = mesp
        .iter()
        .zip(mezo)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff > 1e-4, "MeZO should not match exact-gradient methods");
}

#[test]
fn storeh_matches_mesp_trajectory() {
    // Table 5's two strategies are mathematically identical too.
    let runs =
        sweep_methods(&base(), &[Method::Mesp, Method::StoreH], 8).unwrap();
    for (i, (a, b)) in runs[0].2.iter().zip(&runs[1].2).enumerate() {
        assert!((a - b).abs() < 1e-4, "step {i}: {a} vs {b}");
    }
}

#[test]
fn adam_converges_faster_than_sgd_on_toy() {
    // Substrate sanity for the optimizer zoo (not a paper claim):
    // with a properly scaled lr, Adam reaches a lower loss in 30 steps.
    let mut sgd_cfg = base();
    sgd_cfg.lr = 5e-3;
    let mut adam_cfg = base();
    adam_cfg.lr = 5e-3;
    adam_cfg.optimizer = mesp::config::OptimizerKind::parse("adam").unwrap();
    let mut s1 = TrainSession::builder(sgd_cfg).build().unwrap();
    s1.run(30).unwrap();
    let mut s2 = TrainSession::builder(adam_cfg).build().unwrap();
    s2.run(30).unwrap();
    let sgd_last = stats::mean(&s1.losses()[25..]);
    let adam_last = stats::mean(&s2.losses()[25..]);
    assert!(
        adam_last < sgd_last + 0.05,
        "adam {adam_last:.4} should be competitive with sgd {sgd_last:.4}"
    );
}
