//! Session snapshot integration: the bitwise suspend/resume guarantee.
//!
//! The contract under test (ISSUE 5 / CI resume tier): a run suspended
//! at step k and resumed reproduces the uninterrupted run BITWISE —
//! identical per-step losses and identical adapter bits — for every
//! exact-gradient method × quant mode × kernel variant, at any thread
//! count, and across repeated suspend/resume cycles. Corrupted,
//! truncated and version-skewed snapshot files must be rejected with
//! actionable errors before any state is touched.

use std::path::PathBuf;

use mesp::config::{
    KernelKind, Method, OptimizerKind, QuantMode, TrainConfig,
};
use mesp::coordinator::TrainSession;
use mesp::memory::snapshot_bytes;
use mesp::persist::Snapshot;

fn cfg(
    method: Method,
    quant: QuantMode,
    kernel: KernelKind,
    steps: usize,
) -> TrainConfig {
    TrainConfig {
        config: "toy".into(),
        method,
        quant,
        kernel,
        steps,
        // Adam: the snapshot must carry both moment sets + the bias-
        // correction counter for the resumed trajectory to match.
        optimizer: OptimizerKind::parse("adam").unwrap(),
        seed: 7,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mesp-test-persist-{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every LoRA parameter of the session as raw f32 bits.
fn lora_bits(sess: &TrainSession) -> Vec<u32> {
    sess.engine
        .ctx()
        .adapters
        .lora
        .iter()
        .flat_map(|l| l.tensors.iter())
        .flat_map(|t| t.as_f32().iter().map(|x| x.to_bits()))
        .collect()
}

fn loss_bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn resume_is_bitwise_identical_across_methods_quants_kernels() {
    let dir = tmp("grid");
    let total = 4;
    let suspend_at = 2;
    for method in [Method::Mesp, Method::Mebp, Method::StoreH] {
        for quant in QuantMode::ALL {
            for kernel in KernelKind::ALL {
                let label =
                    format!("{}/{}/{}", method.name(), quant.name(), kernel.name());
                let base = cfg(method, quant, kernel, total);

                // Uninterrupted reference run.
                let mut full = TrainSession::builder(base.clone()).build().unwrap();
                full.run(total).unwrap();
                let full_losses = full.losses();
                let full_bits = lora_bits(&full);

                // Suspend at k...
                let mut part = TrainSession::builder(base.clone()).build().unwrap();
                part.run(suspend_at).unwrap();
                let early_losses = part.losses();
                let path = dir.join(format!(
                    "{}-{}-{}.snap",
                    method.name(), quant.name(), kernel.name()
                ));
                part.save_snapshot(&path).unwrap();
                drop(part);

                // ...resume and finish.
                let mut resumed = TrainSession::builder(base.clone()).resume_from(&path).build().unwrap();
                assert_eq!(resumed.steps_done(), suspend_at, "{label}");
                resumed.run(total - suspend_at).unwrap();
                let late_losses = resumed.losses();

                // The stitched trajectory equals the uninterrupted one.
                let mut stitched = early_losses.clone();
                stitched.extend_from_slice(&late_losses);
                assert_eq!(
                    loss_bits(&stitched),
                    loss_bits(&full_losses),
                    "{label}: losses diverge after resume"
                );
                assert_eq!(
                    lora_bits(&resumed),
                    full_bits,
                    "{label}: adapter bits diverge after resume"
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_is_bitwise_identical_across_thread_counts() {
    // The parallel kernel is bitwise-identical at any thread count, so a
    // session suspended under 3 kernel threads and resumed under 2 must
    // still match an uninterrupted 1-thread run.
    let dir = tmp("threads");
    let mut base = cfg(Method::Mesp, QuantMode::F32, KernelKind::Parallel, 4);
    base.threads = 1;
    let mut full = TrainSession::builder(base.clone()).build().unwrap();
    full.run(4).unwrap();

    let mut three = base.clone();
    three.threads = 3;
    let mut part = TrainSession::builder(three).build().unwrap();
    part.run(2).unwrap();
    let path = dir.join("threads.snap");
    part.save_snapshot(&path).unwrap();
    drop(part);

    let mut two = base.clone();
    two.threads = 2;
    let mut resumed =
        TrainSession::builder(two).resume_from(&path).build().unwrap();
    resumed.run(2).unwrap();
    assert_eq!(
        resumed.losses().last().unwrap().to_bits(),
        full.losses().last().unwrap().to_bits(),
        "thread count must not affect the resumed trajectory"
    );
    assert_eq!(lora_bits(&resumed), lora_bits(&full));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mezo_resume_replays_the_same_perturbation_stream() {
    // MeZO's z is derived from the step counter; restoring the counter
    // restores the SPSA stream bitwise.
    let dir = tmp("mezo");
    let base = TrainConfig {
        config: "toy".into(),
        method: Method::Mezo,
        steps: 4,
        seed: 11,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut full = TrainSession::builder(base.clone()).build().unwrap();
    full.run(4).unwrap();

    let mut part = TrainSession::builder(base.clone()).build().unwrap();
    part.run(2).unwrap();
    let path = dir.join("mezo.snap");
    part.save_snapshot(&path).unwrap();
    drop(part);
    let mut resumed = TrainSession::builder(base.clone()).resume_from(&path).build().unwrap();
    resumed.run(2).unwrap();
    assert_eq!(
        loss_bits(&resumed.losses()),
        loss_bits(&full.losses()[2..]),
        "MeZO losses diverge after resume"
    );
    assert_eq!(lora_bits(&resumed), lora_bits(&full));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repeated_suspend_resume_cycles_stay_bitwise() {
    let dir = tmp("cycles");
    let base = cfg(Method::Mesp, QuantMode::Q4, KernelKind::Tiled, 4);
    let mut full = TrainSession::builder(base.clone()).build().unwrap();
    full.run(4).unwrap();

    // 1 step → park → 1 step → park → 2 steps.
    let mut sess = TrainSession::builder(base.clone()).build().unwrap();
    for k in 1..=2u32 {
        sess.run(1).unwrap();
        let path = dir.join(format!("cycle-{k}.snap"));
        sess.save_snapshot(&path).unwrap();
        drop(sess);
        sess = TrainSession::builder(base.clone()).resume_from(&path).build().unwrap();
        assert_eq!(sess.steps_done(), k as usize);
        assert_eq!(sess.batches_consumed(), k as u64);
    }
    sess.run(2).unwrap();
    assert_eq!(
        sess.losses().last().unwrap().to_bits(),
        full.losses().last().unwrap().to_bits()
    );
    assert_eq!(lora_bits(&sess), lora_bits(&full));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_file_size_matches_the_analytical_model() {
    let dir = tmp("size");
    for (opt, name) in [
        (OptimizerKind::Sgd, "sgd"),
        (OptimizerKind::parse("momentum").unwrap(), "momentum"),
        (OptimizerKind::parse("adam").unwrap(), "adam"),
    ] {
        let mut base = cfg(Method::Mesp, QuantMode::F32, KernelKind::Tiled, 1);
        base.optimizer = opt;
        let mut sess = TrainSession::builder(base).build().unwrap();
        sess.run(1).unwrap();
        let path = dir.join(format!("{name}.snap"));
        let actual = sess.save_snapshot(&path).unwrap();
        let dims = mesp::config::presets::compiled("toy").unwrap();
        let model = snapshot_bytes(&dims, opt);
        assert!(
            actual >= model,
            "{name}: file {actual} B smaller than the payload model {model} B"
        );
        assert!(
            actual <= model + 8192,
            "{name}: file {actual} B exceeds model {model} B + 8 KB envelope \
             — per-tensor overhead grew"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_truncated_and_version_skewed_files_are_rejected() {
    let dir = tmp("reject");
    let base = cfg(Method::Mesp, QuantMode::F32, KernelKind::Tiled, 2);
    let mut sess = TrainSession::builder(base.clone()).build().unwrap();
    sess.run(1).unwrap();
    let path = dir.join("good.snap");
    sess.save_snapshot(&path).unwrap();
    drop(sess);
    let good = std::fs::read(&path).unwrap();

    let expect_err = |name: &str, bytes: &[u8], needle: &str| {
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        let err = TrainSession::builder(base.clone())
            .resume_from(&p)
            .build()
            .err()
            .unwrap_or_else(|| panic!("{name} must be rejected"))
            .to_string();
        assert!(err.contains(needle), "{name}: '{err}' lacks '{needle}'");
    };

    // flipped payload byte → checksum
    let mut corrupt = good.clone();
    let mid = 28 + (good.len() - 28) / 2;
    corrupt[mid] ^= 0x10;
    expect_err("corrupt.snap", &corrupt, "checksum mismatch");

    // truncated file → truncation
    expect_err("short.snap", &good[..good.len() / 2], "truncated");
    expect_err("header-only.snap", &good[..20], "truncated");

    // wrong version → version error naming both versions
    let mut vskew = good.clone();
    vskew[8..12].copy_from_slice(&9u32.to_le_bytes());
    expect_err("vskew.snap", &vskew, "unsupported snapshot version 9");

    // not a snapshot at all (long enough to clear the header check)
    expect_err(
        "junk.snap",
        b"definitely not a snapshot, just forty-odd bytes of text",
        "bad magic",
    );

    // missing file
    let err = TrainSession::builder(base.clone())
        .resume_from(dir.join("nope.snap"))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("read snapshot"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn weight_fingerprint_and_rng_stream_mismatches_refuse_to_resume() {
    let dir = tmp("mismatch");
    let base = cfg(Method::Mesp, QuantMode::F32, KernelKind::Tiled, 2);
    let mut sess = TrainSession::builder(base.clone()).build().unwrap();
    sess.run(1).unwrap();
    let snap = sess.snapshot();
    drop(sess);

    // Tampered base-weight fingerprint: the regenerated model no longer
    // matches what the adapters were trained against.
    let mut bad = snap.clone();
    bad.weights_fingerprint ^= 1;
    let p = dir.join("fp.snap");
    bad.save(&p).unwrap();
    let err = TrainSession::builder(base.clone())
        .resume_from(&p)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("fingerprint"), "{err}");

    // Tampered seed: the stored derive-stream seeds no longer match the
    // derivation for the claimed seed.
    let mut bad = snap.clone();
    bad.seed ^= 0xff;
    let p = dir.join("seed.snap");
    bad.save(&p).unwrap();
    let err = TrainSession::builder(base.clone())
        .resume_from(&p)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("RNG stream"), "{err}");

    // Tampered shape: adapter tensors from a different architecture.
    let mut bad = snap.clone();
    bad.lora.pop();
    let p = dir.join("shape.snap");
    bad.save(&p).unwrap();
    let err = TrainSession::builder(base.clone())
        .resume_from(&p)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("LoRA layers"), "{err}");

    // The untampered snapshot still restores fine.
    let p = dir.join("good.snap");
    snap.save(&p).unwrap();
    TrainSession::builder(base).resume_from(&p).build().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restore_adopts_snapshot_identity_over_flag_defaults() {
    // A store-h q4 adam snapshot resumed with a plain-default base config
    // must come back as store-h/q4/adam — the CLI contract for
    // `train --resume` (explicit conflicting flags lose, loudly
    // documented in USAGE).
    let dir = tmp("identity");
    let base = cfg(Method::StoreH, QuantMode::Q4, KernelKind::Parallel, 2);
    let mut sess = TrainSession::builder(base).build().unwrap();
    sess.run(1).unwrap();
    let path = dir.join("id.snap");
    sess.save_snapshot(&path).unwrap();
    drop(sess);

    let defaults = TrainConfig { log_every: usize::MAX, ..Default::default() };
    let resumed = TrainSession::builder(defaults)
        .resume_from(&path)
        .build()
        .unwrap();
    assert_eq!(resumed.cfg.method, Method::StoreH);
    assert_eq!(resumed.cfg.quant, QuantMode::Q4);
    assert_eq!(resumed.cfg.seed, 7);
    assert_eq!(
        resumed.cfg.optimizer,
        OptimizerKind::parse("adam").unwrap()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_roundtrips_through_encode_decode_at_session_scale() {
    // Session-produced snapshots (real adapter data, q4 config) survive
    // encode → decode bit-for-bit.
    let base = cfg(Method::Mesp, QuantMode::Q4, KernelKind::Tiled, 2);
    let mut sess = TrainSession::builder(base).build().unwrap();
    sess.run(2).unwrap();
    let snap = sess.snapshot();
    let back = Snapshot::decode(&snap.encode()).unwrap();
    assert_eq!(back.step, 2);
    assert_eq!(back.batches_consumed, 2);
    assert_eq!(back.weights_fingerprint, snap.weights_fingerprint);
    for (a, b) in snap.lora.iter().flatten().zip(back.lora.iter().flatten()) {
        assert_eq!(a.shape, b.shape);
        assert!(a
            .as_f32()
            .iter()
            .zip(b.as_f32())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}
