//! Quantized-base-weights path (paper §4.5): the Rust int4 packer must be
//! bit-compatible with the scheme the backends dequantize, and the
//! in-backend dequant forward must match the f32 forward through
//! host-dequantized weights.

use std::sync::Arc;

use mesp::config::{presets, FROZEN};
use mesp::memory::MemoryTracker;
use mesp::model::{quant, ModelState};
use mesp::runtime::reference::QUANT_MATS;
use mesp::runtime::{Arg, Backend, ReferenceBackend};
use mesp::tensor::HostTensor;
use mesp::util::Rng;

#[test]
fn q4_artifact_matches_host_dequant() {
    let tracker = MemoryTracker::new();
    let dims = presets::compiled("toy").unwrap();
    let rt: Arc<dyn Backend> =
        Arc::new(ReferenceBackend::new(dims.clone(), tracker.clone()));
    if !rt.has_artifact("block_fwd_q4") {
        eprintln!("skipping: backend has no q4 artifact");
        return;
    }
    let model = ModelState::init(&dims, 3, &tracker);
    let mut rng = Rng::new(7);
    let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5,
                              &mut rng);

    // quantize the 7 projection matrices with the Rust packer
    let frozen: Vec<&HostTensor> =
        model.blocks[0].tensors.iter().map(|t| &t.value).collect();
    let by_name: std::collections::HashMap<&str, &HostTensor> =
        FROZEN.iter().copied().zip(frozen.iter().copied()).collect();
    let mut qtensors: Vec<HostTensor> = Vec::new();
    let mut deq_frozen: Vec<HostTensor> = Vec::new();
    for name in FROZEN {
        let t = by_name[name];
        if QUANT_MATS.contains(&name) {
            let (din, dout) = (t.shape[0], t.shape[1]);
            let (packed, scales) = quant::quantize(t.as_f32(), din, dout);
            deq_frozen.push(HostTensor::f32(
                &t.shape, quant::dequantize(&packed, &scales, din, dout)));
            qtensors.push(HostTensor::i32(
                &[din / 2, dout],
                packed.iter().map(|b| *b as i32).collect()));
            qtensors.push(HostTensor::f32(
                &[din / quant::GROUP, dout], scales));
        } else {
            deq_frozen.push(t.clone());
        }
    }

    // reference: f32 forward through host-dequantized weights
    let mut ref_args: Vec<Arg> = vec![Arg::Host(&x)];
    for t in &deq_frozen {
        ref_args.push(Arg::Host(t));
    }
    let lora: Vec<&HostTensor> = model.lora[0].tensors.iter().collect();
    for t in &lora {
        ref_args.push(Arg::Host(t));
    }
    let y_ref = rt.execute("block_fwd", &ref_args).unwrap()
        .into_iter().next().unwrap();

    // q4 artifact: ln1, ln2 then (packed, scales) pairs then lora
    let mut q_args: Vec<Arg> = vec![
        Arg::Host(&x), Arg::Host(by_name["ln1"]), Arg::Host(by_name["ln2"]),
    ];
    for t in &qtensors {
        q_args.push(Arg::Host(t));
    }
    for t in &lora {
        q_args.push(Arg::Host(t));
    }
    let y_q4 = rt.execute("block_fwd_q4", &q_args).unwrap()
        .into_iter().next().unwrap();

    assert_eq!(y_ref.shape, y_q4.shape);
    for (a, b) in y_ref.as_f32().iter().zip(y_q4.as_f32()) {
        assert!((a - b).abs() < 1e-4,
                "in-backend dequant diverges from host dequant: {a} vs {b}");
    }
}
