//! The q4 training-path tier (paper §4.5 made first-class): base weights
//! stay int4-packed for the whole session and every backward variant
//! runs against them.
//!
//! 1. Fused in-kernel dequantization must be BITWISE identical to a
//!    forward through host-dequantized weights, per kernel variant (the
//!    panel dequant evaluates exactly `quant::dequantize`'s expression).
//! 2. Gradient parity: MeSP ≡ store-h ≡ MeBP bitwise under q4 for every
//!    kernel variant — the paper's §4 claim survives quantization.
//! 3. Thread independence: tiled-q4 ≡ parallel-q4 bitwise at ≥2 thread
//!    counts on a config big enough to actually fan out.
//! 4. The deployment claim: q4 resident base-weight bytes are < 40% of
//!    the f32 session's, and match the analytical resident term.

use std::sync::Arc;

use mesp::config::{presets, KernelKind, Method, QuantMode, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::memory::{resident_weight_bytes, MemoryTracker};
use mesp::model::{quant, ModelSpec};
use mesp::runtime::{Arg, Backend, KernelOptions, ReferenceBackend};
use mesp::tensor::HostTensor;
use mesp::util::Rng;

fn q4_cfg(config: &str, method: Method, kernel: KernelKind, threads: usize,
          seed: u64) -> TrainConfig {
    TrainConfig {
        config: config.into(),
        method,
        kernel,
        threads,
        seed,
        quant: QuantMode::Q4,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn grads(cfg: TrainConfig) -> Vec<Vec<f32>> {
    let mut sess = TrainSession::builder(cfg).build().expect("session");
    let (batch, _g) = sess.loader.next();
    sess.engine.gradients(&batch).expect("gradients")
}

#[test]
fn q4_fused_dequant_matches_host_dequant_bitwise() {
    let dims = presets::compiled("toy").unwrap();
    for kind in KernelKind::ALL {
        let tracker = MemoryTracker::new();
        let rt: Arc<dyn Backend> = Arc::new(ReferenceBackend::with_kernels(
            dims.clone(),
            tracker.clone(),
            KernelOptions { kind, threads: 2 },
        ));
        // Same seed for both models: the q4 one holds the packed form of
        // exactly the weights the f32 one holds.
        let (qm, adapters) =
            ModelSpec::new(dims.clone(), 3, QuantMode::Q4).build(&tracker);
        let mut rng = Rng::new(7);
        let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5,
                                  &mut rng);
        let lora: Vec<HostTensor> = adapters.lora[0]
            .tensors
            .iter()
            .map(|t| HostTensor::randn(&t.shape, 0.1, &mut rng))
            .collect();

        // q4 forward: x, then the block's [ln1, ln2, (packed, scales)×7].
        let mut q_args: Vec<Arg> = vec![Arg::Host(&x)];
        for t in qm.block_tensors(0) {
            q_args.push(Arg::Host(t));
        }
        for t in &lora {
            q_args.push(Arg::Host(t));
        }
        let y_q4 = rt.execute("block_fwd_q4", &q_args).unwrap()
            .into_iter().next().unwrap();

        // Oracle: the plain f32 forward through host-dequantized weights.
        let deq_frozen = quant::dequantize_block(&dims, qm.block_tensors(0));
        let mut f_args: Vec<Arg> = vec![Arg::Host(&x)];
        for t in &deq_frozen {
            f_args.push(Arg::Host(t));
        }
        for t in &lora {
            f_args.push(Arg::Host(t));
        }
        let y_ref = rt.execute("block_fwd", &f_args).unwrap()
            .into_iter().next().unwrap();

        assert_eq!(y_ref.shape, y_q4.shape);
        assert_eq!(
            y_ref.as_f32(),
            y_q4.as_f32(),
            "kernel {}: fused dequant must be bitwise identical to the \
             host-dequant oracle",
            kind.name()
        );
    }
}

#[test]
fn q4_gradient_parity_across_methods_per_kernel() {
    for kernel in KernelKind::ALL {
        let mesp = grads(q4_cfg("toy", Method::Mesp, kernel, 1, 3));
        let storeh = grads(q4_cfg("toy", Method::StoreH, kernel, 1, 3));
        let mebp = grads(q4_cfg("toy", Method::Mebp, kernel, 1, 3));
        for (l, ((a, b), c)) in mesp.iter().zip(&storeh).zip(&mebp).enumerate() {
            assert_eq!(a, b, "kernel {} layer {l}: q4 MeSP != store-h bitwise",
                       kernel.name());
            assert_eq!(a, c, "kernel {} layer {l}: q4 MeSP != MeBP bitwise",
                       kernel.name());
        }
    }
}

#[test]
fn q4_tiled_parallel_bitwise_across_thread_counts() {
    // `small` is above PARALLEL_MIN_MADDS on its projection GEMMs, so the
    // parallel kernel genuinely fans out here.
    let tiled = grads(q4_cfg("small", Method::Mesp, KernelKind::Tiled, 1, 11));
    for threads in [2, 3] {
        let parallel = grads(q4_cfg(
            "small", Method::Mesp, KernelKind::Parallel, threads, 11,
        ));
        assert_eq!(
            tiled, parallel,
            "q4 parallel @{threads} threads must not change a single bit"
        );
    }
}

#[test]
fn q4_quantization_actually_changes_the_forward() {
    // Guard against a silent fall-back to f32 weights: quantized base
    // weights must produce (slightly) different gradients.
    let f32_grads = grads(TrainConfig {
        config: "toy".into(),
        method: Method::Mesp,
        seed: 3,
        log_every: usize::MAX,
        ..Default::default()
    });
    let q4_grads = grads(q4_cfg("toy", Method::Mesp, KernelKind::Parallel, 0, 3));
    assert_ne!(f32_grads, q4_grads, "q4 session silently ran on f32 weights");
}

#[test]
fn q4_resident_weights_under_40_percent_of_f32() {
    let resident = |quant: QuantMode| -> u64 {
        let cfg = TrainConfig {
            config: "toy".into(),
            method: Method::Mesp,
            quant,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut sess = TrainSession::builder(cfg).build().unwrap();
        sess.run(1).unwrap();
        sess.tracker.tag_bytes("weights:shared")
    };
    let f32_resident = resident(QuantMode::F32);
    let q4_resident = resident(QuantMode::Q4);
    assert!(
        q4_resident * 10 < f32_resident * 4,
        "q4 residents {q4_resident} B are not < 40% of f32 {f32_resident} B"
    );
    // ...and both match the analytical resident term admission charges.
    let dims = presets::compiled("toy").unwrap();
    assert_eq!(f32_resident, resident_weight_bytes(&dims, QuantMode::F32));
    assert_eq!(q4_resident, resident_weight_bytes(&dims, QuantMode::Q4));
}
