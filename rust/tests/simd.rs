//! SIMD micro-kernel parity (ISSUE 8):
//!
//! 1. Ragged-shape sweep — on every ISA the machine can execute, the
//!    tiled GEMM must be BITWISE identical to the scalar micro-kernel
//!    (the oracle) on shapes that exercise partial mr/nr tiles, k below
//!    one KC panel and k across several, for all three operand forms.
//! 2. q4 fused dequant — the SIMD int4 unpack inside `pack_b` must
//!    reproduce `quant::dequantize` exactly, so q4 GEMMs equal f32 GEMMs
//!    over the host-dequantized matrix bitwise on every ISA.
//! 3. Thread fan-out — `parallel::gemm` (called directly, so a 1-core CI
//!    machine still exercises real row-panel splits) stays bitwise
//!    identical to `tiled::gemm` on every ISA at several thread counts.
//!
//! Together these pin the PR-8 guarantee chain: SIMD ≡ scalar, fused-q4
//! ≡ host dequant, and parallel ≡ tiled — all at the same fixed tiles,
//! so the session-level MeSP ≡ MeBP and resume-parity suites inherit
//! bitwise stability from whichever ISA dispatch picks.

use mesp::config::KernelKind;
use mesp::memory::MemoryTracker;
use mesp::model::quant;
use mesp::runtime::kernels::{parallel, simd, tiled, tune, AView, BView, Q4View};
use mesp::runtime::{KernelOptions, Kernels};
use mesp::tensor::TensorArena;
use mesp::util::Rng;

fn engine(isa: simd::Isa) -> Kernels {
    Kernels::new(
        KernelOptions { kind: KernelKind::Tiled, threads: 1 },
        MemoryTracker::new(),
    )
    .with_isa(isa)
}

/// Shapes chosen so every packing/micro-kernel edge fires: single
/// elements, partial mr rows, partial nr columns (for both the 8- and
/// 16-wide kernels), exact tile multiples, k under one KC panel and k
/// spanning several (> MAX_KC forces multiple panels at any profile).
fn ragged_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (5, 3, 7),
        (6, 64, 16),
        (7, 33, 17),
        (13, 130, 29),
        (12, 256, 32),
        (19, 520, 23),
        (11, 700, 41),
    ]
}

#[test]
fn every_isa_matches_scalar_bitwise_on_ragged_shapes() {
    let scalar = engine(simd::Isa::Scalar);
    for isa in simd::supported() {
        let ks = engine(isa);
        assert_eq!(ks.isa(), isa);
        assert_eq!(ks.tiles(), scalar.tiles(), "parity holds at fixed tiles");
        let mut rng = Rng::new(81);
        for (m, k, n) in ragged_shapes() {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            assert_eq!(
                &scalar.matmul(&a, &b, m, k, n)[..],
                &ks.matmul(&a, &b, m, k, n)[..],
                "{}: matmul {m}x{k}x{n}",
                isa.name()
            );
            let at = rng.normal_vec(k * m, 1.0);
            assert_eq!(
                &scalar.matmul_at(&at, &b, k, m, n)[..],
                &ks.matmul_at(&at, &b, k, m, n)[..],
                "{}: matmul_at {m}x{k}x{n}",
                isa.name()
            );
            let bt = rng.normal_vec(n * k, 1.0);
            assert_eq!(
                &scalar.matmul_bt(&a, &bt, m, k, n)[..],
                &ks.matmul_bt(&a, &bt, m, k, n)[..],
                "{}: matmul_bt {m}x{k}x{n}",
                isa.name()
            );
        }
    }
}

#[test]
fn q4_fused_dequant_matches_host_dequant_bitwise_on_every_isa() {
    // k must be a GROUP multiple for the quantizer; n both ragged and
    // nr-aligned so the vectorized full-tile pack AND the scalar ragged
    // fallback run.
    for (m, k, n) in [(9, 128, 24), (6, 64, 32), (13, 192, 17), (8, 640, 48)] {
        let mut rng = Rng::new(91);
        let w = rng.normal_vec(k * n, 0.05);
        let (packed, scales) = quant::quantize(&w, k, n);
        let deq = quant::dequantize(&packed, &scales, k, n);
        let view = Q4View::new(&packed, &scales, k, n);
        let a = rng.normal_vec(m * k, 1.0);
        let g = rng.normal_vec(m * n, 1.0);
        for isa in simd::supported() {
            let ks = engine(isa);
            assert_eq!(
                &ks.matmul_q4(&a, view, m)[..],
                &ks.matmul(&a, &deq, m, k, n)[..],
                "{}: x @ W {m}x{k}x{n}",
                isa.name()
            );
            assert_eq!(
                &ks.matmul_bt_q4(&g, view, m)[..],
                &ks.matmul_bt(&g, &deq, m, n, k)[..],
                "{}: g @ Wt {m}x{k}x{n}",
                isa.name()
            );
        }
    }
}

#[test]
fn parallel_rows_split_is_bitwise_identical_to_tiled_on_every_isa() {
    // Direct parallel::gemm calls: the engine clamps --threads to the
    // core count, but the row-panel math itself is thread-count-driven,
    // so this exercises real multi-panel splits even on a 1-core runner.
    let arena = TensorArena::new(MemoryTracker::new());
    let tiles = tune::active_tiles();
    let (m, k, n) = (37, 300, 29);
    let mut rng = Rng::new(101);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    for isa in simd::supported() {
        let mut want = vec![0.0f32; m * n];
        tiled::gemm(
            &arena, isa, tiles, AView::Rows(&a), BView::Rows(&b), 0, m, k, n, &mut want,
        );
        for threads in [2, 3, 5, 16] {
            let mut got = vec![0.0f32; m * n];
            parallel::gemm(
                &arena, threads, isa, tiles,
                AView::Rows(&a), BView::Rows(&b), m, k, n, &mut got,
            );
            assert_eq!(
                want, got,
                "{}: threads={threads} changed bits",
                isa.name()
            );
        }
    }
}

#[test]
fn q4_parallel_is_bitwise_identical_to_tiled_on_every_isa() {
    let arena = TensorArena::new(MemoryTracker::new());
    let tiles = tune::active_tiles();
    let (m, k, n) = (25, 128, 40);
    let mut rng = Rng::new(111);
    let w = rng.normal_vec(k * n, 0.05);
    let (packed, scales) = quant::quantize(&w, k, n);
    let a = rng.normal_vec(m * k, 1.0);
    for isa in simd::supported() {
        for b in [
            BView::Q4(Q4View::new(&packed, &scales, k, n)),
            // transposed use: out is [m, k], depth n
            BView::Q4T(Q4View::new(&packed, &scales, k, n)),
        ] {
            let (depth, cols) = match b {
                BView::Q4T(_) => (n, k),
                _ => (k, n),
            };
            let x = if depth == k { &a } else { &w }; // any [m, depth] operand
            let x = &x[..m * depth];
            let mut want = vec![0.0f32; m * cols];
            tiled::gemm(
                &arena, isa, tiles, AView::Rows(x), b, 0, m, depth, cols, &mut want,
            );
            for threads in [2, 4] {
                let mut got = vec![0.0f32; m * cols];
                parallel::gemm(
                    &arena, threads, isa, tiles, AView::Rows(x), b, m, depth, cols,
                    &mut got,
                );
                assert_eq!(want, got, "{}: q4 threads={threads}", isa.name());
            }
        }
    }
}
