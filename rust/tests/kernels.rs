//! Kernel-engine integration (ISSUE 3):
//!
//! 1. Property-style shape sweep — tiled and parallel GEMM must match the
//!    naive oracle within tight tolerance on odd / non-tile-multiple
//!    shapes, and parallel must be BITWISE identical to tiled at any
//!    thread count.
//! 2. Gradient parity per kernel variant: MeSP ↔ MeBP ↔ store-h stay
//!    bitwise identical *within* each variant (the paper's §4 claim must
//!    survive the kernel swap).
//! 3. Scratch accounting: a training step's tracked peak includes a
//!    nonzero `scratch` tag, and the analytical model's scratch term (at
//!    tracked widths) bounds the measured arena high-water mark.
//! 4. FLOP accounting: the measured per-artifact counter equals the
//!    analytical inventory `mesp inspect` reports.

use mesp::config::{
    presets, KernelKind, Method, OptimizerKind, QuantMode, TrainConfig,
};
use mesp::coordinator::TrainSession;
use mesp::memory::model as memmodel;
use mesp::memory::{MemoryTracker, Widths};
use mesp::model::ModelSpec;
use mesp::runtime::{Arg, Backend, KernelOptions, Kernels, ReferenceBackend};
use mesp::tensor::HostTensor;
use mesp::util::Rng;

fn engine(kind: KernelKind, threads: usize) -> Kernels {
    Kernels::new(KernelOptions { kind, threads }, MemoryTracker::new())
}

#[test]
fn shape_sweep_tiled_and_parallel_match_naive() {
    // 60 random shapes, biased to odd and non-tile-multiple dims.
    let naive = engine(KernelKind::Naive, 1);
    let tiled = engine(KernelKind::Tiled, 1);
    let parallel = engine(KernelKind::Parallel, 3);
    let mut rng = Rng::new(42);
    for case in 0..60u64 {
        let m = 1 + rng.below(77);
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(77);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let tol = 1e-5f32 * (k as f32).sqrt().max(1.0);
        let close = |x: &[f32], y: &[f32], what: &str| {
            for (i, (p, q)) in x.iter().zip(y).enumerate() {
                assert!(
                    (p - q).abs() <= tol * p.abs().max(1.0),
                    "case {case} {what} ({m}x{k}x{n}) elem {i}: {p} vs {q}"
                );
            }
        };
        // a @ b
        let want = naive.matmul(&a, &b, m, k, n);
        let got_t = tiled.matmul(&a, &b, m, k, n);
        close(&want, &got_t, "matmul/tiled");
        let got_p = parallel.matmul(&a, &b, m, k, n);
        assert_eq!(&got_t[..], &got_p[..], "case {case}: parallel != tiled bitwise");
        // aᵀ @ b  (a reinterpreted as [k=m, m=k] is wrong; use real dims)
        let at = rng.normal_vec(k * m, 1.0);
        close(
            &naive.matmul_at(&at, &b, k, m, n),
            &tiled.matmul_at(&at, &b, k, m, n),
            "matmul_at/tiled",
        );
        // a @ bᵀ
        let bt = rng.normal_vec(n * k, 1.0);
        close(
            &naive.matmul_bt(&a, &bt, m, k, n),
            &tiled.matmul_bt(&a, &bt, m, k, n),
            "matmul_bt/tiled",
        );
        let p_at = parallel.matmul_at(&at, &b, k, m, n);
        let t_at = tiled.matmul_at(&at, &b, k, m, n);
        assert_eq!(&t_at[..], &p_at[..], "case {case}: at parallel != tiled");
    }
}

#[test]
fn zeros_do_not_change_tiled_results() {
    // The naive oracle's zero-skip is a correctness no-op; tiled/parallel
    // must agree on inputs riddled with exact zeros (fresh LoRA B state).
    let naive = engine(KernelKind::Naive, 1);
    let tiled = engine(KernelKind::Tiled, 1);
    let (m, k, n) = (9, 33, 14);
    let mut rng = Rng::new(5);
    let mut a = rng.normal_vec(m * k, 1.0);
    for (i, v) in a.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0;
        }
    }
    let b = vec![0.0f32; k * n]; // fully zero right operand
    assert_eq!(&naive.matmul(&a, &b, m, k, n)[..], &vec![0.0f32; m * n][..]);
    assert_eq!(&tiled.matmul(&a, &b, m, k, n)[..], &vec![0.0f32; m * n][..]);
    let b2 = rng.normal_vec(k * n, 1.0);
    let want = naive.matmul(&a, &b2, m, k, n);
    let got = tiled.matmul(&a, &b2, m, k, n);
    for (p, q) in want.iter().zip(&got[..]) {
        assert!((p - q).abs() <= 1e-4 * p.abs().max(1.0));
    }
}

fn grads_for(method: Method, kernel: KernelKind, seed: u64) -> Vec<Vec<f32>> {
    let cfg = TrainConfig {
        config: "toy".into(),
        method,
        kernel,
        seed,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().expect("session");
    let (batch, _g) = sess.loader.next();
    sess.engine.gradients(&batch).expect("gradients")
}

#[test]
fn mesp_mebp_storeh_bitwise_identical_within_each_kernel() {
    for kernel in KernelKind::ALL {
        let mesp = grads_for(Method::Mesp, kernel, 3);
        let mebp = grads_for(Method::Mebp, kernel, 3);
        let storeh = grads_for(Method::StoreH, kernel, 3);
        for (l, ((a, b), c)) in mesp.iter().zip(&mebp).zip(&storeh).enumerate() {
            assert_eq!(
                a, b,
                "kernel {} layer {l}: MeSP != MeBP bitwise",
                kernel.name()
            );
            assert_eq!(
                a, c,
                "kernel {} layer {l}: MeSP != store-h bitwise",
                kernel.name()
            );
        }
    }
}

#[test]
fn parallel_session_gradients_match_tiled_bitwise() {
    // Thread-count independence end to end, not just per GEMM.
    let tiled = grads_for(Method::Mesp, KernelKind::Tiled, 11);
    let parallel = grads_for(Method::Mesp, KernelKind::Parallel, 11);
    assert_eq!(tiled, parallel, "parallel must not change a single bit");
}

#[test]
fn step_tracks_scratch_and_model_bounds_it() {
    for method in [Method::Mesp, Method::Mebp, Method::Mezo] {
        let cfg = TrainConfig {
            config: "toy".into(),
            method,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut sess = TrainSession::builder(cfg).build().unwrap();
        sess.run(2).unwrap();
        let measured = sess.tracker.tag_peak("scratch");
        assert!(
            measured > 0,
            "{}: tracked peak must include a nonzero scratch tag",
            method.name()
        );
        let dims = presets::compiled("toy").unwrap();
        let predicted = memmodel::peak(
            method, &dims, OptimizerKind::Sgd, Widths::tracked(),
        )
        .scratch;
        assert!(
            measured <= predicted,
            "{}: measured scratch {measured} B exceeds the model's scratch \
             term {predicted} B",
            method.name()
        );
    }
}

#[test]
fn measured_flops_equal_analytical_inventory() {
    let dims = presets::compiled("toy").unwrap();
    let tracker = MemoryTracker::new();
    let be = ReferenceBackend::new(dims.clone(), tracker.clone());
    let (model, adapters) =
        ModelSpec::new(dims.clone(), 17, QuantMode::F32).build(&tracker);
    let mut rng = Rng::new(23);
    let x = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5, &mut rng);
    let frozen: Vec<HostTensor> = model.block_tensors(0).to_vec();
    let lora: Vec<HostTensor> = adapters.lora[0]
        .tensors
        .iter()
        .map(|t| HostTensor::randn(&t.shape, 0.1, &mut rng))
        .collect();
    let mut args: Vec<Arg> = vec![Arg::Host(&x)];
    for t in &frozen {
        args.push(Arg::Host(t));
    }
    for t in &lora {
        args.push(Arg::Host(t));
    }
    be.execute("block_fwd", &args).unwrap();
    let g = HostTensor::randn(&[dims.batch, dims.seq, dims.d_model], 0.5, &mut rng);
    let mut bwd_args: Vec<Arg> = vec![Arg::Host(&x), Arg::Host(&g)];
    for t in &frozen {
        bwd_args.push(Arg::Host(t));
    }
    for t in &lora {
        bwd_args.push(Arg::Host(t));
    }
    be.execute("block_bwd_mesp", &bwd_args).unwrap();

    for name in ["block_fwd", "block_bwd_mesp"] {
        let stats = be
            .exec_stats()
            .into_iter()
            .find(|(n, _)| n == name)
            .unwrap()
            .1;
        let analytic = mesp::runtime::kernels::flops::artifact(&dims, name);
        assert_eq!(
            stats.flops, analytic,
            "{name}: measured flops diverged from the analytical inventory"
        );
        assert!(stats.flops > 0);
        assert!(stats.gflops_per_sec() > 0.0);
    }
}

#[test]
fn session_exec_stats_report_flops() {
    let cfg = TrainConfig {
        config: "toy".into(),
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().unwrap();
    sess.run(1).unwrap();
    let stats = sess.engine.ctx().rt.exec_stats();
    assert!(!stats.is_empty());
    let bwd = stats.iter().find(|(n, _)| n == "block_bwd_mesp").unwrap();
    assert!(bwd.1.flops > 0, "backward must report FLOPs");
    let table = mesp::metrics::exec_stats_table(&stats);
    assert!(table.contains("GFLOP/s"), "{table}");
}
