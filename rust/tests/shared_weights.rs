//! Shared base-weight cache integration: the PR-6 economics end to end.
//! A fleet whose budget is sized for TWO private-weight jobs overlaps
//! ten-plus jobs that share one cached frozen base; the cache evicts a
//! base when its last holder drops and rebuilds it bit-identically on
//! demand; and a snapshot restore re-attaches to the live cached base —
//! charged zero extra bytes — while staying bitwise-equal to an
//! uninterrupted run, in both f32 and q4 resident precision.

use std::sync::Arc;

use mesp::config::{Method, QuantMode, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::fleet::{grid, job_cost_bytes, job_weight_class, FleetOptions, JobSpec, Scheduler};
use mesp::memory::MemoryTracker;
use mesp::model::WeightCache;

/// The weight-dominated demo config: ~128 MB frozen base over a per-job
/// activation cost of a few MB (see `presets::basebound`).
fn basebound(steps: usize) -> TrainConfig {
    TrainConfig {
        config: "basebound".into(),
        method: Method::Mesp,
        steps,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn toy(quant: QuantMode) -> TrainConfig {
    TrainConfig {
        config: "toy".into(),
        method: Method::Mesp,
        quant,
        log_every: usize::MAX,
        ..Default::default()
    }
}

fn lora_bits(sess: &TrainSession) -> Vec<u32> {
    sess.engine
        .ctx()
        .adapters
        .lora
        .iter()
        .flat_map(|l| l.tensors.iter())
        .flat_map(|t| t.as_f32().iter().map(|x| x.to_bits()))
        .collect()
}

#[test]
fn two_private_job_budget_overlaps_ten_shared_jobs() {
    // The headline scenario: all grid jobs pin the base model stream, so
    // they form ONE weight class — the budget pays the ~128 MB base once
    // and each extra job costs only its activations.
    let base = basebound(4);
    let spec = JobSpec::from_base(&base);
    let cost = job_cost_bytes(&spec).unwrap();
    let w = job_weight_class(&spec).unwrap().bytes;
    let n = 12;
    let budget = 2 * (cost + w);
    // The acceptance floor: at least TEN shared jobs must fit the budget
    // that two private-weight jobs would exhaust. (All 12 fit on typical
    // machines; the per-core packing term can shave the tail on very wide
    // ones, which the ≥10 assertions below absorb.)
    assert!(
        10 * cost + w <= budget,
        "premise: 10 shared jobs ({cost} B each + one {w} B base) must fit \
         a two-private-job budget {budget} B — basebound is meant to be \
         weight-dominated"
    );

    let jobs = grid(&base, &[Method::Mesp], n);
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: n,
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert!(
        report.peak_concurrent >= 10,
        "a two-private-job budget must overlap ≥10 shared-base jobs, got \
         {}\n{}",
        report.peak_concurrent,
        report.render()
    );
    assert!(
        report.aggregate_peak <= budget,
        "aggregate tracked peak {} exceeds budget {}",
        report.aggregate_peak,
        budget
    );
    assert_eq!(
        report.weight_shared_admissions,
        n - 1,
        "first admission pays the base, the other {} attach free\n{}",
        n - 1,
        report.render()
    );
    assert_eq!(
        report.shared_weight_peak_bytes,
        w,
        "exactly one resident copy of the shared base\n{}",
        report.render()
    );
}

#[test]
fn same_budget_admits_only_two_private_weight_jobs() {
    // Contrast run: identical budget, but each job pins its OWN model
    // seed — three distinct weight classes, each paying the full base.
    let base = basebound(2);
    let spec = JobSpec::from_base(&base);
    let cost = job_cost_bytes(&spec).unwrap();
    let w = job_weight_class(&spec).unwrap().bytes;
    let budget = 2 * (cost + w);

    let mut jobs = grid(&base, &[Method::Mesp], 3);
    for j in &mut jobs {
        j.spec.model_seed = Some(0xba5e_0000 + j.id as u64);
    }
    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 3,
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, &base, jobs).unwrap();
    assert_eq!(report.failed(), 0, "{}", report.render());
    assert_eq!(
        report.peak_concurrent,
        2,
        "private-weight jobs must pay the base each — only two fit\n{}",
        report.render()
    );
    assert_eq!(report.weight_shared_admissions, 0, "nothing to attach to");
    assert!(
        report.shared_weight_peak_bytes >= 2 * w,
        "two private bases resident at the peak\n{}",
        report.render()
    );
}

#[test]
fn cache_evicts_on_last_drop_and_rebuilds() {
    let tracker = MemoryTracker::new();
    let cache = WeightCache::new(tracker.clone());
    let cfg = toy(QuantMode::F32);

    let s1 = TrainSession::builder(cfg.clone())
        .weight_cache(cache.clone())
        .build()
        .unwrap();
    let charged = tracker.tag_bytes("weights:shared");
    assert!(charged > 0, "building the base must charge the cache tracker");
    assert_eq!(cache.live_entries(), 1);

    // Second same-base session: shares the Arc, charges nothing.
    let s2 = TrainSession::builder(cfg.clone())
        .weight_cache(cache.clone())
        .build()
        .unwrap();
    assert!(
        Arc::ptr_eq(&s1.engine.ctx().frozen, &s2.engine.ctx().frozen),
        "same spec must intern to one FrozenModel"
    );
    assert_eq!(tracker.tag_bytes("weights:shared"), charged);
    assert_eq!(cache.live_entries(), 1);
    let fp = s1.engine.ctx().frozen.fingerprint();

    // Last holder drops: the entry dies and the bytes come back.
    drop(s1);
    assert_eq!(cache.live_entries(), 1, "s2 still holds the base");
    drop(s2);
    assert_eq!(cache.live_entries(), 0, "dead entries are pruned");
    assert_eq!(tracker.tag_bytes("weights:shared"), 0);

    // Rebuild after eviction: same charge, bit-identical weights.
    let s3 = TrainSession::builder(cfg)
        .weight_cache(cache.clone())
        .build()
        .unwrap();
    assert_eq!(tracker.tag_bytes("weights:shared"), charged);
    assert_eq!(cache.live_entries(), 1);
    assert_eq!(
        s3.engine.ctx().frozen.fingerprint(),
        fp,
        "regenerated base must be bit-identical"
    );
}

fn resume_attaches_to_cache_and_stays_bitwise(quant: QuantMode) {
    let total = 12;
    let cut = 5;
    let cfg = toy(quant);

    // Uninterrupted twin.
    let mut solo = TrainSession::builder(cfg.clone()).build().unwrap();
    solo.run(total).unwrap();
    let solo_losses = solo.losses();
    let solo_bits = lora_bits(&solo);
    drop(solo);

    // Interrupted run, suspended at `cut` on a shared cache.
    let dir = std::env::temp_dir().join("mesp-test-shared-weights");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("resume-{}.snap", quant.name()));
    let tracker = MemoryTracker::new();
    let cache = WeightCache::new(tracker.clone());
    let mut first = TrainSession::builder(cfg.clone())
        .weight_cache(cache.clone())
        .build()
        .unwrap();
    first.run(cut).unwrap();
    first.save_snapshot(&path).unwrap();
    let charged = tracker.tag_bytes("weights:shared");

    // Restore while the suspended session still holds the base: the
    // resumed session must ATTACH to the live cached FrozenModel —
    // pointer-equal, zero extra weight bytes — not regenerate it.
    let mut resumed = TrainSession::builder(cfg.clone())
        .weight_cache(cache.clone())
        .resume_from(&path)
        .build()
        .unwrap();
    assert!(
        Arc::ptr_eq(&first.engine.ctx().frozen, &resumed.engine.ctx().frozen),
        "restore must re-attach to the cached base"
    );
    assert_eq!(
        tracker.tag_bytes("weights:shared"),
        charged,
        "re-attaching must not charge a second copy"
    );
    assert_eq!(cache.live_entries(), 1);
    drop(first);

    // The continued run is bitwise-identical to the uninterrupted one.
    resumed.run(total - cut).unwrap();
    let tail = resumed.losses();
    assert_eq!(tail.len(), total - cut);
    for (i, (a, b)) in tail.iter().zip(&solo_losses[cut..]).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{}: step {} diverged after cache re-attach: {a} vs {b}",
            quant.name(),
            cut + i
        );
    }
    assert_eq!(
        lora_bits(&resumed),
        solo_bits,
        "{}: final adapters must match the uninterrupted run bitwise",
        quant.name()
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_resume_attaches_to_cache_bitwise_f32() {
    resume_attaches_to_cache_and_stays_bitwise(QuantMode::F32);
}

#[test]
fn snapshot_resume_attaches_to_cache_bitwise_q4() {
    resume_attaches_to_cache_and_stays_bitwise(QuantMode::Q4);
}
