//! Memory behaviour on REAL runs: the tracker-measured peaks must show
//! the paper's ordering (MeSP < store-h < MeBP for held tensors), the
//! analytical model must be consistent with the tracker where they
//! describe the same tensors, and spill mode must bound checkpoint RAM.

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::memory::model as memmodel;
use mesp::memory::{MemoryTracker, Widths};

fn measured_peak(config: &str, method: Method) -> (u64, u64) {
    let cfg = TrainConfig {
        config: config.into(),
        method,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().unwrap();
    // warm step compiles executables; measure the second step
    sess.run(2).unwrap();
    let s = &sess.metrics.history[1];
    (s.peak_bytes, s.live_after)
}

#[test]
fn measured_ordering_matches_paper() {
    // The paper's Tables 1 & 5, measured live on this runtime: MeBP's
    // held residuals dominate, store-h sits between, MeSP is lowest.
    let (mesp, _) = measured_peak("toy", Method::Mesp);
    let (mebp, _) = measured_peak("toy", Method::Mebp);
    let (storeh, _) = measured_peak("toy", Method::StoreH);
    assert!(mesp < storeh, "MeSP {mesp} !< store-h {storeh}");
    assert!(storeh < mebp, "store-h {storeh} !< MeBP {mebp}");
}

#[test]
fn mesp_reduction_vs_mebp_is_substantial() {
    // Compare step-TRANSIENT peaks (peak − always-live baseline): the
    // paper's phys_footprint excludes the mmap'd base weights, so the
    // comparable measured quantity here excludes our always-live f32
    // weights. This is the activation memory MeSP's schedule is about.
    let (mesp_peak, mesp_live) = measured_peak("small", Method::Mesp);
    let (mebp_peak, mebp_live) = measured_peak("small", Method::Mebp);
    let mesp_t = (mesp_peak - mesp_live) as f64;
    let mebp_t = (mebp_peak - mebp_live) as f64;
    let red = 100.0 * (1.0 - mesp_t / mebp_t);
    // paper band at Qwen scale is 42-62%
    assert!(red > 35.0, "measured transient reduction only {red:.1}% \
            (MeSP {mesp_t} vs MeBP {mebp_t} bytes)");
}

#[test]
fn live_after_step_is_params_only() {
    // After a step completes, only weights/params/optimizer remain live —
    // the paper's "explicitly deallocate all intermediates".
    let cfg = TrainConfig {
        config: "toy".into(),
        method: Method::Mesp,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().unwrap();
    let baseline = sess.tracker.live(); // weights + params (+ queued batches)
    sess.run(3).unwrap();
    let after = sess.metrics.history[2].live_after;
    // allow the prefetch queue (4 batches ≈ tiny) but nothing blockwise
    assert!(
        after <= baseline + 16 * 1024,
        "leak: baseline {baseline} -> after {after}"
    );
}

#[test]
fn analytical_model_consistent_with_tracker_ordering() {
    // Evaluate the model at the toy dims in tracked widths and check it
    // predicts the same ordering the tracker measures.
    let cfg = TrainConfig { config: "toy".into(), log_every: usize::MAX,
                            ..Default::default() };
    let sess = TrainSession::builder(cfg).build().unwrap();
    let dims = sess.engine.ctx().rt.dims().clone();
    let w = Widths::tracked();
    let opt = mesp::config::OptimizerKind::Sgd;
    let model_mesp = memmodel::peak(Method::Mesp, &dims, opt, w).total();
    let model_mebp = memmodel::peak(Method::Mebp, &dims, opt, w).total();
    assert!(model_mesp < model_mebp);
    let (real_mesp, _) = measured_peak("toy", Method::Mesp);
    let (real_mebp, _) = measured_peak("toy", Method::Mebp);
    // both views must agree on the direction AND rough magnitude of the
    // gap (within a factor of ~3 — the model includes dequant terms the
    // runtime doesn't have, the runtime has exec I/O the model folds in)
    let model_gap = (model_mebp - model_mesp) as f64;
    let real_gap = (real_mebp - real_mesp) as f64;
    assert!(real_gap > 0.0);
    let ratio = model_gap / real_gap;
    assert!((0.2..5.0).contains(&ratio),
            "model gap {model_gap} vs real gap {real_gap} (ratio {ratio:.2})");
}

#[test]
fn concurrent_tag_breakdown_is_exact() {
    // 8 threads × 200 rounds of tagged alloc/free; the final breakdown
    // must account every surviving guard exactly, per tag.
    let t = MemoryTracker::new();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let t = t.clone();
            std::thread::spawn(move || {
                let tag = if i % 2 == 0 { "even" } else { "odd" };
                let mut kept = Vec::new();
                for r in 0..200u64 {
                    let g = t.track(tag, 10);
                    if r % 4 == 0 {
                        kept.push(g); // 50 survive per thread
                    }
                }
                kept
            })
        })
        .collect();
    let kept: Vec<_> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert_eq!(kept.len(), 8 * 50);
    assert_eq!(t.live(), 8 * 50 * 10);
    let bd = t.breakdown();
    assert_eq!(
        bd,
        vec![("even".to_string(), 2000), ("odd".to_string(), 2000)]
    );
    drop(kept);
    assert_eq!(t.live(), 0);
    assert!(t.breakdown().is_empty(), "all tags drained to zero");
}

#[test]
fn concurrent_timeline_is_ordered_and_consistent() {
    // Events from racing threads must have strictly increasing sequence
    // numbers, and replaying the deltas must reproduce every recorded
    // live value (the mutex serializes alloc/free atomically).
    let t = MemoryTracker::with_timeline();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let t = t.clone();
            std::thread::spawn(move || {
                for _ in 0..100 {
                    let _g = t.track("x", 3);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let tl = t.timeline();
    assert_eq!(tl.len(), 4 * 100 * 2, "one alloc + one free per track");
    let mut live = 0i64;
    for (i, ev) in tl.iter().enumerate() {
        if i > 0 {
            assert!(ev.seq > tl[i - 1].seq, "seq must strictly increase");
        }
        live += ev.delta;
        assert_eq!(live as u64, ev.live, "event {i}: replay mismatch");
    }
    assert_eq!(live, 0);
}

#[test]
fn session_trackers_isolated_while_aggregate_sums() {
    // The fleet invariant, exercised raw: per-session child trackers
    // stay isolated from each other, while the aggregate parent's live
    // bytes equal the sum of live bytes across all children at every
    // quiescent point.
    let aggregate = MemoryTracker::new();
    let children: Vec<MemoryTracker> =
        (0..4).map(|_| aggregate.child()).collect();
    let handles: Vec<_> = children
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let c = c.clone();
            std::thread::spawn(move || {
                let mut kept = Vec::new();
                for r in 0..100u64 {
                    let g = c.track("sess", (i as u64 + 1) * 8);
                    if r % 2 == 0 {
                        kept.push(g);
                    }
                }
                kept
            })
        })
        .collect();
    let guards: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, c) in children.iter().enumerate() {
        assert_eq!(
            c.live(),
            50 * (i as u64 + 1) * 8,
            "child {i} sees only its own bytes"
        );
    }
    let sum: u64 = children.iter().map(|c| c.live()).sum();
    assert_eq!(aggregate.live(), sum, "aggregate == Σ children");
    assert!(aggregate.peak() >= sum);
    drop(guards);
    assert_eq!(aggregate.live(), 0);
    for c in &children {
        assert_eq!(c.live(), 0);
    }
}

#[test]
fn mezo_holds_no_checkpoints() {
    let (_, _live) = measured_peak("toy", Method::Mezo);
    let cfg = TrainConfig {
        config: "toy".into(),
        method: Method::Mezo,
        log_every: usize::MAX,
        ..Default::default()
    };
    let mut sess = TrainSession::builder(cfg).build().unwrap();
    sess.run(1).unwrap();
    for (tag, bytes) in sess.tracker.breakdown() {
        assert!(
            !tag.starts_with("ckpt"),
            "MeZO must not hold checkpoints ({tag}: {bytes})"
        );
    }
}
