//! Store-h ablation — the paper's Table 5 counterfactual.
//!
//! Identical to MeSP except the seven LoRA intermediates h = xA of EVERY
//! block are stored at forward time (`block_fwd_saveh`) and consumed at
//! backward time (`block_bwd_storeh`) instead of being recomputed. The
//! stored h tensors of all L×7 sites live from forward until that block's
//! backward — the accumulation the paper's §5.7 measures (and rejects in
//! favour of recomputation).

use crate::data::Batch;
use crate::memory::Guard;
use crate::tensor::HostTensor;

use super::common::EngineCtx;
use super::{CheckpointStore, Engine, StepStats};

pub struct StoreHEngine {
    ctx: EngineCtx,
    store: CheckpointStore,
    /// Per-layer stored h tensors + their tracking guard.
    saved_h: Vec<Option<(Vec<HostTensor>, Guard)>>,
}

impl StoreHEngine {
    pub fn new(ctx: EngineCtx) -> anyhow::Result<Self> {
        anyhow::ensure!(
            ctx.rt.has_artifact(&ctx.artifact("block_fwd_saveh"))
                && ctx.rt.has_artifact(&ctx.artifact("block_bwd_storeh")),
            "config '{}' lacks the store-h ablation artifacts",
            ctx.rt.dims().name
        );
        ctx.warmup(&["embed_fwd", "block_fwd_saveh", "block_bwd_storeh",
                     "lm_loss_grad"])?;
        let store = CheckpointStore::new(ctx.tracker.clone(), ctx.spill_limit);
        let n = ctx.rt.dims().n_layers;
        Ok(StoreHEngine {
            ctx,
            store,
            saved_h: (0..n).map(|_| None).collect(),
        })
    }

    /// Forward that stores checkpoints AND h×7 per block.
    fn forward(&mut self, batch: &Batch) -> anyhow::Result<HostTensor> {
        use crate::runtime::Arg;
        let ctx = &self.ctx;
        let _sp = ctx.trace.span("fwd", "train");
        let fwd = ctx.artifact("block_fwd_saveh");
        let mut x = ctx.embed(&batch.tokens)?;
        for l in 0..ctx.rt.dims().n_layers {
            let mut args: Vec<Arg> = vec![Arg::Host(&x)];
            args.extend(ctx.block_args_mixed(l));
            let mut outs = ctx.rt.execute(&fwd, &args)?;
            drop(args);
            let hs: Vec<HostTensor> = outs.drain(1..).collect();
            let h_bytes: u64 = hs.iter().map(|t| t.bytes()).sum();
            let guard = ctx.tracker.track("storeh:h", h_bytes);
            self.saved_h[l] = Some((hs, guard));
            let y = outs.pop().unwrap();
            self.store.store(l, x)?;
            x = y;
        }
        Ok(x)
    }

    fn backward<F>(
        ctx: &mut EngineCtx,
        store: &mut CheckpointStore,
        saved_h: &mut [Option<(Vec<HostTensor>, Guard)>],
        mut g: HostTensor,
        mut on_block: F,
    ) -> anyhow::Result<()>
    where
        F: FnMut(&mut EngineCtx, usize, Vec<HostTensor>)
            -> anyhow::Result<HostTensor>,
    {
        use crate::runtime::Arg;
        let _sp = ctx.trace.span("bwd", "train");
        let bwd = ctx.artifact("block_bwd_storeh");
        for l in (0..ctx.rt.dims().n_layers).rev() {
            let x = store.take(l)?;
            let (hs, h_guard) = saved_h[l]
                .take()
                .ok_or_else(|| anyhow::anyhow!("h for layer {l} not saved"))?;
            let mut args: Vec<Arg> = vec![Arg::Host(&x), Arg::Host(&g)];
            args.extend(hs.iter().map(Arg::Host));
            args.extend(ctx.block_args_mixed(l));
            let outs = ctx.rt.execute(&bwd, &args)?;
            drop(args);
            drop(hs);
            drop(h_guard); // h released only now — the Table-5 cost
            g = on_block(ctx, l, outs)?;
        }
        Ok(())
    }
}

impl Engine for StoreHEngine {
    fn name(&self) -> &'static str {
        "Store-h"
    }

    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats> {
        self.ctx.tracker.reset_peak();
        let start = std::time::Instant::now();
        let mut sp = self.ctx.trace.span("step", "train");
        sp.arg("step", crate::util::json::Json::Num((self.ctx.step + 1) as f64));
        let h = self.forward(batch)?;
        let (loss, g) = self.ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        Self::backward(
            &mut self.ctx, &mut self.store, &mut self.saved_h, g,
            |ctx, l, outs| ctx.apply_block_grads(l, outs),
        )?;
        drop(sp);
        self.ctx.step += 1;
        self.ctx.tracker.mark_step(self.ctx.step as u64);
        Ok(StepStats {
            step: self.ctx.step,
            loss,
            peak_bytes: self.ctx.tracker.peak(),
            secs: start.elapsed().as_secs_f64(),
            live_after: self.ctx.tracker.live(),
        })
    }

    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>> {
        let h = self.forward(batch)?;
        let (_, g) = self.ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        let n_layers = self.ctx.rt.dims().n_layers;
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
        Self::backward(
            &mut self.ctx, &mut self.store, &mut self.saved_h, g,
            |_ctx, l, mut outs| {
                let mut flat = Vec::new();
                for t in &outs[1..] {
                    flat.extend_from_slice(t.as_f32());
                }
                grads[l] = flat;
                outs.truncate(1);
                Ok(outs.pop().unwrap())
            },
        )?;
        Ok(grads)
    }

    fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut EngineCtx {
        &mut self.ctx
    }
}
