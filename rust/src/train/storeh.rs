//! Store-h ablation — the paper's Table 5 counterfactual.
//!
//! Identical to MeSP except the seven LoRA intermediates h = xA of EVERY
//! block are stored at forward time (`block_fwd_saveh`) and consumed at
//! backward time (`block_bwd_storeh`) instead of being recomputed. The
//! stored h tensors of all L×7 sites live from forward until that block's
//! backward — the accumulation the paper's §5.7 measures (and rejects in
//! favour of recomputation).

use crate::config::{ActCompress, PROJS};
use crate::data::Batch;
use crate::memory::Guard;
use crate::model::actquant;
use crate::tensor::HostTensor;

use super::common::EngineCtx;
use super::{CheckpointStore, Engine, StepStats};

/// One layer's buffered h = xA set: f32 tensors (the Table-5 baseline)
/// or one int8+outlier blob covering all seven sites (`--act-compress
/// int8`). The guard charges whatever representation is actually held —
/// the compressed form is ~4× smaller, which is what lets the fleet
/// overlap more store-h jobs under one budget.
enum SavedH {
    F32(Vec<HostTensor>, Guard),
    Int8(actquant::Compressed, Guard),
}

pub struct StoreHEngine {
    ctx: EngineCtx,
    store: CheckpointStore,
    /// Per-layer stored h set + its tracking guard.
    saved_h: Vec<Option<SavedH>>,
}

impl StoreHEngine {
    pub fn new(ctx: EngineCtx) -> anyhow::Result<Self> {
        anyhow::ensure!(
            ctx.rt.has_artifact(&ctx.artifact("block_fwd_saveh"))
                && ctx.rt.has_artifact(&ctx.artifact("block_bwd_storeh")),
            "config '{}' lacks the store-h ablation artifacts",
            ctx.rt.dims().name
        );
        ctx.warmup(&["embed_fwd", "block_fwd_saveh", "block_bwd_storeh",
                     "lm_loss_grad"])?;
        let store = CheckpointStore::new(ctx.tracker.clone(), ctx.spill_limit);
        let n = ctx.rt.dims().n_layers;
        Ok(StoreHEngine {
            ctx,
            store,
            saved_h: (0..n).map(|_| None).collect(),
        })
    }

    /// Forward that stores checkpoints AND h×7 per block.
    fn forward(&mut self, batch: &Batch) -> anyhow::Result<HostTensor> {
        use crate::runtime::Arg;
        let ctx = &self.ctx;
        let _sp = ctx.trace.span("fwd", "train");
        let fwd = ctx.artifact("block_fwd_saveh");
        let mut x = ctx.embed(&batch.tokens)?;
        for l in 0..ctx.rt.dims().n_layers {
            let mut args: Vec<Arg> = vec![Arg::Host(&x)];
            args.extend(ctx.block_args_mixed(l));
            let mut outs = ctx.rt.execute(&fwd, &args)?;
            drop(args);
            let hs: Vec<HostTensor> = outs.drain(1..).collect();
            self.saved_h[l] = Some(match ctx.act_compress {
                ActCompress::None => {
                    let h_bytes: u64 = hs.iter().map(|t| t.bytes()).sum();
                    let guard = ctx.tracker.track("storeh:h", h_bytes);
                    SavedH::F32(hs, guard)
                }
                ActCompress::Int8 => {
                    // All seven sites flatten into one stream so short
                    // tails share quantization groups (PROJS order —
                    // the decompress side slices the same way).
                    let total: usize = hs.iter().map(|t| t.len()).sum();
                    let mut flat = Vec::with_capacity(total);
                    for t in &hs {
                        flat.extend_from_slice(t.as_f32());
                    }
                    drop(hs);
                    let blob = actquant::compress(&flat);
                    let guard = ctx.tracker.track("storeh:h", blob.bytes());
                    SavedH::Int8(blob, guard)
                }
            });
            let y = outs.pop().unwrap();
            self.store.store(l, x)?;
            x = y;
        }
        Ok(x)
    }

    fn backward<F>(
        ctx: &mut EngineCtx,
        store: &mut CheckpointStore,
        saved_h: &mut [Option<SavedH>],
        mut g: HostTensor,
        mut on_block: F,
    ) -> anyhow::Result<()>
    where
        F: FnMut(&mut EngineCtx, usize, Vec<HostTensor>)
            -> anyhow::Result<HostTensor>,
    {
        use crate::runtime::Arg;
        let _sp = ctx.trace.span("bwd", "train");
        let bwd = ctx.artifact("block_bwd_storeh");
        let (m, r) = (ctx.rt.dims().m(), ctx.rt.dims().rank);
        for l in (0..ctx.rt.dims().n_layers).rev() {
            let x = store.take(l)?;
            let (hs, h_guard) = match saved_h[l]
                .take()
                .ok_or_else(|| anyhow::anyhow!("h for layer {l} not saved"))?
            {
                SavedH::F32(hs, guard) => (hs, guard),
                SavedH::Int8(blob, guard) => {
                    // Transient f32 for the backward call only; the blob
                    // (and its guard) die at the end of this arm.
                    let flat = actquant::decompress(&blob);
                    let hs = (0..PROJS.len())
                        .map(|i| {
                            HostTensor::f32(
                                &[m, r],
                                flat[i * m * r..(i + 1) * m * r].to_vec(),
                            )
                        })
                        .collect();
                    (hs, guard)
                }
            };
            let mut args: Vec<Arg> = vec![Arg::Host(&x), Arg::Host(&g)];
            args.extend(hs.iter().map(Arg::Host));
            args.extend(ctx.block_args_mixed(l));
            let outs = ctx.rt.execute(&bwd, &args)?;
            drop(args);
            drop(hs);
            drop(h_guard); // h released only now — the Table-5 cost
            g = on_block(ctx, l, outs)?;
        }
        Ok(())
    }
}

impl Engine for StoreHEngine {
    fn name(&self) -> &'static str {
        "Store-h"
    }

    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats> {
        self.ctx.tracker.reset_peak();
        let start = std::time::Instant::now();
        let mut sp = self.ctx.trace.span("step", "train");
        sp.arg("step", crate::util::json::Json::Num((self.ctx.step + 1) as f64));
        let h = self.forward(batch)?;
        let (loss, g) = self.ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        Self::backward(
            &mut self.ctx, &mut self.store, &mut self.saved_h, g,
            |ctx, l, outs| ctx.apply_block_grads(l, outs),
        )?;
        drop(sp);
        self.ctx.step += 1;
        self.ctx.tracker.mark_step(self.ctx.step as u64);
        Ok(StepStats {
            step: self.ctx.step,
            loss,
            peak_bytes: self.ctx.tracker.peak(),
            secs: start.elapsed().as_secs_f64(),
            live_after: self.ctx.tracker.live(),
        })
    }

    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>> {
        let h = self.forward(batch)?;
        let (_, g) = self.ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        let n_layers = self.ctx.rt.dims().n_layers;
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
        Self::backward(
            &mut self.ctx, &mut self.store, &mut self.saved_h, g,
            |_ctx, l, mut outs| {
                let mut flat = Vec::new();
                for t in &outs[1..] {
                    flat.extend_from_slice(t.as_f32());
                }
                grads[l] = flat;
                outs.truncate(1);
                Ok(outs.pop().unwrap())
            },
        )?;
        Ok(grads)
    }

    fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut EngineCtx {
        &mut self.ctx
    }
}
