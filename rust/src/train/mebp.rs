//! MeBP baseline — gradient checkpointing + framework autodiff (§3.3).
//!
//! Forward phase is identical to MeSP (block-input checkpoints). The
//! backward phase mechanically mirrors what mx.grad / torch.autograd do
//! inside a checkpointed segment: first a recompute-forward call emits the
//! full residual set the framework would retain (every tensor feeding a
//! gradient rule INCLUDING all seven h = xA and the framework slack), and
//! those residuals are held as real tracked buffers while a second call
//! consumes them to produce gradients. The held residual set is exactly
//! why the paper measures MeBP's peak so much higher than MeSP's.

use crate::config::ActCompress;
use crate::data::Batch;
use crate::model::actquant;
use crate::tensor::HostTensor;

use super::common::EngineCtx;
use super::{CheckpointStore, Engine, StepStats};

pub struct MebpEngine {
    ctx: EngineCtx,
    store: CheckpointStore,
}

impl MebpEngine {
    pub fn new(ctx: EngineCtx) -> anyhow::Result<Self> {
        anyhow::ensure!(
            ctx.rt.has_artifact(&ctx.artifact("block_fwd_residuals")),
            "config '{}' lacks the MeBP residual artifacts on this backend",
            ctx.rt.dims().name
        );
        ctx.warmup(&["embed_fwd", "block_fwd", "block_fwd_residuals",
                     "block_bwd_residuals", "lm_loss_grad"])?;
        let store = CheckpointStore::new(ctx.tracker.clone(), ctx.spill_limit);
        Ok(MebpEngine { ctx, store })
    }

    fn backward<F>(
        ctx: &mut EngineCtx,
        store: &mut CheckpointStore,
        mut g: HostTensor,
        mut on_block: F,
    ) -> anyhow::Result<()>
    where
        F: FnMut(&mut EngineCtx, usize, Vec<HostTensor>)
            -> anyhow::Result<HostTensor>,
    {
        use crate::runtime::Arg;
        let _sp = ctx.trace.span("bwd", "train");
        let fwd_name = ctx.artifact("block_fwd_residuals");
        let bwd_name = ctx.artifact("block_bwd_residuals");
        for l in (0..ctx.rt.dims().n_layers).rev() {
            let x = store.take(l)?;
            // Phase 1: autodiff-style recompute-forward. The residual set
            // becomes host-held, tracked memory — the framework's
            // "implicitly retained" tensors (paper §3.3).
            let mut args: Vec<Arg> = vec![Arg::Host(&x)];
            args.extend(ctx.block_args_mixed(l));
            let mut fwd = ctx.rt.execute(&fwd_name, &args)?;
            drop(args);
            let mut residuals: Vec<HostTensor> = fwd.drain(1..).collect();
            drop(fwd); // the recomputed y is dead (we already have g)
            // `--act-compress int8`: the held window between the two
            // phases is stored compressed (lossy — each residual is
            // re-materialized in f32 for the consuming call, so MeBP's
            // peak is NOT reduced; the win is store-h's long-lived h
            // buffers. Kept here so both buffered paths share one flag).
            if ctx.act_compress == ActCompress::Int8 {
                residuals = residuals
                    .into_iter()
                    .map(|t| {
                        let shape = t.shape.clone();
                        let blob = actquant::compress(t.as_f32());
                        drop(t);
                        HostTensor::f32(&shape, actquant::decompress(&blob))
                    })
                    .collect();
            }
            let res_bytes: u64 = residuals.iter().map(|t| t.bytes()).sum();
            let res_guard = ctx.tracker.track("residuals:block", res_bytes);

            // Phase 2: consume residuals → gradients.
            let mut args: Vec<Arg> = vec![Arg::Host(&g)];
            args.extend(residuals.iter().map(Arg::Host));
            args.extend(ctx.block_args_mixed(l));
            let outs = ctx.rt.execute(&bwd_name, &args)?;
            drop(args);
            drop(residuals);
            drop(res_guard);
            g = on_block(ctx, l, outs)?;
        }
        Ok(())
    }
}

impl Engine for MebpEngine {
    fn name(&self) -> &'static str {
        "MeBP"
    }

    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats> {
        let store = &mut self.store;
        self.ctx.measured(|ctx| {
            let h = ctx.forward_with_checkpoints(batch, store)?;
            // Autodiff loss head: framework retains logits + softmax while
            // building g — model this as a tracked buffer of 2×logits
            // alongside the call (the manual path releases in place).
            let dims = ctx.rt.dims();
            let logit_bytes = (dims.m() * dims.vocab * 4) as u64;
            let slack = ctx.tracker.track("loss:autodiff_slack", 2 * logit_bytes);
            let (loss, g) = ctx.loss_grad(&h, &batch.targets)?;
            drop(slack);
            drop(h);
            Self::backward(ctx, store, g, |ctx, l, outs| {
                ctx.apply_block_grads(l, outs)
            })?;
            Ok(loss)
        })
    }

    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>> {
        let store = &mut self.store;
        let ctx = &mut self.ctx;
        let h = ctx.forward_with_checkpoints(batch, store)?;
        let (_, g) = ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        let n_layers = ctx.rt.dims().n_layers;
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
        Self::backward(ctx, store, g, |_ctx, l, mut outs| {
            let mut flat = Vec::new();
            for t in &outs[1..] {
                flat.extend_from_slice(t.as_f32());
            }
            grads[l] = flat;
            outs.truncate(1);
            Ok(outs.pop().unwrap())
        })?;
        Ok(grads)
    }

    fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut EngineCtx {
        &mut self.ctx
    }
}
