//! Optimizers over host-side LoRA parameters. Parameters are small
//! (r·(d_in+d_out) per site), so updates run on the host — exactly as the
//! paper's implementation updates LoRA weights immediately after each
//! block's backward ("update parameters immediately with the optimizer",
//! §4.3). State is tracked so optimizer memory shows up in step peaks.

use crate::config::OptimizerKind;
use crate::memory::{Guard, MemoryTracker};

/// Per-parameter-group optimizer state (one group per LoRA tensor).
enum State {
    Sgd,
    Momentum { v: Vec<Vec<f32>>, beta: f32 },
    Adam { m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, b1: f32, b2: f32, eps: f32, t: u64 },
}

pub struct Optimizer {
    lr: f32,
    state: State,
    _guard: Option<Guard>,
}

impl Optimizer {
    /// `group_sizes`: element counts of every parameter tensor this
    /// optimizer will update (layer-major, ABI order).
    pub fn new(
        kind: OptimizerKind,
        lr: f32,
        group_sizes: &[usize],
        tracker: &MemoryTracker,
    ) -> Self {
        let alloc = |sizes: &[usize]| -> Vec<Vec<f32>> {
            sizes.iter().map(|n| vec![0.0; *n]).collect()
        };
        let (state, bytes) = match kind {
            OptimizerKind::Sgd => (State::Sgd, 0u64),
            OptimizerKind::Momentum { beta } => {
                let v = alloc(group_sizes);
                let b = 4 * group_sizes.iter().sum::<usize>() as u64;
                (State::Momentum { v, beta }, b)
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                let m = alloc(group_sizes);
                let v = alloc(group_sizes);
                let b = 8 * group_sizes.iter().sum::<usize>() as u64;
                (State::Adam { m, v, b1: beta1, b2: beta2, eps, t: 0 }, b)
            }
        };
        let guard = (bytes > 0).then(|| tracker.track("optimizer:state", bytes));
        Optimizer { lr, state, _guard: guard }
    }

    /// Advance the step counter (Adam bias correction). Call once per
    /// optimizer step, before the per-group updates.
    pub fn begin_step(&mut self) {
        if let State::Adam { t, .. } = &mut self.state {
            *t += 1;
        }
    }

    /// Apply one group's gradient in place: params[group] -= lr * f(grad).
    pub fn update(&mut self, group: usize, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let lr = self.lr;
        match &mut self.state {
            State::Sgd => {
                for (p, g) in params.iter_mut().zip(grad) {
                    *p -= lr * g;
                }
            }
            State::Momentum { v, beta } => {
                let v = &mut v[group];
                for i in 0..params.len() {
                    v[i] = *beta * v[i] + grad[i];
                    params[i] -= lr * v[i];
                }
            }
            State::Adam { m, v, b1, b2, eps, t } => {
                let (b1v, b2v, epsv, tv) = (*b1, *b2, *eps, *t as i32);
                let m = &mut m[group];
                let v = &mut v[group];
                let bc1 = 1.0 - b1v.powi(tv);
                let bc2 = 1.0 - b2v.powi(tv);
                for i in 0..params.len() {
                    m[i] = b1v * m[i] + (1.0 - b1v) * grad[i];
                    v[i] = b2v * v[i] + (1.0 - b2v) * grad[i] * grad[i];
                    let mh = m[i] / bc1;
                    let vh = v[i] / bc2;
                    params[i] -= lr * mh / (vh.sqrt() + epsv);
                }
            }
        }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Export the mutable state for a session snapshot:
    /// `(t, first_moments, second_moments)` — `(0, [], [])` for SGD,
    /// `(0, v, [])` for momentum, `(t, m, v)` for Adam. Hyperparameters
    /// (betas, eps, lr) are NOT exported: they belong to the config the
    /// snapshot stores separately, and restore rebuilds the optimizer
    /// from that config before importing the moments.
    pub fn export_state(&self) -> (u64, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        match &self.state {
            State::Sgd => (0, Vec::new(), Vec::new()),
            State::Momentum { v, .. } => (0, v.clone(), Vec::new()),
            State::Adam { m, v, t, .. } => (*t, m.clone(), v.clone()),
        }
    }

    /// Inverse of [`Self::export_state`]: overwrite the moment buffers of
    /// an optimizer freshly built from the same config. Group counts and
    /// lengths must match exactly — a snapshot from a different model
    /// shape fails here instead of silently mis-scattering moments.
    pub fn import_state(
        &mut self,
        t: u64,
        m1: &[Vec<f32>],
        m2: &[Vec<f32>],
    ) -> anyhow::Result<()> {
        let copy_groups = |dst: &mut Vec<Vec<f32>>,
                           src: &[Vec<f32>],
                           what: &str|
         -> anyhow::Result<()> {
            anyhow::ensure!(
                dst.len() == src.len(),
                "snapshot {what} has {} groups, optimizer expects {}",
                src.len(),
                dst.len()
            );
            for (i, (d, s)) in dst.iter_mut().zip(src).enumerate() {
                anyhow::ensure!(
                    d.len() == s.len(),
                    "snapshot {what} group {i} has {} params, optimizer \
                     expects {}",
                    s.len(),
                    d.len()
                );
                d.copy_from_slice(s);
            }
            Ok(())
        };
        match &mut self.state {
            State::Sgd => {
                anyhow::ensure!(
                    m1.is_empty() && m2.is_empty() && t == 0,
                    "snapshot carries optimizer moments but the session \
                     optimizer is SGD (stateless)"
                );
            }
            State::Momentum { v, .. } => {
                anyhow::ensure!(
                    m2.is_empty() && t == 0,
                    "snapshot optimizer state is not momentum-shaped"
                );
                copy_groups(v, m1, "momentum velocity")?;
            }
            State::Adam { m, v, t: tt, .. } => {
                copy_groups(m, m1, "Adam first moment")?;
                copy_groups(v, m2, "Adam second moment")?;
                *tt = t;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr() -> MemoryTracker {
        MemoryTracker::new()
    }

    #[test]
    fn sgd_matches_hand_computed() {
        let t = tr();
        let mut o = Optimizer::new(OptimizerKind::Sgd, 0.1, &[2], &t);
        let mut p = vec![1.0, -2.0];
        o.begin_step();
        o.update(0, &mut p, &[0.5, -1.0]);
        assert_eq!(p, vec![1.0 - 0.05, -2.0 + 0.1]);
    }

    #[test]
    fn momentum_accumulates() {
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::Momentum { beta: 0.9 }, 1.0, &[1], &t);
        let mut p = vec![0.0];
        o.begin_step();
        o.update(0, &mut p, &[1.0]); // v=1, p=-1
        o.begin_step();
        o.update(0, &mut p, &[1.0]); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |Δp| ≈ lr on step 1 regardless of grad scale
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.01, &[1], &t);
        for g in [1e-3f32, 1.0, 100.0] {
            let mut p = vec![0.0];
            let mut o2 = Optimizer::new(
                OptimizerKind::parse("adam").unwrap(), 0.01, &[1], &t);
            o2.begin_step();
            o2.update(0, &mut p, &[g]);
            assert!((p[0].abs() - 0.01).abs() < 1e-3, "g={g} dp={}", p[0]);
        }
        let _ = &mut o;
    }

    #[test]
    fn state_is_tracked() {
        let t = tr();
        let _o = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.1, &[100, 50], &t);
        assert_eq!(t.live(), 8 * 150);
        let _s = Optimizer::new(OptimizerKind::Sgd, 0.1, &[100], &t);
        assert_eq!(t.live(), 8 * 150, "sgd adds no state");
    }

    #[test]
    fn export_import_roundtrip_continues_identically() {
        // Two Adam optimizers: one runs 4 updates straight; the other
        // runs 2, exports, imports into a FRESH optimizer, runs 2 more.
        // Both parameter trajectories must be bitwise identical.
        let t = tr();
        let grads: Vec<Vec<f32>> =
            (0..4).map(|i| vec![0.3 * (i as f32 - 1.5), -0.1]).collect();
        let run = |o: &mut Optimizer, p: &mut Vec<f32>, gs: &[Vec<f32>]| {
            for g in gs {
                o.begin_step();
                o.update(0, p, g);
            }
        };
        let mut full = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.05, &[2], &t);
        let mut p_full = vec![1.0, -1.0];
        run(&mut full, &mut p_full, &grads);

        let mut first = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.05, &[2], &t);
        let mut p_half = vec![1.0, -1.0];
        run(&mut first, &mut p_half, &grads[..2]);
        let (step, m1, m2) = first.export_state();
        assert_eq!(step, 2);
        let mut resumed = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.05, &[2], &t);
        resumed.import_state(step, &m1, &m2).unwrap();
        run(&mut resumed, &mut p_half, &grads[2..]);

        for (a, b) in p_full.iter().zip(&p_half) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn import_rejects_mismatched_shapes() {
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.1, &[3, 2], &t);
        // wrong group count
        assert!(o.import_state(1, &[vec![0.0; 3]], &[vec![0.0; 3]]).is_err());
        // wrong group length
        assert!(o
            .import_state(
                1,
                &[vec![0.0; 3], vec![0.0; 99]],
                &[vec![0.0; 3], vec![0.0; 2]],
            )
            .is_err());
        // SGD must reject any moments at all
        let mut s = Optimizer::new(OptimizerKind::Sgd, 0.1, &[3], &t);
        assert!(s.import_state(0, &[vec![0.0; 3]], &[]).is_err());
        assert!(s.import_state(0, &[], &[]).is_ok());
    }

    #[test]
    fn groups_are_independent() {
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::Momentum { beta: 0.5 }, 1.0, &[1, 1], &t);
        let (mut p0, mut p1) = (vec![0.0], vec![0.0]);
        o.begin_step();
        o.update(0, &mut p0, &[1.0]);
        o.begin_step();
        o.update(1, &mut p1, &[1.0]);
        // group 1 must not see group 0's velocity
        assert_eq!(p0, p1);
    }
}
