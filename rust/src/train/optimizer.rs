//! Optimizers over host-side LoRA parameters. Parameters are small
//! (r·(d_in+d_out) per site), so updates run on the host — exactly as the
//! paper's implementation updates LoRA weights immediately after each
//! block's backward ("update parameters immediately with the optimizer",
//! §4.3). State is tracked so optimizer memory shows up in step peaks.

use crate::config::OptimizerKind;
use crate::memory::{Guard, MemoryTracker};

/// Per-parameter-group optimizer state (one group per LoRA tensor).
enum State {
    Sgd,
    Momentum { v: Vec<Vec<f32>>, beta: f32 },
    Adam { m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, b1: f32, b2: f32, eps: f32, t: u64 },
}

pub struct Optimizer {
    lr: f32,
    state: State,
    _guard: Option<Guard>,
}

impl Optimizer {
    /// `group_sizes`: element counts of every parameter tensor this
    /// optimizer will update (layer-major, ABI order).
    pub fn new(
        kind: OptimizerKind,
        lr: f32,
        group_sizes: &[usize],
        tracker: &MemoryTracker,
    ) -> Self {
        let alloc = |sizes: &[usize]| -> Vec<Vec<f32>> {
            sizes.iter().map(|n| vec![0.0; *n]).collect()
        };
        let (state, bytes) = match kind {
            OptimizerKind::Sgd => (State::Sgd, 0u64),
            OptimizerKind::Momentum { beta } => {
                let v = alloc(group_sizes);
                let b = 4 * group_sizes.iter().sum::<usize>() as u64;
                (State::Momentum { v, beta }, b)
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                let m = alloc(group_sizes);
                let v = alloc(group_sizes);
                let b = 8 * group_sizes.iter().sum::<usize>() as u64;
                (State::Adam { m, v, b1: beta1, b2: beta2, eps, t: 0 }, b)
            }
        };
        let guard = (bytes > 0).then(|| tracker.track("optimizer:state", bytes));
        Optimizer { lr, state, _guard: guard }
    }

    /// Advance the step counter (Adam bias correction). Call once per
    /// optimizer step, before the per-group updates.
    pub fn begin_step(&mut self) {
        if let State::Adam { t, .. } = &mut self.state {
            *t += 1;
        }
    }

    /// Apply one group's gradient in place: params[group] -= lr * f(grad).
    pub fn update(&mut self, group: usize, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let lr = self.lr;
        match &mut self.state {
            State::Sgd => {
                for (p, g) in params.iter_mut().zip(grad) {
                    *p -= lr * g;
                }
            }
            State::Momentum { v, beta } => {
                let v = &mut v[group];
                for i in 0..params.len() {
                    v[i] = *beta * v[i] + grad[i];
                    params[i] -= lr * v[i];
                }
            }
            State::Adam { m, v, b1, b2, eps, t } => {
                let (b1v, b2v, epsv, tv) = (*b1, *b2, *eps, *t as i32);
                let m = &mut m[group];
                let v = &mut v[group];
                let bc1 = 1.0 - b1v.powi(tv);
                let bc2 = 1.0 - b2v.powi(tv);
                for i in 0..params.len() {
                    m[i] = b1v * m[i] + (1.0 - b1v) * grad[i];
                    v[i] = b2v * v[i] + (1.0 - b2v) * grad[i] * grad[i];
                    let mh = m[i] / bc1;
                    let vh = v[i] / bc2;
                    params[i] -= lr * mh / (vh.sqrt() + epsv);
                }
            }
        }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr() -> MemoryTracker {
        MemoryTracker::new()
    }

    #[test]
    fn sgd_matches_hand_computed() {
        let t = tr();
        let mut o = Optimizer::new(OptimizerKind::Sgd, 0.1, &[2], &t);
        let mut p = vec![1.0, -2.0];
        o.begin_step();
        o.update(0, &mut p, &[0.5, -1.0]);
        assert_eq!(p, vec![1.0 - 0.05, -2.0 + 0.1]);
    }

    #[test]
    fn momentum_accumulates() {
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::Momentum { beta: 0.9 }, 1.0, &[1], &t);
        let mut p = vec![0.0];
        o.begin_step();
        o.update(0, &mut p, &[1.0]); // v=1, p=-1
        o.begin_step();
        o.update(0, &mut p, &[1.0]); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |Δp| ≈ lr on step 1 regardless of grad scale
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.01, &[1], &t);
        for g in [1e-3f32, 1.0, 100.0] {
            let mut p = vec![0.0];
            let mut o2 = Optimizer::new(
                OptimizerKind::parse("adam").unwrap(), 0.01, &[1], &t);
            o2.begin_step();
            o2.update(0, &mut p, &[g]);
            assert!((p[0].abs() - 0.01).abs() < 1e-3, "g={g} dp={}", p[0]);
        }
        let _ = &mut o;
    }

    #[test]
    fn state_is_tracked() {
        let t = tr();
        let _o = Optimizer::new(
            OptimizerKind::parse("adam").unwrap(), 0.1, &[100, 50], &t);
        assert_eq!(t.live(), 8 * 150);
        let _s = Optimizer::new(OptimizerKind::Sgd, 0.1, &[100], &t);
        assert_eq!(t.live(), 8 * 150, "sgd adds no state");
    }

    #[test]
    fn groups_are_independent() {
        let t = tr();
        let mut o = Optimizer::new(
            OptimizerKind::Momentum { beta: 0.5 }, 1.0, &[1, 1], &t);
        let (mut p0, mut p1) = (vec![0.0], vec![0.0]);
        o.begin_step();
        o.update(0, &mut p0, &[1.0]);
        o.begin_step();
        o.update(1, &mut p1, &[1.0]);
        // group 1 must not see group 0's velocity
        assert_eq!(p0, p1);
    }
}
