//! Shared engine plumbing: the per-block forward sweep, loss-head calls,
//! and immediate optimizer application — the parts of the schedule that
//! are identical across methods (paper §4.3's Forward Phase).

use std::sync::Arc;
use std::time::Instant;

use crate::config::{OptimizerKind, QuantMode, PROJS};
use crate::data::Batch;
use crate::memory::{Guard, MemoryTracker};
use crate::model::{quant, ModelState};
use crate::runtime::{Arg, Backend, DeviceBuffer};
use crate::tensor::HostTensor;

use super::{CheckpointStore, Optimizer, StepStats};

/// Everything an engine needs: backend, model, optimizer, tracker.
///
/// Engines are backend-agnostic: `rt` is a [`Backend`] trait object, so
/// the same schedule runs on the in-process reference backend and on the
/// PJRT artifact runtime. Frozen weights and the embedding are uploaded
/// ONCE to persistent backend buffers at construction and their host
/// copies freed — the paper-equivalent of keeping base weights resident
/// while only LoRA params move (perf §L3: this removed the dominant
/// per-call memcpy at 100M scale). LoRA params stay host-side (the
/// optimizer updates them after every block) and ride along each call as
/// transient uploads.
///
/// Under `--quant q4` the seven projection matrices of every block are
/// int4-packed at upload time and the f32 originals dropped: the session
/// never holds full-precision base weights again (paper §4.5), the
/// `weights:device` tag shrinks to the packed bytes, and every block
/// call is routed to its `_q4` artifact twin.
pub struct EngineCtx {
    pub rt: Arc<dyn Backend>,
    pub model: ModelState,
    pub opt: Optimizer,
    pub tracker: MemoryTracker,
    pub step: usize,
    /// Checkpoint-store disk-spill budget in bytes (0 = never spill).
    pub spill_limit: u64,
    quant: QuantMode,
    /// Fingerprint of the frozen base weights, computed at init BEFORE
    /// the host copies are freed — session snapshots store this instead
    /// of the (regenerable) weights themselves.
    weights_fingerprint: u64,
    /// Per block: FROZEN-order tensors (f32 mode) or
    /// `[ln1, ln2, (packed, scales) × QUANT_MATS]` (q4 mode) — exactly
    /// the frozen argument run of the selected artifact ABI.
    dev_frozen: Vec<Vec<DeviceBuffer>>,
    dev_emb: DeviceBuffer,
    dev_fnorm: DeviceBuffer,
    _dev_guard: Guard,
}

impl EngineCtx {
    /// Standard construction: seeded model + optimizer sized to the LoRA
    /// tensor groups (layer-major, ABI order), then weight upload
    /// (quantizing the projections first under `QuantMode::Q4`).
    pub fn new(
        rt: Arc<dyn Backend>,
        seed: u64,
        opt_kind: OptimizerKind,
        lr: f32,
        spill_limit: u64,
        quant_mode: QuantMode,
    ) -> anyhow::Result<Self> {
        if quant_mode == QuantMode::Q4 {
            anyhow::ensure!(
                rt.has_artifact("block_bwd_mesp_q4"),
                "config '{}' has no q4 training artifacts on the {} backend: \
                 either a quantized d_in is not divisible by {} (group size), \
                 or this backend only ships the q4 inference forward — the \
                 `_q4` backward twins currently exist on `reference` only",
                rt.dims().name,
                rt.kind(),
                quant::GROUP
            );
        }
        let tracker = rt.tracker().clone();
        let mut model =
            ModelState::init_with_quant(rt.dims(), seed, &tracker, quant_mode);
        let group_sizes: Vec<usize> = model
            .lora
            .iter()
            .flat_map(|l| l.tensors.iter().map(|t| t.len()))
            .collect();
        let opt = Optimizer::new(opt_kind, lr, &group_sizes, &tracker);
        // Hash the resident frozen tensors now — the upload loop below
        // drains the host copies, after which they are gone for good.
        let weights_fingerprint = model.weights_fingerprint();

        // Upload frozen state once; free the host copies (their Tracked
        // guards drop here), accounting the device bytes instead. The
        // model already holds the blocks in the selected artifact ABI
        // order — int4-packed + scales under q4 — so the upload loop is
        // mode-agnostic and `weights:device` shrinks to the packed bytes.
        let mut dev_bytes = 0u64;
        let mut dev_frozen = Vec::with_capacity(model.blocks.len());
        for block in &mut model.blocks {
            let mut bufs = Vec::with_capacity(block.tensors.len());
            for t in block.tensors.drain(..) {
                dev_bytes += t.value.bytes();
                bufs.push(rt.upload(&t.value).expect("weight upload"));
            }
            dev_frozen.push(bufs);
        }
        let dev_emb = rt.upload(&model.embedding.value).expect("emb upload");
        dev_bytes += model.embedding.value.bytes();
        // free the host embedding data (keep shape for introspection)
        model.embedding.value.data = crate::tensor::Data::F32(Vec::new());
        model.embedding.value.shape = vec![0];
        let dev_fnorm = rt.upload(&model.final_norm.value).expect("fnorm");
        dev_bytes += model.final_norm.value.bytes();
        let _dev_guard = tracker.track("weights:device", dev_bytes);
        Ok(EngineCtx {
            rt, model, opt, tracker, step: 0, spill_limit, quant: quant_mode,
            weights_fingerprint, dev_frozen, dev_emb, dev_fnorm, _dev_guard,
        })
    }

    /// The session's resident base-weight precision.
    pub fn quant(&self) -> QuantMode {
        self.quant
    }

    /// Fingerprint of the frozen base weights (see
    /// [`crate::model::ModelState::weights_fingerprint`]).
    pub fn weights_fingerprint(&self) -> u64 {
        self.weights_fingerprint
    }

    /// Map a block-artifact base name onto the session's quant mode
    /// (`block_bwd_mesp` → `block_bwd_mesp_q4` under q4). Non-block
    /// artifacts (embed, loss heads) pass through unchanged.
    pub fn artifact(&self, base: &str) -> String {
        match self.quant {
            QuantMode::Q4 if base.starts_with("block_") => format!("{base}_q4"),
            _ => base.to_string(),
        }
    }

    /// Warm the backend up on `bases`, mapped through [`Self::artifact`].
    pub fn warmup(&self, bases: &[&str]) -> anyhow::Result<()> {
        let names: Vec<String> = bases.iter().map(|b| self.artifact(b)).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        self.rt.warmup(&refs)
    }

    /// A block's frozen (device) + LoRA (host) tensors in artifact ABI
    /// order, ready to append after the leading args.
    pub fn block_args_mixed(&self, layer: usize) -> Vec<Arg<'_>> {
        let mut v: Vec<Arg> =
            Vec::with_capacity(self.dev_frozen[layer].len() + 2 * PROJS.len());
        for b in &self.dev_frozen[layer] {
            v.push(Arg::Device(b));
        }
        for t in &self.model.lora[layer].tensors {
            v.push(Arg::Host(t));
        }
        v
    }

    /// Token embedding lookup.
    pub fn embed(&self, tokens: &HostTensor) -> anyhow::Result<HostTensor> {
        let out = self.rt.execute(
            "embed_fwd", &[Arg::Host(tokens), Arg::Device(&self.dev_emb)])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// One block's forward. `x` is consumed conceptually; returns y.
    pub fn block_fwd(&self, layer: usize, x: &HostTensor)
        -> anyhow::Result<HostTensor>
    {
        let mut args: Vec<Arg> = vec![Arg::Host(x)];
        args.extend(self.block_args_mixed(layer));
        let out = self.rt.execute(&self.artifact("block_fwd"), &args)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Loss + gradient w.r.t. the final hidden state (manual CE backward).
    pub fn loss_grad(&self, h: &HostTensor, targets: &HostTensor)
        -> anyhow::Result<(f64, HostTensor)>
    {
        let out = self.rt.execute(
            "lm_loss_grad",
            &[Arg::Host(h), Arg::Device(&self.dev_fnorm),
              Arg::Device(&self.dev_emb), Arg::Host(targets)],
        )?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().scalar();
        Ok((loss, it.next().unwrap()))
    }

    /// Loss only (MeZO's forward).
    pub fn loss_only(&self, h: &HostTensor, targets: &HostTensor)
        -> anyhow::Result<f64>
    {
        let out = self.rt.execute(
            "lm_loss_fwd",
            &[Arg::Host(h), Arg::Device(&self.dev_fnorm),
              Arg::Device(&self.dev_emb), Arg::Host(targets)],
        )?;
        Ok(out[0].scalar())
    }

    /// Apply a block's 14 LoRA gradients (artifact output order: g_x,
    /// then (dA, dB) per PROJS site) and update immediately — the paper's
    /// §4.3 Backward Phase discipline. `outs` is the full backward output
    /// tuple; returns g_x (the only tensor that survives).
    pub fn apply_block_grads(
        &mut self,
        layer: usize,
        mut outs: Vec<HostTensor>,
    ) -> anyhow::Result<HostTensor> {
        anyhow::ensure!(outs.len() == 1 + 2 * PROJS.len(),
                        "expected 15 backward outputs, got {}", outs.len());
        // Gradients are transient: tracked only while the update runs.
        let g_bytes: u64 = outs[1..].iter().map(|t| t.bytes()).sum();
        let _g = self.tracker.track("grads:block", g_bytes);
        self.opt.begin_step();
        for i in (1..outs.len()).rev() {
            let grad = outs.pop().unwrap();
            let idx = i - 1; // 0..14 over lora tensors of this block
            let group = layer * 2 * PROJS.len() + idx;
            let params = self.model.lora[layer].tensors[idx].as_f32_mut();
            self.opt.update(group, params, grad.as_f32());
            // grad dropped here — "discarded immediately after being used"
        }
        Ok(outs.pop().unwrap())
    }

    /// Forward sweep storing block-INPUT checkpoints (all exact-grad
    /// engines share this). Returns the final hidden state.
    pub fn forward_with_checkpoints(
        &self,
        batch: &Batch,
        store: &mut CheckpointStore,
    ) -> anyhow::Result<HostTensor> {
        let mut x = self.embed(&batch.tokens)?;
        for l in 0..self.rt.dims().n_layers {
            let y = self.block_fwd(l, &x)?;
            store.store(l, x)?; // the INPUT of block l (Appendix E.1)
            x = y;
        }
        Ok(x)
    }

    /// Wrap a step body with peak/latency measurement.
    pub fn measured<F>(&mut self, body: F) -> anyhow::Result<StepStats>
    where
        F: FnOnce(&mut Self) -> anyhow::Result<f64>,
    {
        self.tracker.reset_peak();
        let start = Instant::now();
        let loss = body(self)?;
        let secs = start.elapsed().as_secs_f64();
        self.step += 1;
        Ok(StepStats {
            step: self.step,
            loss,
            peak_bytes: self.tracker.peak(),
            secs,
            live_after: self.tracker.live(),
        })
    }
}
