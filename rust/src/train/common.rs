//! Shared engine plumbing: the per-block forward sweep, loss-head calls,
//! and immediate optimizer application — the parts of the schedule that
//! are identical across methods (paper §4.3's Forward Phase).

use std::sync::Arc;
use std::time::Instant;

use crate::config::{ActCompress, OptimizerKind, QuantMode, PROJS};
use crate::data::Batch;
use crate::memory::{Guard, MemoryTracker};
use crate::model::{quant, AdapterState, FrozenModel};
use crate::obs::TraceSink;
use crate::runtime::{Arg, Backend, DeviceBuffer};
use crate::tensor::HostTensor;
use crate::util::json::Json;

use super::{CheckpointStore, Optimizer, StepStats};

/// Everything an engine needs: backend, model halves, optimizer, tracker.
///
/// Engines are backend-agnostic: `rt` is a [`Backend`] trait object, so
/// the same schedule runs on the in-process reference backend and on the
/// PJRT artifact runtime. The frozen base is an `Arc<FrozenModel>` —
/// possibly shared with other sessions through a
/// [`crate::model::WeightCache`] — and the session owns only its
/// [`AdapterState`] (LoRA params stay host-side; the optimizer updates
/// them after every block).
///
/// How frozen weights reach the backend depends on
/// [`Backend::shares_host_memory`]: backends that compute on host memory
/// (the reference backend) receive zero-copy [`Arg::Resident`] borrows of
/// the shared tensors — N same-base sessions hold ONE copy of the base
/// weights, charged once under `weights:shared` by whoever built the
/// `FrozenModel`. Upload backends (PJRT) get a per-session device copy at
/// construction, charged under `weights:device` (the host copy stays with
/// the shared `FrozenModel` — it is immutable and may serve other
/// sessions).
///
/// Under `--quant q4` the frozen blocks are int4-packed
/// (`[ln1, ln2, (packed, scales) × QUANT_MATS]`) and every block call is
/// routed to its `_q4` artifact twin.
pub struct EngineCtx {
    pub rt: Arc<dyn Backend>,
    /// The immutable, possibly shared frozen half.
    pub frozen: Arc<FrozenModel>,
    /// This session's private trainable half.
    pub adapters: AdapterState,
    pub opt: Optimizer,
    pub tracker: MemoryTracker,
    pub step: usize,
    /// Checkpoint-store disk-spill budget in bytes (0 = never spill).
    pub spill_limit: u64,
    /// Structured tracing (step/fwd/bwd/opt spans); disabled by default.
    /// Observe-only — traced and untraced runs are bitwise identical.
    pub trace: TraceSink,
    /// Buffered-activation compression (`--act-compress`): store-h's
    /// saved h = xA and MeBP's between-phase residual window are held as
    /// int8+outlier blobs instead of f32 (lossy — gradients shift within
    /// quantization error; bitwise parity claims apply to `None` only).
    pub act_compress: ActCompress,
    quant: QuantMode,
    /// Upload-backend path only (`shares_host_memory() == false`):
    /// per-session device copies of the frozen state, in artifact ABI
    /// order. Empty/None on shared-memory backends.
    dev_frozen: Vec<Vec<DeviceBuffer>>,
    dev_emb: Option<DeviceBuffer>,
    dev_fnorm: Option<DeviceBuffer>,
    _dev_guard: Option<Guard>,
}

impl EngineCtx {
    /// Wire a session around an existing frozen base (fresh or from a
    /// [`crate::model::WeightCache`]) and this session's adapters. The
    /// optimizer is sized to the LoRA tensor groups (layer-major, ABI
    /// order).
    pub fn new(
        rt: Arc<dyn Backend>,
        frozen: Arc<FrozenModel>,
        adapters: AdapterState,
        opt_kind: OptimizerKind,
        lr: f32,
        spill_limit: u64,
        trace: TraceSink,
    ) -> anyhow::Result<Self> {
        let quant = frozen.quant;
        if quant == QuantMode::Q4 {
            anyhow::ensure!(
                rt.has_artifact("block_bwd_mesp_q4"),
                "config '{}' has no q4 training artifacts on the {} backend: \
                 either a quantized d_in is not divisible by {} (group size), \
                 or this backend only ships the q4 inference forward — the \
                 `_q4` backward twins currently exist on `reference` only",
                rt.dims().name,
                rt.kind(),
                quant::GROUP
            );
        }
        let tracker = rt.tracker().clone();
        let group_sizes: Vec<usize> = adapters
            .lora
            .iter()
            .flat_map(|l| l.tensors.iter().map(|t| t.len()))
            .collect();
        let opt = Optimizer::new(opt_kind, lr, &group_sizes, &tracker);

        // Shared-memory backends borrow the frozen tensors per call
        // (`Arg::Resident`) — no copies, no extra accounting. Upload
        // backends get a per-session device copy, charged here.
        let (dev_frozen, dev_emb, dev_fnorm, _dev_guard) =
            if rt.shares_host_memory() {
                (Vec::new(), None, None, None)
            } else {
                let mut dev_bytes = 0u64;
                let mut dev_frozen = Vec::with_capacity(frozen.blocks.len());
                for block in &frozen.blocks {
                    let mut bufs = Vec::with_capacity(block.len());
                    for t in block {
                        dev_bytes += t.bytes();
                        bufs.push(rt.upload(t).expect("weight upload"));
                    }
                    dev_frozen.push(bufs);
                }
                let dev_emb = rt.upload(&frozen.embedding).expect("emb upload");
                dev_bytes += frozen.embedding.bytes();
                let dev_fnorm = rt.upload(&frozen.final_norm).expect("fnorm");
                dev_bytes += frozen.final_norm.bytes();
                let guard = tracker.track("weights:device", dev_bytes);
                (dev_frozen, Some(dev_emb), Some(dev_fnorm), Some(guard))
            };
        Ok(EngineCtx {
            rt, frozen, adapters, opt, tracker, step: 0, spill_limit, trace,
            act_compress: ActCompress::None,
            quant, dev_frozen, dev_emb, dev_fnorm, _dev_guard,
        })
    }

    /// The session's resident base-weight precision.
    pub fn quant(&self) -> QuantMode {
        self.quant
    }

    /// Fingerprint of the frozen base weights (see
    /// [`crate::model::FrozenModel::fingerprint`]).
    pub fn weights_fingerprint(&self) -> u64 {
        self.frozen.fingerprint()
    }

    /// Map a block-artifact base name onto the session's quant mode
    /// (`block_bwd_mesp` → `block_bwd_mesp_q4` under q4). Non-block
    /// artifacts (embed, loss heads) pass through unchanged.
    pub fn artifact(&self, base: &str) -> String {
        match self.quant {
            QuantMode::Q4 if base.starts_with("block_") => format!("{base}_q4"),
            _ => base.to_string(),
        }
    }

    /// Warm the backend up on `bases`, mapped through [`Self::artifact`].
    pub fn warmup(&self, bases: &[&str]) -> anyhow::Result<()> {
        let names: Vec<String> = bases.iter().map(|b| self.artifact(b)).collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        self.rt.warmup(&refs)
    }

    /// A block's frozen (shared-resident or device) + LoRA (host) tensors
    /// in artifact ABI order, ready to append after the leading args.
    pub fn block_args_mixed(&self, layer: usize) -> Vec<Arg<'_>> {
        let frozen = &self.frozen.blocks[layer];
        let mut v: Vec<Arg> =
            Vec::with_capacity(frozen.len() + 2 * PROJS.len());
        if self.dev_frozen.is_empty() {
            for t in frozen {
                v.push(Arg::Resident(t));
            }
        } else {
            for b in &self.dev_frozen[layer] {
                v.push(Arg::Device(b));
            }
        }
        for t in &self.adapters.lora[layer].tensors {
            v.push(Arg::Host(t));
        }
        v
    }

    /// The embedding table as a call argument (shared borrow or uploaded
    /// buffer).
    fn emb_arg(&self) -> Arg<'_> {
        match &self.dev_emb {
            Some(b) => Arg::Device(b),
            None => Arg::Resident(&self.frozen.embedding),
        }
    }

    fn fnorm_arg(&self) -> Arg<'_> {
        match &self.dev_fnorm {
            Some(b) => Arg::Device(b),
            None => Arg::Resident(&self.frozen.final_norm),
        }
    }

    /// Token embedding lookup.
    pub fn embed(&self, tokens: &HostTensor) -> anyhow::Result<HostTensor> {
        let out = self
            .rt
            .execute("embed_fwd", &[Arg::Host(tokens), self.emb_arg()])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// One block's forward. `x` is consumed conceptually; returns y.
    pub fn block_fwd(&self, layer: usize, x: &HostTensor)
        -> anyhow::Result<HostTensor>
    {
        let mut args: Vec<Arg> = vec![Arg::Host(x)];
        args.extend(self.block_args_mixed(layer));
        let out = self.rt.execute(&self.artifact("block_fwd"), &args)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Loss + gradient w.r.t. the final hidden state (manual CE backward).
    pub fn loss_grad(&self, h: &HostTensor, targets: &HostTensor)
        -> anyhow::Result<(f64, HostTensor)>
    {
        let out = self.rt.execute(
            "lm_loss_grad",
            &[Arg::Host(h), self.fnorm_arg(), self.emb_arg(),
              Arg::Host(targets)],
        )?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().scalar();
        Ok((loss, it.next().unwrap()))
    }

    /// Loss only (MeZO's forward).
    pub fn loss_only(&self, h: &HostTensor, targets: &HostTensor)
        -> anyhow::Result<f64>
    {
        let out = self.rt.execute(
            "lm_loss_fwd",
            &[Arg::Host(h), self.fnorm_arg(), self.emb_arg(),
              Arg::Host(targets)],
        )?;
        Ok(out[0].scalar())
    }

    /// Apply a block's 14 LoRA gradients (artifact output order: g_x,
    /// then (dA, dB) per PROJS site) and update immediately — the paper's
    /// §4.3 Backward Phase discipline. `outs` is the full backward output
    /// tuple; returns g_x (the only tensor that survives).
    pub fn apply_block_grads(
        &mut self,
        layer: usize,
        mut outs: Vec<HostTensor>,
    ) -> anyhow::Result<HostTensor> {
        anyhow::ensure!(outs.len() == 1 + 2 * PROJS.len(),
                        "expected 15 backward outputs, got {}", outs.len());
        let mut _sp = self.trace.span("opt", "train");
        _sp.arg("layer", Json::Num(layer as f64));
        // Gradients are transient: tracked only while the update runs.
        let g_bytes: u64 = outs[1..].iter().map(|t| t.bytes()).sum();
        let _g = self.tracker.track("grads:block", g_bytes);
        self.opt.begin_step();
        for i in (1..outs.len()).rev() {
            let grad = outs.pop().unwrap();
            let idx = i - 1; // 0..14 over lora tensors of this block
            let group = layer * 2 * PROJS.len() + idx;
            let params = self.adapters.lora[layer].tensors[idx].as_f32_mut();
            self.opt.update(group, params, grad.as_f32());
            // grad dropped here — "discarded immediately after being used"
        }
        Ok(outs.pop().unwrap())
    }

    /// Forward sweep storing block-INPUT checkpoints (all exact-grad
    /// engines share this). Returns the final hidden state.
    pub fn forward_with_checkpoints(
        &self,
        batch: &Batch,
        store: &mut CheckpointStore,
    ) -> anyhow::Result<HostTensor> {
        let _sp = self.trace.span("fwd", "train");
        let mut x = self.embed(&batch.tokens)?;
        for l in 0..self.rt.dims().n_layers {
            let y = self.block_fwd(l, &x)?;
            store.store(l, x)?; // the INPUT of block l (Appendix E.1)
            x = y;
        }
        Ok(x)
    }

    /// Wrap a step body with peak/latency measurement.
    pub fn measured<F>(&mut self, body: F) -> anyhow::Result<StepStats>
    where
        F: FnOnce(&mut Self) -> anyhow::Result<f64>,
    {
        self.tracker.reset_peak();
        let start = Instant::now();
        let mut sp = self.trace.span("step", "train");
        sp.arg("step", Json::Num((self.step + 1) as f64));
        let loss = body(self)?;
        drop(sp);
        let secs = start.elapsed().as_secs_f64();
        self.step += 1;
        // Timeline annotation: lets `mesp report` split the memory
        // timeline into per-step segments (no-op without a timeline).
        self.tracker.mark_step(self.step as u64);
        Ok(StepStats {
            step: self.step,
            loss,
            peak_bytes: self.tracker.peak(),
            secs,
            live_after: self.tracker.live(),
        })
    }
}
