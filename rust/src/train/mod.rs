//! Training engines: the paper's three systems plus the Table-5 ablation.
//!
//! All engines share the same forward scheduling (per-block calls into the
//! AOT artifacts, storing block-input checkpoints) and differ exactly
//! where the paper says they differ:
//!
//! * [`mesp::MespEngine`]   — backward = ONE fused call per block that
//!   recomputes intermediates internally (manual Appendix-A VJPs, Pallas
//!   LoRA kernel); nothing but checkpoints lives across calls.
//! * [`mebp::MebpEngine`]   — backward = recompute-forward call that emits
//!   the framework-retained residual set (held as real, tracked buffers),
//!   then a consume-residuals gradient call; mirrors checkpointed autodiff.
//! * [`mezo::MezoEngine`]   — no backward at all: two perturbed forwards
//!   and an SPSA update (paper eq. 4).
//! * [`storeh::StoreHEngine`] — MeSP but h = xA is stored at forward time
//!   and consumed at backward time (paper Table 5's "Store h").

pub mod checkpoint;
pub mod common;
pub mod mebp;
pub mod mesp;
pub mod mezo;
pub mod optimizer;
pub mod storeh;

use crate::data::Batch;

pub use checkpoint::CheckpointStore;
pub use optimizer::Optimizer;

/// Per-step result every engine reports.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    /// Peak tracked bytes during this step.
    pub peak_bytes: u64,
    /// Wall-clock seconds for the step.
    pub secs: f64,
    /// Live tracked bytes after the step (params + state only).
    pub live_after: u64,
}

/// A training engine: one method from the paper.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Run one optimization step on `batch`.
    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats>;

    /// Compute exact LoRA gradients for `batch` WITHOUT updating params
    /// (gradient-quality analysis, Table 3). Layer-major, tensor-ABI
    /// order. Engines without exact gradients return an estimate.
    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>>;

    /// Immutable access to shared state (model, runtime, tracker).
    fn ctx(&self) -> &common::EngineCtx;

    fn ctx_mut(&mut self) -> &mut common::EngineCtx;
}

/// Build the engine for a method. `mezo_eps` is the SPSA perturbation
/// scale (ignored by the exact-gradient engines).
pub fn build_engine(
    method: crate::config::Method,
    ctx: common::EngineCtx,
    mezo_eps: f32,
) -> anyhow::Result<Box<dyn Engine>> {
    use crate::config::Method;
    Ok(match method {
        Method::Mesp => Box::new(mesp::MespEngine::new(ctx)?),
        Method::Mebp => Box::new(mebp::MebpEngine::new(ctx)?),
        Method::Mezo => Box::new(mezo::MezoEngine::new(ctx)?.with_eps(mezo_eps)),
        Method::StoreH => Box::new(storeh::StoreHEngine::new(ctx)?),
    })
}
