//! MeSP — the paper's contribution (§4).
//!
//! Forward: per-block calls storing ONLY block-input checkpoints.
//! Backward: reverse block order; each block is ONE fused backend call
//! (`block_bwd_mesp`) that re-executes the forward internally with the
//! manually derived Appendix-A VJPs — the LoRA intermediate h = xA never
//! crosses the call boundary — and returns (g_x, dA×7, dB×7). LoRA
//! params are updated immediately and every buffer is dropped before the
//! next block, so peak memory is checkpoints + ONE block's working set.

use crate::data::Batch;
use crate::tensor::HostTensor;

use super::common::EngineCtx;
use super::{CheckpointStore, Engine, StepStats};

pub struct MespEngine {
    ctx: EngineCtx,
    store: CheckpointStore,
}

impl MespEngine {
    pub fn new(ctx: EngineCtx) -> anyhow::Result<Self> {
        ctx.warmup(&["embed_fwd", "block_fwd", "block_bwd_mesp",
                     "lm_loss_grad"])?;
        let store = CheckpointStore::new(ctx.tracker.clone(), ctx.spill_limit);
        Ok(MespEngine { ctx, store })
    }

    /// The paper's backward phase, shared with `gradients()`.
    fn backward<F>(
        ctx: &mut EngineCtx,
        store: &mut CheckpointStore,
        mut g: HostTensor,
        mut on_block: F,
    ) -> anyhow::Result<()>
    where
        F: FnMut(&mut EngineCtx, usize, Vec<HostTensor>)
            -> anyhow::Result<HostTensor>,
    {
        let _sp = ctx.trace.span("bwd", "train");
        let bwd = ctx.artifact("block_bwd_mesp");
        for l in (0..ctx.rt.dims().n_layers).rev() {
            let x = store.take(l)?; // checkpoint consumed, freed after call
            let mut args = vec![crate::runtime::Arg::Host(&x),
                                crate::runtime::Arg::Host(&g)];
            args.extend(ctx.block_args_mixed(l));
            let outs = ctx.rt.execute(&bwd, &args)?;
            drop(args);
            g = on_block(ctx, l, outs)?;
            // x and the previous g drop here — explicit lifecycle end
        }
        Ok(())
    }
}

impl Engine for MespEngine {
    fn name(&self) -> &'static str {
        "MeSP"
    }

    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats> {
        let store = &mut self.store;
        self.ctx.measured(|ctx| {
            let h = ctx.forward_with_checkpoints(batch, store)?;
            let (loss, g) = ctx.loss_grad(&h, &batch.targets)?;
            drop(h); // logits path done; final hidden state released
            Self::backward(ctx, store, g, |ctx, l, outs| {
                ctx.apply_block_grads(l, outs) // update immediately
            })?;
            Ok(loss)
        })
    }

    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>> {
        let store = &mut self.store;
        let ctx = &mut self.ctx;
        let h = ctx.forward_with_checkpoints(batch, store)?;
        let (_, g) = ctx.loss_grad(&h, &batch.targets)?;
        drop(h);
        let n_layers = ctx.rt.dims().n_layers;
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
        Self::backward(ctx, store, g, |_ctx, l, mut outs| {
            let mut flat = Vec::new();
            for t in &outs[1..] {
                flat.extend_from_slice(t.as_f32());
            }
            grads[l] = flat;
            outs.truncate(1);
            Ok(outs.pop().unwrap())
        })?;
        Ok(grads)
    }

    fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut EngineCtx {
        &mut self.ctx
    }
}
