//! MeZO baseline — zeroth-order SPSA (paper §3.2, eq. 4).
//!
//! Two full forward passes per step, one at θ+εz and one at θ−εz, with
//! z ~ N(0, I) over all LoRA parameters; the update is
//!     θ ← θ − lr · c · z,   c = (L(θ+εz) − L(θ−εz)) / 2ε.
//! No checkpoints, no backward artifacts. The perturbation z and the
//! projected-gradient scratch are held (tracked) across both forwards,
//! mirroring the measured MLX implementation — this is what makes MeZO's
//! memory grow with LoRA rank in the paper's Table 4.

use crate::data::Batch;
use crate::memory::Guard;
use crate::util::Rng;

use super::common::EngineCtx;
use super::{Engine, StepStats};

pub struct MezoEngine {
    ctx: EngineCtx,
    eps: f32,
    seed: u64,
}

impl MezoEngine {
    pub fn new(ctx: EngineCtx) -> anyhow::Result<Self> {
        ctx.warmup(&["embed_fwd", "block_fwd", "lm_loss_fwd"])?;
        Ok(MezoEngine { ctx, eps: 1e-3, seed: 0x5eed })
    }

    pub fn with_eps(mut self, eps: f32) -> Self {
        self.eps = eps;
        self
    }

    /// Inference forward: no checkpoints — each block's input is dropped
    /// as soon as its output exists (MeZO's memory advantage).
    fn forward_loss(ctx: &EngineCtx, batch: &Batch) -> anyhow::Result<f64> {
        let _sp = ctx.trace.span("fwd", "train");
        let mut x = ctx.embed(&batch.tokens)?;
        for l in 0..ctx.rt.dims().n_layers {
            x = ctx.block_fwd(l, &x)?;
        }
        ctx.loss_only(&x, &batch.targets)
    }

    /// Per-block perturbation vectors for one step, regenerated from the
    /// step seed (held live across both forwards, tracked).
    fn sample_z(&self, step: usize) -> (Vec<Vec<f32>>, Guard) {
        let base = Rng::new(self.seed ^ (step as u64).wrapping_mul(0x9e37));
        let z: Vec<Vec<f32>> = (0..self.ctx.rt.dims().n_layers)
            .map(|l| {
                let mut r = base.fork(l as u64);
                r.normal_vec(self.ctx.adapters.lora[l].param_count(), 1.0)
            })
            .collect();
        let bytes: u64 = z.iter().map(|v| 4 * v.len() as u64).sum();
        // ×2: z itself + the perturbed-parameter scratch the measured
        // implementation materializes (memory-model parity).
        let guard = self.ctx.tracker.track("mezo:perturbation", 2 * bytes);
        (z, guard)
    }

    fn perturb(ctx: &mut EngineCtx, z: &[Vec<f32>], scale: f32) {
        for (l, zl) in z.iter().enumerate() {
            let mut flat = ctx.adapters.lora[l].flatten();
            for (p, zi) in flat.iter_mut().zip(zl) {
                *p += scale * zi;
            }
            ctx.adapters.lora[l].unflatten(&flat);
        }
    }

    /// SPSA estimate: returns (loss⁺, loss⁻, c) leaving params restored.
    fn spsa(&mut self, batch: &Batch, z: &[Vec<f32>])
        -> anyhow::Result<(f64, f64, f32)>
    {
        let eps = self.eps;
        Self::perturb(&mut self.ctx, z, eps);
        let l_plus = Self::forward_loss(&self.ctx, batch)?;
        Self::perturb(&mut self.ctx, z, -2.0 * eps);
        let l_minus = Self::forward_loss(&self.ctx, batch)?;
        Self::perturb(&mut self.ctx, z, eps); // restore
        let c = ((l_plus - l_minus) / (2.0 * eps as f64)) as f32;
        Ok((l_plus, l_minus, c))
    }
}

impl Engine for MezoEngine {
    fn name(&self) -> &'static str {
        "MeZO"
    }

    fn step(&mut self, batch: &Batch) -> anyhow::Result<StepStats> {
        // Measure the WHOLE step (both forwards included): reset the peak
        // before z is sampled so the tracked peak covers the perturbation
        // state living across the two forward passes.
        self.ctx.tracker.reset_peak();
        let start = std::time::Instant::now();
        let mut sp = self.ctx.trace.span("step", "train");
        sp.arg("step", crate::util::json::Json::Num((self.ctx.step + 1) as f64));
        let (z, z_guard) = self.sample_z(self.ctx.step);
        let (l_plus, l_minus, c) = self.spsa(batch, &z)?;
        // θ ← θ − lr·c·z (plain SGD on the SPSA estimate, as in MeZO)
        let lr = self.ctx.opt.lr();
        for (l, zl) in z.iter().enumerate() {
            let mut flat = self.ctx.adapters.lora[l].flatten();
            for (p, zi) in flat.iter_mut().zip(zl) {
                *p -= lr * c * zi;
            }
            self.ctx.adapters.lora[l].unflatten(&flat);
        }
        drop(z_guard);
        drop(sp);
        self.ctx.step += 1;
        self.ctx.tracker.mark_step(self.ctx.step as u64);
        Ok(StepStats {
            step: self.ctx.step,
            loss: 0.5 * (l_plus + l_minus),
            peak_bytes: self.ctx.tracker.peak(),
            secs: start.elapsed().as_secs_f64(),
            live_after: self.ctx.tracker.live(),
        })
    }

    /// MeZO's "gradient" is the SPSA estimate ĝ = c·z — the uncorrelated
    /// estimator the paper dissects in Table 3.
    fn gradients(&mut self, batch: &Batch) -> anyhow::Result<Vec<Vec<f32>>> {
        let step = self.ctx.step;
        let (z, _guard) = self.sample_z(step);
        let (_, _, c) = self.spsa(batch, &z)?;
        Ok(z
            .into_iter()
            .map(|zl| zl.into_iter().map(|zi| c * zi).collect())
            .collect())
    }

    fn ctx(&self) -> &EngineCtx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut EngineCtx {
        &mut self.ctx
    }
}
