//! Checkpoint store: the block-input activations the forward phase keeps
//! (the ONLY cross-block state MeSP retains — paper §4.3 / Appendix E.1).
//!
//! Supports an optional disk-spill mode: when live checkpoint bytes would
//! exceed a budget, older checkpoints are written to a spill file and
//! reloaded on demand during the backward sweep. This is the "memory cap"
//! extension a real on-device runtime needs (the paper's unified-memory
//! budget), exercised by tests and the spill ablation.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};

use crate::memory::{Guard, MemoryTracker};
use crate::tensor::HostTensor;

enum Slot {
    Ram { t: HostTensor, _guard: Guard },
    Spilled { offset: u64, shape: Vec<usize>, len: usize },
}

pub struct CheckpointStore {
    slots: BTreeMap<usize, Slot>,
    tracker: MemoryTracker,
    /// 0 = never spill.
    budget: u64,
    spill: Option<std::fs::File>,
    spill_len: u64,
    pub spill_count: u64,
}

impl CheckpointStore {
    pub fn new(tracker: MemoryTracker, budget: u64) -> Self {
        CheckpointStore {
            slots: BTreeMap::new(),
            tracker,
            budget,
            spill: None,
            spill_len: 0,
            spill_count: 0,
        }
    }

    fn ram_bytes(&self) -> u64 {
        self.slots
            .values()
            .map(|s| match s {
                Slot::Ram { t, .. } => t.bytes(),
                Slot::Spilled { .. } => 0,
            })
            .sum()
    }

    /// Store block `layer`'s checkpoint tensor.
    pub fn store(&mut self, layer: usize, t: HostTensor) -> anyhow::Result<()> {
        if self.budget > 0 && self.ram_bytes() + t.bytes() > self.budget {
            self.spill_oldest()?;
        }
        let guard = self.tracker.track("ckpt:block", t.bytes());
        self.slots.insert(layer, Slot::Ram { t, _guard: guard });
        Ok(())
    }

    fn spill_file(&mut self) -> anyhow::Result<&mut std::fs::File> {
        if self.spill.is_none() {
            let path = std::env::temp_dir()
                .join(format!("mesp-spill-{}.bin", std::process::id()));
            let f = std::fs::OpenOptions::new()
                .create(true)
                .truncate(true)
                .read(true)
                .write(true)
                .open(&path)?;
            // unlink immediately; the fd keeps it alive
            let _ = std::fs::remove_file(&path);
            self.spill = Some(f);
        }
        Ok(self.spill.as_mut().unwrap())
    }

    /// Move the lowest-layer RAM checkpoint to disk (lowest = consumed
    /// last during the reverse-order backward, so it is the best victim).
    fn spill_oldest(&mut self) -> anyhow::Result<()> {
        let victim = self.slots.iter().find_map(|(k, v)| {
            matches!(v, Slot::Ram { .. }).then_some(*k)
        });
        let Some(layer) = victim else { return Ok(()) };
        let Slot::Ram { t, _guard } = self.slots.remove(&layer).unwrap() else {
            unreachable!()
        };
        let offset = self.spill_len;
        let data = t.as_f32();
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        let f = self.spill_file()?;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(bytes)?;
        self.spill_len += bytes.len() as u64;
        self.spill_count += 1;
        self.slots.insert(
            layer,
            Slot::Spilled { offset, shape: t.shape.clone(), len: data.len() },
        );
        Ok(())
    }

    /// Retrieve and REMOVE block `layer`'s checkpoint (the backward sweep
    /// consumes each checkpoint exactly once, freeing it immediately —
    /// the paper's lifecycle discipline).
    pub fn take(&mut self, layer: usize) -> anyhow::Result<HostTensor> {
        match self.slots.remove(&layer) {
            Some(Slot::Ram { t, _guard }) => Ok(t),
            Some(Slot::Spilled { offset, shape, len }) => {
                let f = self
                    .spill
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("spill file missing"))?;
                let mut buf = vec![0u8; len * 4];
                f.seek(SeekFrom::Start(offset))?;
                f.read_exact(&mut buf)?;
                let mut data = vec![0f32; len];
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        buf.as_ptr(),
                        data.as_mut_ptr() as *mut u8,
                        buf.len(),
                    );
                }
                Ok(HostTensor::f32(&shape, data))
            }
            None => anyhow::bail!("checkpoint for layer {layer} not stored"),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Drop everything (end of step).
    pub fn clear(&mut self) {
        self.slots.clear();
        self.spill_len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(val: f32, n: usize) -> HostTensor {
        HostTensor::f32(&[n], vec![val; n])
    }

    #[test]
    fn store_take_roundtrip() {
        let tr = MemoryTracker::new();
        let mut s = CheckpointStore::new(tr.clone(), 0);
        for l in 0..4 {
            s.store(l, tensor(l as f32, 8)).unwrap();
        }
        assert_eq!(tr.live(), 4 * 32);
        // reverse-order consumption
        for l in (0..4).rev() {
            let t = s.take(l).unwrap();
            assert_eq!(t.as_f32()[0], l as f32);
        }
        assert_eq!(tr.live(), 0);
        assert!(s.take(0).is_err(), "double-take must fail");
    }

    #[test]
    fn spill_and_reload() {
        let tr = MemoryTracker::new();
        // budget of ~2 tensors of 1024 f32
        let mut s = CheckpointStore::new(tr.clone(), 2 * 4096 + 100);
        for l in 0..5 {
            s.store(l, tensor(l as f32 + 0.5, 1024)).unwrap();
        }
        assert!(s.spill_count >= 3, "spilled {} times", s.spill_count);
        assert!(tr.live() <= 3 * 4096, "ram bounded: {}", tr.live());
        for l in (0..5).rev() {
            let t = s.take(l).unwrap();
            assert_eq!(t.as_f32()[17], l as f32 + 0.5, "layer {l} intact");
            assert_eq!(t.len(), 1024);
        }
    }

    #[test]
    fn clear_releases_everything() {
        let tr = MemoryTracker::new();
        let mut s = CheckpointStore::new(tr.clone(), 0);
        s.store(0, tensor(1.0, 64)).unwrap();
        s.store(1, tensor(2.0, 64)).unwrap();
        s.clear();
        assert_eq!(tr.live(), 0);
        assert!(s.is_empty());
    }
}
