//! # mesp — Memory-Efficient Structured Backpropagation
//!
//! A full-system reproduction of *"Memory-Efficient Structured
//! Backpropagation for On-Device LLM Fine-Tuning"* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator: per-block forward
//!   scheduling with checkpoint-only storage, reverse-order backward with
//!   immediate optimizer updates and explicit tensor lifecycle management
//!   (the paper's contribution), plus the MeBP / MeZO / store-h baselines,
//!   a byte-accurate memory tracker, an analytical Qwen-scale memory
//!   model, a data pipeline, metrics, and reproduction drivers for every
//!   table and figure in the paper.
//! * **L2 (python/compile/model.py)** — the Qwen2.5-style transformer
//!   block and the manually derived Appendix-A backward passes, AOT-lowered
//!   to HLO text once (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the hot spots,
//!   headlined by the fused LoRA gradient that recomputes `h = xA` in VMEM.
//!
//! Quickstart: `make artifacts && cargo run --release -- train --config toy`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod reproduce;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
