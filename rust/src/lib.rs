//! # mesp — Memory-Efficient Structured Backpropagation
//!
//! A full-system reproduction of *"Memory-Efficient Structured
//! Backpropagation for On-Device LLM Fine-Tuning"*:
//!
//! * **L3 (this crate)** — the training coordinator: per-block forward
//!   scheduling with checkpoint-only storage, reverse-order backward with
//!   immediate optimizer updates and explicit tensor lifecycle management
//!   (the paper's contribution), plus the MeBP / MeZO / store-h baselines,
//!   a byte-accurate memory tracker, an analytical Qwen-scale memory
//!   model, a data pipeline, metrics, and reproduction drivers for every
//!   table and figure in the paper. The [`fleet`] subsystem schedules
//!   many concurrent sessions under a shared device memory budget, using
//!   the analytical model for admission control.
//! * **Compute backends** ([`runtime::Backend`]) — the engines talk to a
//!   pluggable backend trait. The default [`runtime::ReferenceBackend`]
//!   implements the whole artifact surface (including the Appendix-A
//!   manual LoRA VJPs that recompute `h = xA` in the backward) in pure
//!   Rust, so the system builds and trains from a clean checkout. The
//!   `pjrt` cargo feature adds [`runtime::client::Runtime`], which
//!   executes AOT-compiled HLO artifacts instead.
//! * **L2 (python/compile/model.py)** — the Qwen2.5-style transformer
//!   block and the manually derived Appendix-A backward passes, AOT-lowered
//!   to HLO text once (`make artifacts`; pjrt backend only).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the hot spots,
//!   headlined by the fused LoRA gradient that recomputes `h = xA` in VMEM.
//!
//! Quickstart: `cargo run --release -- train --config toy`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod persist;
pub mod reproduce;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
