//! Simulation-only model presets: the Qwen2.5 family dims the paper
//! measures on an iPhone 17 Pro. These are fed to the analytical memory
//! model (`memory::model`) to regenerate the paper's tables — they are
//! never compiled to artifacts (0.5B+ params would not train on the CPU
//! testbed in reasonable time, and peak memory depends only on shapes).
//!
//! Dims follow the Qwen2.5 technical report (Qwen Team, 2024):
//!   0.5B: 24 layers, d=896,  14 Q heads / 2 KV heads, ffn 4864
//!   1.5B: 28 layers, d=1536, 12 Q heads / 2 KV heads, ffn 8960
//!   3B:   36 layers, d=2048, 16 Q heads / 2 KV heads, ffn 11008
//! All with head_dim 128 on 1.5B/3B and 64 on 0.5B, vocab 151936.

use super::ModelDims;

/// Qwen2.5-0.5B at the given sequence length and LoRA rank.
pub fn qwen25_05b(seq: usize, rank: usize) -> ModelDims {
    ModelDims {
        name: format!("qwen2.5-0.5b/seq{seq}/r{rank}"),
        vocab: 151_936,
        d_model: 896,
        n_layers: 24,
        n_heads: 14,
        n_kv_heads: 2,
        head_dim: 64,
        d_ff: 4864,
        seq,
        batch: 1,
        rank,
        alpha: 2.0 * rank as f32,
    }
}

/// Qwen2.5-1.5B.
pub fn qwen25_15b(seq: usize, rank: usize) -> ModelDims {
    ModelDims {
        name: format!("qwen2.5-1.5b/seq{seq}/r{rank}"),
        vocab: 151_936,
        d_model: 1536,
        n_layers: 28,
        n_heads: 12,
        n_kv_heads: 2,
        head_dim: 128,
        d_ff: 8960,
        seq,
        batch: 1,
        rank,
        alpha: 2.0 * rank as f32,
    }
}

/// Qwen2.5-3B.
pub fn qwen25_3b(seq: usize, rank: usize) -> ModelDims {
    ModelDims {
        name: format!("qwen2.5-3b/seq{seq}/r{rank}"),
        vocab: 151_936,
        d_model: 2048,
        n_layers: 36,
        n_heads: 16,
        n_kv_heads: 2,
        head_dim: 128,
        d_ff: 11008,
        seq,
        batch: 1,
        rank,
        alpha: 2.0 * rank as f32,
    }
}

/// Look up a sim preset by the names used in the paper's tables.
pub fn by_name(name: &str, seq: usize, rank: usize) -> anyhow::Result<ModelDims> {
    match name.to_ascii_lowercase().as_str() {
        "0.5b" | "qwen2.5-0.5b" => Ok(qwen25_05b(seq, rank)),
        "1.5b" | "qwen2.5-1.5b" => Ok(qwen25_15b(seq, rank)),
        "3b" | "qwen2.5-3b" => Ok(qwen25_3b(seq, rank)),
        _ => anyhow::bail!("unknown sim preset '{name}' (0.5b|1.5b|3b)"),
    }
}

// ---------------------------------------------------------------------
// Runnable configs: the dims the reference backend instantiates directly
// (and the pjrt backend compiles via `make artifacts`). Single source of
// truth on the Rust side, mirroring python/compile/configs.py — keep the
// two in sync.

/// Minimal dims for fast unit/integration tests and gradcheck.
fn toy(name: &str) -> ModelDims {
    ModelDims {
        name: name.into(),
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 128,
        seq: 32,
        batch: 1,
        rank: 4,
        alpha: 8.0,
    }
}

/// Convergence runs, MeZO gradient-quality analysis, benches.
fn small() -> ModelDims {
    ModelDims {
        name: "small".into(),
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 32,
        d_ff: 256,
        seq: 64,
        batch: 1,
        rank: 8,
        alpha: 16.0,
    }
}

/// Weight-dominated dims for the shared-base-weight fleet demo: a fat
/// f32 embedding (vocab 131072 × d 256 ≈ 128 MB) over two thin blocks
/// at seq 4, so the resident frozen base dwarfs the per-job activation
/// cost by well over 8× even on many-core machines (the per-job cost
/// includes a per-available-core GEMM packing term). A budget sized for
/// TWO private-weight jobs then overlaps ten-plus jobs that share one
/// cached base — the `tests/shared_weights.rs` scenario and the CI
/// shared-weights smoke. All quantized d_ins (256, 128) divide the q4
/// group size, so the preset runs in both precisions.
fn basebound() -> ModelDims {
    ModelDims {
        name: "basebound".into(),
        vocab: 131072,
        d_model: 256,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 64,
        d_ff: 128,
        seq: 4,
        batch: 1,
        rank: 4,
        alpha: 8.0,
    }
}

/// Long-context loss-head stress preset: a fat vocab (32768) over a thin
/// trunk (d 128) at seq 512, so the `m×vocab` logits (≈67 MB f32) dwarf
/// every per-block intermediate — the regime where `lm_loss_grad`'s
/// scratch dominates the tracked peak and `--loss-chunk` pays. The
/// obs-tier CI envelope check runs `mesp report` here: the pre-fix
/// one-buffer `loss_head` model term under-counted this preset by ~67 MB.
/// All quantized d_ins (128, 256) divide the q4 group size.
fn longctx() -> ModelDims {
    ModelDims {
        name: "longctx".into(),
        vocab: 32768,
        d_model: 128,
        n_layers: 8,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 64,
        d_ff: 256,
        seq: 512,
        batch: 1,
        rank: 8,
        alpha: 16.0,
    }
}

/// The end-to-end validation model: ~98M params (DESIGN.md §2).
fn e2e100m() -> ModelDims {
    ModelDims {
        name: "e2e100m".into(),
        vocab: 16384,
        d_model: 768,
        n_layers: 12,
        n_heads: 12,
        n_kv_heads: 4,
        head_dim: 64,
        d_ff: 2304,
        seq: 128,
        batch: 1,
        rank: 8,
        alpha: 16.0,
    }
}

/// Dims of a runnable config by name. `toy_flash` shares toy's dims: on
/// the pjrt backend it selects the flash-attention/all-Pallas artifact
/// set; on the reference backend both names run the same math.
pub fn compiled(name: &str) -> anyhow::Result<ModelDims> {
    match name {
        "toy" => Ok(toy("toy")),
        "toy_flash" => Ok(toy("toy_flash")),
        "small" => Ok(small()),
        "basebound" => Ok(basebound()),
        "longctx" => Ok(longctx()),
        "e2e100m" => Ok(e2e100m()),
        _ => anyhow::bail!(
            "unknown config '{name}' (toy|toy_flash|small|basebound|longctx|e2e100m)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_names() {
        // each preset's frozen params should land near its nominal size
        let p05 = qwen25_05b(256, 8).frozen_params_total() as f64 / 1e9;
        let p15 = qwen25_15b(256, 8).frozen_params_total() as f64 / 1e9;
        let p3 = qwen25_3b(256, 8).frozen_params_total() as f64 / 1e9;
        assert!((0.35..0.65).contains(&p05), "{p05}");
        assert!((1.2..1.9).contains(&p15), "{p15}");
        assert!((2.5..3.5).contains(&p3), "{p3}");
    }

    #[test]
    fn gqa_ratio_is_integral() {
        for d in [qwen25_05b(256, 8), qwen25_15b(256, 8), qwen25_3b(256, 8)] {
            assert_eq!(d.n_heads % d.n_kv_heads, 0);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("0.5b", 128, 4).is_ok());
        assert!(by_name("7b", 128, 4).is_err());
    }

    #[test]
    fn compiled_configs_resolve() {
        let t = compiled("toy").unwrap();
        assert_eq!((t.d_model, t.n_layers, t.seq, t.rank), (64, 2, 32, 4));
        assert_eq!(t.scale(), 2.0);
        let s = compiled("small").unwrap();
        assert_eq!((s.d_model, s.n_layers), (128, 4));
        let e = compiled("e2e100m").unwrap();
        // ~98M frozen params (DESIGN.md §2)
        let p = e.frozen_params_total();
        assert!((80_000_000..120_000_000).contains(&p), "{p}");
        assert!(compiled("toy_flash").is_ok());
        assert!(compiled("huge").is_err());
    }

    #[test]
    fn basebound_is_weight_dominated_and_q4able() {
        use crate::config::QuantMode;
        use crate::memory::model::resident_weight_bytes;
        let d = compiled("basebound").unwrap();
        assert_eq!((d.d_model, d.n_layers, d.seq), (256, 2, 4));
        assert_eq!(d.n_heads * d.head_dim, d.d_model);
        // the frozen base must dwarf a job's activation cost: ~128 MB of
        // embedding alone
        let w = resident_weight_bytes(&d, QuantMode::F32);
        assert!(w > 120 << 20, "resident base only {w} bytes");
        // q4-eligible: every quantized d_in divides the group size
        assert_eq!(d.d_model % crate::model::quant::GROUP, 0);
        assert_eq!(d.d_ff % crate::model::quant::GROUP, 0);
    }

    #[test]
    fn longctx_is_loss_head_dominated_and_q4able() {
        use crate::config::{Method, OptimizerKind, QuantMode};
        use crate::memory::model::{peak_q, Widths};
        let d = compiled("longctx").unwrap();
        assert_eq!(d.n_heads * d.head_dim, d.d_model);
        // the full logits must dwarf every per-block term: this is the
        // preset where the loss head IS the peak
        let b = peak_q(
            Method::Mesp, &d, OptimizerKind::Sgd, Widths::tracked(), QuantMode::F32,
        );
        // Compare against the shape-only per-block terms (b.scratch also
        // carries a per-CORE packing charge, which would make this
        // assertion depend on the machine running the tests).
        assert!(
            b.loss_head > 4 * (b.block_intermediates + b.checkpoints),
            "loss head {} must dominate the block terms {} + {}",
            b.loss_head,
            b.block_intermediates,
            b.checkpoints
        );
        // q4-eligible: every quantized d_in divides the group size
        assert_eq!(d.d_model % crate::model::quant::GROUP, 0);
        assert_eq!(d.d_ff % crate::model::quant::GROUP, 0);
        assert_eq!(d.q_dim() % crate::model::quant::GROUP, 0);
    }
}
