//! Hand-rolled CLI (no clap in the offline build): subcommands + --flag
//! value parsing with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus --key value flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                anyhow::bail!("expected a subcommand before '{cmd}'");
            }
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    anyhow::bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f32(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a float, got '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()),
                 Some("true") | Some("1") | Some("yes"))
    }

    /// Error on unknown flags (catches typos early).
    pub fn expect_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                anyhow::bail!(
                    "unknown flag --{k} for '{}' (known: {})",
                    self.command,
                    known.join(", ")
                );
            }
        }
        Ok(())
    }

    /// Validate the whole command line against the per-subcommand flag
    /// allowlists: unknown subcommands and typo'd flags (`--budegt-mb`)
    /// fail loudly with the USAGE text instead of being ignored.
    pub fn validate(&self) -> anyhow::Result<()> {
        let Some(known) = known_flags(&self.command) else {
            anyhow::bail!("unknown command '{}'\n\n{USAGE}", self.command);
        };
        self.expect_known(known)
            .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))
    }
}

// Per-subcommand flag allowlists — the single source of truth for
// `Args::validate` (and the reference the USAGE text must stay in sync
// with).
pub const TRAIN_FLAGS: &[&str] = &[
    "config", "backend", "method", "steps", "lr", "seed", "optimizer",
    "mezo-eps", "log-every", "spill-limit", "metrics", "artifacts",
    "kernel", "threads", "quant", "save-every", "snapshot-dir", "resume",
    "trace", "metrics-out", "tune", "loss-chunk", "act-compress",
];
pub const FLEET_FLAGS: &[&str] = &[
    "config", "backend", "methods", "steps", "lr", "seed", "optimizer",
    "budget-mb", "jobs", "workers", "job-file", "artifacts",
    "kernel", "threads", "quant", "budget-schedule", "preempt",
    "snapshot-dir", "print-cost", "trace", "metrics-out", "tune",
    "loss-chunk", "act-compress",
];
pub const SERVE_FLAGS: &[&str] = &[
    "config", "backend", "steps", "lr", "seed", "optimizer", "kernel",
    "threads", "quant", "loss-chunk", "act-compress", "artifacts",
    "socket", "snapshot-dir", "budget-mb", "workers", "budget-schedule",
    "checkpoint-every", "quota", "tenant-weights", "metrics-out",
];
pub const LOADGEN_FLAGS: &[&str] = &[
    "socket", "arrivals", "rate", "tenants", "sim-us", "seed", "steps",
    "out", "time-scale", "squeeze", "diurnal-amp", "diurnal-period",
    "burst-every", "burst-len", "burst-x", "real", "shutdown",
];
pub const SIMULATE_FLAGS: &[&str] = &["model", "seq", "rank", "breakdown"];
pub const GRADCHECK_FLAGS: &[&str] = &[
    "config", "backend", "seeds", "tol", "artifacts", "kernel", "threads",
    "quant",
];
pub const MEZO_QUALITY_FLAGS: &[&str] = &["config"];
pub const REPRODUCE_FLAGS: &[&str] = &["table", "fig", "all", "steps", "out"];
pub const INSPECT_FLAGS: &[&str] = &["config", "backend", "artifacts"];
pub const REPORT_FLAGS: &[&str] = &[
    "config", "methods", "steps", "kernel", "threads", "quant", "seed",
    "optimizer", "artifacts", "loss-chunk", "act-compress",
];

/// The flag allowlist of a subcommand; `None` for unknown subcommands.
pub fn known_flags(command: &str) -> Option<&'static [&'static str]> {
    match command {
        "train" => Some(TRAIN_FLAGS),
        "fleet" => Some(FLEET_FLAGS),
        "serve" => Some(SERVE_FLAGS),
        "loadgen" => Some(LOADGEN_FLAGS),
        "simulate" => Some(SIMULATE_FLAGS),
        "gradcheck" => Some(GRADCHECK_FLAGS),
        "mezo-quality" => Some(MEZO_QUALITY_FLAGS),
        "reproduce" => Some(REPRODUCE_FLAGS),
        "inspect" => Some(INSPECT_FLAGS),
        "report" => Some(REPORT_FLAGS),
        "help" | "" => Some(&[]),
        _ => None,
    }
}

pub const USAGE: &str = "\
mesp — Memory-Efficient Structured Backpropagation (paper reproduction)

USAGE: mesp <command> [--flag value]...

COMMANDS
  train       Run a training session.
              --config toy|small|e2e100m  --method mesp|mebp|mezo|storeh
              --backend reference|pjrt  --steps N  --lr F  --seed N
              --optimizer sgd|momentum|adam  --mezo-eps F  --log-every N
              --metrics PATH.jsonl  --spill-limit BYTES  --artifacts DIR
              --kernel naive|tiled|parallel  --threads N (0 = all cores)
              --quant f32|q4 (q4: frozen base weights stay int4-packed
              for the whole session, dequantized inside the kernels)
              --save-every N (snapshot every N steps; 0 = never)
              --snapshot-dir DIR (where snapshots go; default snapshots/)
              --resume PATH.snap (resume a suspended session bitwise;
              the snapshot's config/method/seed win over these flags)
              --trace PATH.json (write a Chrome trace-event file: step/
              fwd/bwd/opt spans, per-GEMM kernel events — open in
              Perfetto; observe-only, losses stay bitwise identical)
              --metrics-out PATH.jsonl (write the metrics-registry
              snapshot: counters/gauges/histograms, one JSON per line)
              --tune (sweep GEMM tile candidates on a calibration set
              first, persist the winner to the tuning profile —
              $MESP_TUNE_PROFILE or ~/.cache/mesp/tune.json — and run
              with it; later runs load the profile automatically)
              --loss-chunk N (stream the lm head in tiles of N sequence
              rows: only N×vocab logits floats live at once, losses stay
              bitwise identical; 0 = unchunked)
              --act-compress none|int8 (store-h's saved h = xA and
              MeBP's residual window held as int8+outlier blobs instead
              of f32 — lossy: gradients shift within quantization error)
  fleet       Run many sessions concurrently under a device memory budget
              (admission control via the analytical peak-memory model).
              --budget-mb N  --jobs N  --workers N  --config toy|small
              --methods mesp,mebp|all  --steps N  --lr F  --seed N
              --optimizer sgd|momentum|adam  --job-file PATH.jsonl
              (job lines may set "priority": 0..9 — higher wins)
              --backend reference|pjrt  --artifacts DIR  --quant f32|q4
              --kernel naive|tiled|parallel  --threads N (0 = auto:
              cores/workers, so jobs never oversubscribe the machine)
              --preempt (arriving higher-priority jobs may park running
              lower-priority jobs: snapshot → requeue → bitwise resume)
              --budget-schedule step:mb,step:mb (shrink/grow the budget
              after N fleet-wide steps; implies --preempt)
              --snapshot-dir DIR (where preempted sessions park)
              --print-cost (print per-method admission costs and exit —
              CI sizes preemption budgets with this)
              --trace PATH.json (fleet-wide Chrome trace: job lifecycle
              admit/park/resume instants + per-session spans, one file)
              --metrics-out PATH.jsonl (fleet metrics-registry snapshot:
              admission waits, preempt churn, step latencies)
              --tune (autotune GEMM tiles before the fleet starts; see
              train --tune)
              --loss-chunk N / --act-compress none|int8 (as in train;
              both feed the admission cost model, so chunked /
              compressed jobs admit more densely under one budget)
  serve       Long-lived fleet daemon on a Unix socket: JSONL protocol
              (submit/status/cancel/set-budget/drain/shutdown), per-
              tenant quotas, weighted-fair dispatch, crash recovery.
              Full spec: docs/serving.md. Exit codes: 0 clean, 1 runtime
              failure, 2 job failures, 3 startup failure.
              --socket PATH  --snapshot-dir DIR (sidecars + checkpoints;
              rescanned on startup to re-admit interrupted jobs bitwise)
              --budget-mb N  --workers N
              --checkpoint-every N (checkpoint running jobs every N
              steps; 0 = only on preemption/shutdown)
              --budget-schedule step:mb,step:mb (as in fleet)
              --quota tenant:mb,... (per-tenant admission quotas)
              --tenant-weights tenant:w,... (WFQ dispatch weights)
              --metrics-out PATH.jsonl (registry snapshot at exit)
              Base-config flags as in train: --config --backend --steps
              --lr --seed --optimizer --kernel --threads --quant
              --loss-chunk --act-compress --artifacts
  loadgen     Replay a synthetic arrival trace against a live serve
              daemon; writes BENCH_serve.json (latency percentiles,
              preempt churn, per-tenant fairness).
              --socket PATH  --arrivals N  --rate JOBS/S  --tenants N
              --steps N (per job)  --sim-us N (virtual step latency)
              --seed N (same seed = identical trace)  --out PATH.json
              --time-scale F (1 = real time, 0 = flat out)
              --squeeze idx:mb,... (set-budget after arrival idx)
              --diurnal-amp F  --diurnal-period SECS (rate sine wave)
              --burst-every N  --burst-len N  --burst-x F (burst shape)
              --real (full training jobs instead of sim jobs)
              --shutdown (send shutdown after the trace drains)
  simulate    Evaluate the analytical memory model at Qwen2.5 dims.
              --model 0.5b|1.5b|3b  --seq N  --rank N  [--breakdown]
  gradcheck   Assert MeSP ≡ MeBP ≡ store-h gradients on a runnable config.
              --config toy  --backend reference|pjrt  --seeds N  --tol F
              --kernel naive|tiled|parallel  --threads N  --quant f32|q4
  mezo-quality  Gradient-quality analysis (Table 3). --config small
  reproduce   Regenerate paper tables. --table 1..11 | --fig 2 | --all
              [--steps N]  [--out FILE]
  inspect     List a config's artifact specs. --config toy
              --backend reference|pjrt  [--artifacts DIR]
  report      Per-step memory profile from the tracker timeline, checked
              against the analytical peak-memory envelope per method.
              --config toy  --methods mesp,mebp,storeh  --steps N
              --kernel naive|tiled|parallel  --threads N  --quant f32|q4
              --seed N  --optimizer sgd|momentum|adam  --artifacts DIR
              --loss-chunk N  --act-compress none|int8 (the envelope is
              evaluated at the same chunk/compression settings)
  help        This text.

The default backend is `reference`: a pure-Rust in-process implementation
of the artifact surface that needs no XLA toolchain or Python artifacts.
Build with `--features pjrt` (and run `make artifacts`) to execute the
AOT-compiled HLO artifacts instead.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("train --config toy --steps 50 --verbose");
        assert_eq!(a.command, "train");
        assert_eq!(a.str("config", "x"), "toy");
        assert_eq!(a.usize("steps", 0).unwrap(), 50);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("simulate --model=3b --seq=512");
        assert_eq!(a.str("model", ""), "3b");
        assert_eq!(a.usize("seq", 0).unwrap(), 512);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("train");
        assert_eq!(a.usize("steps", 7).unwrap(), 7);
        assert_eq!(a.f32("lr", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn bad_values_error() {
        let a = parse("train --steps abc");
        assert!(a.usize("steps", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("train --confg toy");
        assert!(a.expect_known(&["config"]).is_err());
        let b = parse("train --config toy");
        assert!(b.expect_known(&["config"]).is_ok());
    }

    #[test]
    fn flag_before_command_rejected() {
        assert!(Args::parse(vec!["--x".to_string()]).is_err());
    }

    #[test]
    fn validate_catches_typos_with_usage() {
        let a = parse("fleet --budegt-mb 64");
        let err = a.validate().unwrap_err().to_string();
        assert!(err.contains("unknown flag --budegt-mb"), "{err}");
        assert!(err.contains("USAGE"), "error must include usage: {err}");
        assert!(parse("fleet --budget-mb 64").validate().is_ok());
    }

    #[test]
    fn validate_rejects_unknown_subcommand() {
        let err = parse("frobnicate").validate().unwrap_err().to_string();
        assert!(err.contains("unknown command"), "{err}");
        assert!(err.contains("USAGE"), "{err}");
    }

    #[test]
    fn every_subcommand_has_an_allowlist() {
        for cmd in ["train", "fleet", "serve", "loadgen", "simulate",
                    "gradcheck", "mezo-quality", "reproduce", "inspect",
                    "report", "help", ""] {
            assert!(known_flags(cmd).is_some(), "missing allowlist: {cmd}");
        }
        assert!(known_flags("nope").is_none());
    }

    #[test]
    fn usage_documents_every_subcommand_flag() {
        // keep USAGE and the allowlists from drifting apart
        for flags in [TRAIN_FLAGS, FLEET_FLAGS, SERVE_FLAGS, LOADGEN_FLAGS,
                      SIMULATE_FLAGS, GRADCHECK_FLAGS, MEZO_QUALITY_FLAGS,
                      REPRODUCE_FLAGS, INSPECT_FLAGS, REPORT_FLAGS] {
            for f in flags {
                assert!(USAGE.contains(&format!("--{f}")),
                        "USAGE missing --{f}");
            }
        }
    }
}
