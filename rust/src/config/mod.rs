//! Typed configuration: model dimensions, training settings, method
//! selection. Compiled configs (toy/small/e2e100m) load their dims from
//! `artifacts/<name>/manifest.json`; simulation-only configs (the Qwen2.5
//! family the paper measures on-device) come from `presets` and are only
//! ever fed to the analytical memory model.

pub mod cli;
pub mod presets;

/// The seven LoRA adapter sites, canonical order — must match
/// `python/compile/model.py::PROJS` (artifact ABI).
pub const PROJS: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

/// The nine frozen per-block weights, canonical order (artifact ABI).
pub const FROZEN: [&str; 9] =
    ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"];

/// The seven frozen matrices the q4 path keeps int4-packed, in the q4
/// artifact ABI order (FROZEN minus the two RMSNorm gain vectors).
pub const QUANT_MATS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

/// Training method — the paper's three systems plus the Table-5 ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Memory-efficient Structured Backpropagation (the contribution).
    Mesp,
    /// Gradient checkpointing + framework-autodiff baseline.
    Mebp,
    /// Zeroth-order (SPSA) baseline.
    Mezo,
    /// MeSP variant that stores h = xA instead of recomputing (Table 5).
    StoreH,
}

impl Method {
    /// All four methods, canonical order.
    pub const ALL: [Method; 4] =
        [Method::Mesp, Method::Mebp, Method::Mezo, Method::StoreH];

    pub fn parse(s: &str) -> anyhow::Result<Method> {
        match s.to_ascii_lowercase().as_str() {
            "mesp" => Ok(Method::Mesp),
            "mebp" => Ok(Method::Mebp),
            "mezo" => Ok(Method::Mezo),
            "storeh" | "store-h" => Ok(Method::StoreH),
            _ => anyhow::bail!("unknown method '{s}' (mesp|mebp|mezo|storeh)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Mesp => "MeSP",
            Method::Mebp => "MeBP",
            Method::Mezo => "MeZO",
            Method::StoreH => "Store-h",
        }
    }

    /// Parse a comma-separated method list; `all` expands to every
    /// method. Used by the `mesp fleet` `--methods` flag.
    pub fn parse_list(s: &str) -> anyhow::Result<Vec<Method>> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            if p.eq_ignore_ascii_case("all") {
                out.extend(Method::ALL);
            } else {
                out.push(Method::parse(p)?);
            }
        }
        anyhow::ensure!(!out.is_empty(), "empty method list '{s}'");
        Ok(out)
    }
}

/// Compute-backend selection (see `runtime::Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust in-process reference backend — no external toolchain.
    #[default]
    Reference,
    /// PJRT execution of AOT-compiled HLO artifacts (cargo feature
    /// `pjrt`; requires `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "reference" | "ref" | "cpu" => Ok(BackendKind::Reference),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            _ => anyhow::bail!("unknown backend '{s}' (reference|pjrt)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Precision of the frozen base weights a training session keeps
/// resident (paper §4.5). `F32` uploads the full-precision matrices;
/// `Q4` packs the seven projection matrices int4 (two weights per byte +
/// per-group scales via `model::quant`) and keeps them packed for the
/// whole session — every frozen-weight GEMM, forward and backward,
/// dequantizes panels on the fly inside the kernel. Norm gains, the
/// embedding and all LoRA adapters stay f32 in both modes, so gradients
/// w.r.t. A/B remain exact for the quantized forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum QuantMode {
    #[default]
    F32,
    Q4,
}

impl QuantMode {
    pub const ALL: [QuantMode; 2] = [QuantMode::F32, QuantMode::Q4];

    pub fn parse(s: &str) -> anyhow::Result<QuantMode> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "none" => Ok(QuantMode::F32),
            "q4" | "int4" => Ok(QuantMode::Q4),
            _ => anyhow::bail!("unknown quant mode '{s}' (f32|q4)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::F32 => "f32",
            QuantMode::Q4 => "q4",
        }
    }
}

/// Compression of BUFFERED activations — store-h's saved `h = xA` and
/// MeBP's between-phase residual window (`--act-compress`). Distinct
/// from [`QuantMode`], which packs the frozen *weights*: this packs
/// *activations* at save time (per-group int8 scales + structured
/// outlier storage, HyC-LoRA style, `model::actquant`) and dequantizes
/// them in the backward — a tunable memory/fidelity axis between MeSP
/// (recompute) and store-h (cache). `None` keeps the exact-f32 cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ActCompress {
    #[default]
    None,
    Int8,
}

impl ActCompress {
    pub const ALL: [ActCompress; 2] = [ActCompress::None, ActCompress::Int8];

    pub fn parse(s: &str) -> anyhow::Result<ActCompress> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "f32" | "off" => Ok(ActCompress::None),
            "int8" | "i8" => Ok(ActCompress::Int8),
            _ => anyhow::bail!("unknown act-compress mode '{s}' (none|int8)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ActCompress::None => "none",
            ActCompress::Int8 => "int8",
        }
    }
}

/// GEMM kernel variant of the reference backend's kernel engine
/// (`runtime::kernels`). `Naive` is the original scalar triple loop kept
/// as the correctness oracle; `Tiled` is the cache-blocked register-tiled
/// kernel; `Parallel` adds row-panel fan-out over scoped threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Scalar oracle (keeps the data-dependent zero-skip fast path).
    Naive,
    /// Cache-blocked + register-tiled, single thread, branch-free.
    Tiled,
    /// Tiled kernel fanned out over row panels (`std::thread::scope`).
    #[default]
    Parallel,
}

impl KernelKind {
    pub const ALL: [KernelKind; 3] =
        [KernelKind::Naive, KernelKind::Tiled, KernelKind::Parallel];

    pub fn parse(s: &str) -> anyhow::Result<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(KernelKind::Naive),
            "tiled" => Ok(KernelKind::Tiled),
            "parallel" => Ok(KernelKind::Parallel),
            _ => anyhow::bail!("unknown kernel '{s}' (naive|tiled|parallel)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Tiled => "tiled",
            KernelKind::Parallel => "parallel",
        }
    }
}

/// Model + runtime shape parameters. Mirrors python ModelConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub rank: usize,
    pub alpha: f32,
}

impl ModelDims {
    pub fn scale(&self) -> f32 {
        self.alpha / self.rank as f32
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Tokens per micro-batch.
    pub fn m(&self) -> usize {
        self.batch * self.seq
    }

    /// (d_in, d_out) of LoRA site `p`.
    pub fn proj_dims(&self, p: &str) -> (usize, usize) {
        let (d, qd, kvd, f) = (self.d_model, self.q_dim(), self.kv_dim(), self.d_ff);
        match p {
            "q" => (d, qd),
            "k" => (d, kvd),
            "v" => (d, kvd),
            "o" => (qd, d),
            "gate" => (d, f),
            "up" => (d, f),
            "down" => (f, d),
            _ => panic!("unknown proj {p}"),
        }
    }

    /// Shape of frozen weight `name`.
    pub fn frozen_shape(&self, name: &str) -> Vec<usize> {
        let (d, qd, kvd, f) = (self.d_model, self.q_dim(), self.kv_dim(), self.d_ff);
        match name {
            "ln1" | "ln2" => vec![d],
            "wq" => vec![d, qd],
            "wk" | "wv" => vec![d, kvd],
            "wo" => vec![qd, d],
            "wg" | "wu" => vec![d, f],
            "wd" => vec![f, d],
            _ => panic!("unknown frozen weight {name}"),
        }
    }

    /// LoRA parameter count of one block (all 7 sites, A+B).
    pub fn lora_params_per_block(&self) -> usize {
        PROJS
            .iter()
            .map(|p| {
                let (din, dout) = self.proj_dims(p);
                self.rank * (din + dout)
            })
            .sum()
    }

    pub fn lora_params_total(&self) -> usize {
        self.lora_params_per_block() * self.n_layers
    }

    /// Frozen parameter count of one block.
    pub fn frozen_params_per_block(&self) -> usize {
        FROZEN
            .iter()
            .map(|n| self.frozen_shape(n).iter().product::<usize>())
            .sum()
    }

    /// Total frozen params (blocks + embedding + final norm).
    pub fn frozen_params_total(&self) -> usize {
        self.n_layers * self.frozen_params_per_block()
            + self.vocab * self.d_model
            + self.d_model
    }
}

/// Optimizer selection for the exact-gradient engines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    Sgd,
    Momentum { beta: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl OptimizerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimizerKind::Sgd),
            "momentum" => Ok(OptimizerKind::Momentum { beta: 0.9 }),
            "adam" => Ok(OptimizerKind::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            }),
            _ => anyhow::bail!("unknown optimizer '{s}' (sgd|momentum|adam)"),
        }
    }

    /// f32 state slots per parameter (memory model input).
    pub fn state_slots(self) -> usize {
        match self {
            OptimizerKind::Sgd => 0,
            OptimizerKind::Momentum { .. } => 1,
            OptimizerKind::Adam { .. } => 2,
        }
    }
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Runnable config name (`presets::compiled` for the reference
    /// backend; `artifacts/<name>/` directory for pjrt).
    pub config: String,
    /// Which compute backend executes the artifact surface.
    pub backend: BackendKind,
    pub method: Method,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub optimizer: OptimizerKind,
    /// MeZO perturbation scale ε.
    pub mezo_eps: f32,
    /// Log every N steps.
    pub log_every: usize,
    /// Spill checkpoints to disk beyond this many bytes (0 = never).
    pub spill_limit: u64,
    /// Where metrics JSONL goes (None = stdout summary only).
    pub metrics_path: Option<String>,
    pub artifacts_dir: String,
    /// GEMM kernel variant for the reference backend's kernel engine.
    pub kernel: KernelKind,
    /// Kernel threads for the `parallel` kernel (0 = auto: all cores for
    /// a lone session; the fleet scheduler divides cores by workers).
    pub threads: usize,
    /// Resident precision of the frozen base weights (`--quant f32|q4`).
    pub quant: QuantMode,
    /// Explicit seed for the frozen base weights. `None` derives it from
    /// `seed` (the historical behaviour); fleet grids pin it to the
    /// base's derived model seed so same-base jobs share one cached
    /// `FrozenModel` while their data/job seed streams stay distinct.
    pub model_seed: Option<u64>,
    /// Write a Chrome trace-event file here at end of run (`--trace`).
    /// Also enables span recording for the session (observe-only).
    pub trace_path: Option<String>,
    /// Write the metrics-registry JSONL snapshot here at end of run
    /// (`--metrics-out`). Distinct from `metrics_path`, the per-step
    /// training-loss JSONL stream.
    pub metrics_out: Option<String>,
    /// Loss-head chunk size in sequence rows (`--loss-chunk`; 0 =
    /// unchunked). Chunked runs are bitwise identical to unchunked ones
    /// within a kernel kind/ISA — this knob only moves the peak.
    pub loss_chunk: usize,
    /// Buffered-activation compression for store-h / MeBP residuals
    /// (`--act-compress none|int8`). Lossy when int8: losses drift from
    /// the f32-cache oracle by the quantization error.
    pub act_compress: ActCompress,
}

impl TrainConfig {
    /// The seed the frozen base weights are generated from: the explicit
    /// `model_seed` when pinned, else derived from `seed` on the MODEL
    /// stream. Everything that builds or caches frozen weights keys off
    /// this resolved value.
    pub fn model_seed(&self) -> u64 {
        self.model_seed
            .unwrap_or_else(|| crate::util::rng::derive(self.seed, crate::util::rng::stream::MODEL))
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            config: "toy".into(),
            backend: BackendKind::Reference,
            method: Method::Mesp,
            steps: 10,
            lr: 1e-4,
            seed: 42,
            optimizer: OptimizerKind::Sgd,
            mezo_eps: 1e-3,
            log_every: 10,
            spill_limit: 0,
            metrics_path: None,
            artifacts_dir: "artifacts".into(),
            kernel: KernelKind::default(),
            threads: 0,
            quant: QuantMode::default(),
            model_seed: None,
            trace_path: None,
            metrics_out: None,
            loss_chunk: 0,
            act_compress: ActCompress::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        presets::qwen25_05b(256, 8)
    }

    #[test]
    fn qwen_05b_param_count() {
        let d = dims();
        // Qwen2.5-0.5B is ~0.49B params incl. tied embedding.
        let total = d.frozen_params_total();
        assert!((400_000_000..600_000_000).contains(&total), "{total}");
    }

    #[test]
    fn lora_r8_param_count() {
        let d = dims();
        // paper: LoRA on 7 projections, 24 blocks, r=8 → a few M params
        let lora = d.lora_params_total();
        assert!((2_000_000..8_000_000).contains(&lora), "{lora}");
    }

    #[test]
    fn proj_dims_cover_all_sites() {
        let d = dims();
        for p in PROJS {
            let (din, dout) = d.proj_dims(p);
            assert!(din > 0 && dout > 0);
        }
        assert_eq!(d.proj_dims("q").1, d.q_dim());
        assert_eq!(d.proj_dims("down"), (d.d_ff, d.d_model));
    }

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [("mesp", Method::Mesp), ("MeBP", Method::Mebp),
                       ("MEZO", Method::Mezo), ("store-h", Method::StoreH)] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn method_list_parsing() {
        assert_eq!(Method::parse_list("mesp,mebp").unwrap(),
                   vec![Method::Mesp, Method::Mebp]);
        assert_eq!(Method::parse_list("all").unwrap().len(), 4);
        assert_eq!(Method::parse_list(" mezo , storeh ").unwrap(),
                   vec![Method::Mezo, Method::StoreH]);
        assert!(Method::parse_list("mesp,frobnicate").is_err());
        assert!(Method::parse_list(",").is_err());
    }

    #[test]
    fn optimizer_state_slots() {
        assert_eq!(OptimizerKind::parse("sgd").unwrap().state_slots(), 0);
        assert_eq!(OptimizerKind::parse("adam").unwrap().state_slots(), 2);
    }

    #[test]
    fn kernel_parse_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert!(KernelKind::parse("blocked").is_err());
        assert_eq!(TrainConfig::default().kernel, KernelKind::Parallel);
        assert_eq!(TrainConfig::default().threads, 0, "0 = auto");
    }

    #[test]
    fn quant_parse_roundtrip() {
        for q in QuantMode::ALL {
            assert_eq!(QuantMode::parse(q.name()).unwrap(), q);
        }
        assert_eq!(QuantMode::parse("int4").unwrap(), QuantMode::Q4);
        assert!(QuantMode::parse("q8").is_err());
        assert_eq!(TrainConfig::default().quant, QuantMode::F32);
    }

    #[test]
    fn act_compress_parse_roundtrip() {
        for a in ActCompress::ALL {
            assert_eq!(ActCompress::parse(a.name()).unwrap(), a);
        }
        assert_eq!(ActCompress::parse("i8").unwrap(), ActCompress::Int8);
        assert!(ActCompress::parse("int4").is_err());
        let c = TrainConfig::default();
        assert_eq!(c.act_compress, ActCompress::None);
        assert_eq!(c.loss_chunk, 0, "0 = unchunked");
    }

    #[test]
    fn model_seed_resolves_pinned_or_derived() {
        let mut c = TrainConfig::default();
        let derived =
            crate::util::rng::derive(c.seed, crate::util::rng::stream::MODEL);
        assert_eq!(c.model_seed(), derived);
        c.model_seed = Some(7);
        assert_eq!(c.model_seed(), 7);
    }

    #[test]
    fn backend_parse_and_default() {
        assert_eq!(BackendKind::parse("reference").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("PJRT").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(TrainConfig::default().backend, BackendKind::Reference);
        assert_eq!(BackendKind::Reference.name(), "reference");
    }
}
