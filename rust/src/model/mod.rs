//! Model state: frozen transformer weights + trainable LoRA adapters.
//!
//! Weights are generated *in Rust* with a seeded PRNG and passed to the
//! AOT artifacts as arguments — Python never owns parameters, so there is
//! no cross-language state to keep consistent. Frozen weights use a
//! residual-scaled init so a random ~100M-param model trains stably from
//! scratch in the end-to-end example (DESIGN.md §2: random weights replace
//! the unavailable Qwen checkpoints; memory behaviour is value-independent
//! and convergence claims are relative between methods).

pub mod quant;

use crate::config::{ModelDims, QuantMode, FROZEN, PROJS, QUANT_MATS};
use crate::memory::{MemoryTracker, Tracked};
use crate::tensor::HostTensor;
use crate::util::Rng;

/// One block's frozen weights in artifact ABI order: FROZEN ×9 under
/// f32, or `[ln1, ln2, (packed u8, scales f32) × QUANT_MATS]` under q4
/// — exactly the frozen argument run of the matching artifact family.
#[derive(Debug)]
pub struct BlockWeights {
    pub tensors: Vec<Tracked<HostTensor>>,
}

/// One block's LoRA adapters: [a_q, b_q, a_k, b_k, …] in PROJS order —
/// exactly the artifact argument order.
#[derive(Debug)]
pub struct LoraBlock {
    pub tensors: Vec<HostTensor>,
    _guard: crate::memory::Guard,
}

impl LoraBlock {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flatten all A/B matrices into one contiguous vector (MeZO, metrics).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for t in &self.tensors {
            out.extend_from_slice(t.as_f32());
        }
        out
    }

    /// Inverse of `flatten` — scatter a contiguous vector back.
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.len();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }
}

/// Full model state.
pub struct ModelState {
    pub dims: ModelDims,
    pub embedding: Tracked<HostTensor>,
    pub final_norm: Tracked<HostTensor>,
    pub blocks: Vec<BlockWeights>,
    pub lora: Vec<LoraBlock>,
}

impl ModelState {
    /// Seeded initialization. Frozen weights: N(0, 0.02) with 1/sqrt(2L)
    /// residual scaling on output projections (wo, wd); norms at 1.0.
    /// LoRA: A ~ N(0, 1/sqrt(d_in)), B = 0 (standard LoRA init — the
    /// adapted model starts exactly at the base model).
    pub fn init(dims: &ModelDims, seed: u64, tracker: &MemoryTracker) -> Self {
        Self::init_with_quant(dims, seed, tracker, QuantMode::F32)
    }

    /// [`Self::init`] with a resident precision for the frozen base
    /// weights. Under [`QuantMode::Q4`] each block's f32 matrices exist
    /// only transiently inside this loop — one block at a time, untracked
    /// generation scratch (the tracker's scope is tensors HELD across
    /// calls; the analytical model's per-block dequant term already
    /// over-bounds a one-f32-block transient for the exact-gradient
    /// methods) — and what the model holds, and the tracker charges, is
    /// the int4-packed tensors, so a q4 session never has a
    /// full-precision copy of the frozen model live at once. The weight
    /// RNG stream is identical in both modes: a q4 session quantizes
    /// exactly the weights its f32 twin trains on.
    pub fn init_with_quant(
        dims: &ModelDims,
        seed: u64,
        tracker: &MemoryTracker,
        quant_mode: QuantMode,
    ) -> Self {
        let base = Rng::new(seed);
        let mut rng = base.fork(0xe58);
        let emb = HostTensor::randn(&[dims.vocab, dims.d_model], 0.02, &mut rng);
        let emb_guard = tracker.track("weights:embedding", emb.bytes());
        let fnorm = HostTensor::f32(&[dims.d_model], vec![1.0; dims.d_model]);
        let fnorm_guard = tracker.track("weights:final_norm", fnorm.bytes());

        let resid_scale = 1.0 / ((2 * dims.n_layers) as f32).sqrt();
        let mut blocks = Vec::with_capacity(dims.n_layers);
        let mut lora = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut brng = base.fork(1000 + l as u64);
            let f32_tensors: Vec<HostTensor> = FROZEN
                .iter()
                .map(|name| {
                    let shape = dims.frozen_shape(name);
                    match *name {
                        "ln1" | "ln2" => HostTensor::f32(
                            &shape, vec![1.0; shape.iter().product()]),
                        "wo" | "wd" => HostTensor::randn(
                            &shape, 0.02 * resid_scale, &mut brng),
                        _ => HostTensor::randn(&shape, 0.02, &mut brng),
                    }
                })
                .collect();
            let mut tensors = Vec::new();
            let hold = |t: HostTensor, tensors: &mut Vec<Tracked<HostTensor>>| {
                let guard = tracker.track("weights:blocks", t.bytes());
                tensors.push(Tracked::new(t, guard));
            };
            match quant_mode {
                QuantMode::F32 => {
                    for t in f32_tensors {
                        hold(t, &mut tensors);
                    }
                }
                QuantMode::Q4 => {
                    let idx = |name: &str| {
                        FROZEN.iter().position(|w| *w == name).unwrap()
                    };
                    for ln in ["ln1", "ln2"] {
                        hold(f32_tensors[idx(ln)].clone(), &mut tensors);
                    }
                    for mat in QUANT_MATS {
                        let t = &f32_tensors[idx(mat)];
                        let (din, dout) = (t.shape[0], t.shape[1]);
                        let (packed, scales) =
                            quant::quantize(t.as_f32(), din, dout);
                        hold(HostTensor::u8(&[din / 2, dout], packed),
                             &mut tensors);
                        hold(
                            HostTensor::f32(
                                &[din / quant::GROUP, dout], scales),
                            &mut tensors,
                        );
                    }
                    // f32_tensors drop here: the full-precision block was
                    // generation scratch, never resident state.
                }
            }
            blocks.push(BlockWeights { tensors });

            let mut lrng = base.fork(2000 + l as u64);
            let mut lt = Vec::with_capacity(2 * PROJS.len());
            let mut bytes = 0;
            for p in PROJS {
                let (din, dout) = dims.proj_dims(p);
                let a = HostTensor::randn(
                    &[din, dims.rank], 1.0 / (din as f32).sqrt(), &mut lrng);
                let b = HostTensor::zeros(&[dims.rank, dout]);
                bytes += a.bytes() + b.bytes();
                lt.push(a);
                lt.push(b);
            }
            let guard = tracker.track("params:lora", bytes);
            lora.push(LoraBlock { tensors: lt, _guard: guard });
        }
        ModelState {
            dims: dims.clone(),
            embedding: Tracked::new(emb, emb_guard),
            final_norm: Tracked::new(fnorm, fnorm_guard),
            blocks,
            lora,
        }
    }

    /// FNV-1a 64 fingerprint of every resident frozen tensor (embedding,
    /// final norm, each block's tensors in artifact-ABI order — the
    /// int4-packed bytes + scales under q4, so a quantized model is
    /// fingerprinted in its packed form and never round-tripped through
    /// f32). Frozen weights are a pure function of the model stream
    /// seed, so session snapshots store only this hash: restore
    /// regenerates the weights and refuses to resume on a mismatch.
    ///
    /// Must be computed BEFORE the engine uploads the weights and frees
    /// the host copies ([`crate::train::common::EngineCtx`] does).
    pub fn weights_fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        h = crate::persist::fnv1a64_tensor(h, &self.embedding.value);
        h = crate::persist::fnv1a64_tensor(h, &self.final_norm.value);
        for block in &self.blocks {
            for t in &block.tensors {
                h = crate::persist::fnv1a64_tensor(h, &t.value);
            }
        }
        h
    }

    /// Total trainable (LoRA) parameter count.
    pub fn lora_param_count(&self) -> usize {
        self.lora.iter().map(|l| l.param_count()).sum()
    }

    /// Borrow a block's frozen + LoRA tensors in artifact argument order
    /// (frozen ×9 then lora ×14) — appended after the leading args.
    pub fn block_args<'a>(&'a self, layer: usize) -> Vec<&'a HostTensor> {
        let mut v: Vec<&HostTensor> = Vec::with_capacity(23);
        for t in &self.blocks[layer].tensors {
            v.push(&t.value);
        }
        for t in &self.lora[layer].tensors {
            v.push(t);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn toy_dims() -> ModelDims {
        ModelDims {
            name: "toy".into(), vocab: 256, d_model: 64, n_layers: 2,
            n_heads: 4, n_kv_heads: 2, head_dim: 16, d_ff: 128, seq: 32,
            batch: 1, rank: 4, alpha: 8.0,
        }
    }

    #[test]
    fn init_deterministic() {
        let t = MemoryTracker::new();
        let a = ModelState::init(&toy_dims(), 7, &t);
        let b = ModelState::init(&toy_dims(), 7, &t);
        assert_eq!(a.embedding.as_f32()[..8], b.embedding.as_f32()[..8]);
        assert_eq!(a.lora[0].tensors[0].as_f32(), b.lora[0].tensors[0].as_f32());
        let c = ModelState::init(&toy_dims(), 8, &t);
        assert_ne!(a.embedding.as_f32()[0], c.embedding.as_f32()[0]);
    }

    #[test]
    fn lora_b_starts_zero() {
        let t = MemoryTracker::new();
        let m = ModelState::init(&toy_dims(), 1, &t);
        for l in &m.lora {
            for (i, tt) in l.tensors.iter().enumerate() {
                if i % 2 == 1 {
                    assert!(tt.as_f32().iter().all(|v| *v == 0.0), "B not zero");
                }
            }
        }
    }

    #[test]
    fn param_count_matches_dims() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = ModelState::init(&d, 1, &t);
        assert_eq!(m.lora_param_count(), d.lora_params_total());
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let t = MemoryTracker::new();
        let mut m = ModelState::init(&toy_dims(), 3, &t);
        let flat = m.lora[0].flatten();
        let mut modified = flat.clone();
        modified[0] += 1.5;
        m.lora[0].unflatten(&modified);
        assert_eq!(m.lora[0].flatten(), modified);
    }

    #[test]
    fn block_args_order() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = ModelState::init(&d, 1, &t);
        let args = m.block_args(0);
        assert_eq!(args.len(), 9 + 14);
        // first frozen is ln1 [d]
        assert_eq!(args[0].shape, vec![d.d_model]);
        // first lora pair is a_q [d, r], b_q [r, qd]
        assert_eq!(args[9].shape, vec![d.d_model, d.rank]);
        assert_eq!(args[10].shape, vec![d.rank, d.q_dim()]);
    }

    #[test]
    fn q4_init_holds_packed_blocks_only() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = ModelState::init_with_quant(&d, 7, &t, crate::config::QuantMode::Q4);
        // q4 ABI order: ln1, ln2, then (packed, scales) × 7
        let b = &m.blocks[0].tensors;
        assert_eq!(b.len(), 2 + 2 * QUANT_MATS.len());
        assert_eq!(b[0].value.shape, vec![d.d_model]); // ln1
        assert_eq!(b[2].value.dtype(), crate::tensor::DType::U8); // packed_wq
        assert_eq!(b[2].value.shape, vec![d.d_model / 2, d.q_dim()]);
        assert_eq!(b[3].value.shape,
                   vec![d.d_model / quant::GROUP, d.q_dim()]); // scales_wq
        // packed residents are a fraction of the f32 block bytes
        let t2 = MemoryTracker::new();
        let f = ModelState::init(&d, 7, &t2);
        let q4_bytes: u64 = b.iter().map(|t| t.value.bytes()).sum();
        let f32_bytes: u64 =
            f.blocks[0].tensors.iter().map(|t| t.value.bytes()).sum();
        assert!(q4_bytes * 2 < f32_bytes, "{q4_bytes} !< {f32_bytes} / 2");
        // same seed ⇒ same underlying weights: the packed wq dequantizes
        // to within half a quantization step of the f32 wq
        let packed = b[2].value.as_u8();
        let scales = b[3].value.as_f32();
        let deq = quant::dequantize(packed, scales, d.d_model, d.q_dim());
        let wq = f.blocks[0].tensors[1].value.as_f32();
        for (c, (a, b)) in deq.iter().zip(wq).enumerate() {
            let s = scales[(c / d.q_dim() / quant::GROUP) * d.q_dim()
                + c % d.q_dim()];
            assert!((a - b).abs() <= s / 2.0 + 1e-7, "elem {c}: {a} vs {b}");
        }
    }

    #[test]
    fn weights_fingerprint_is_seed_and_quant_sensitive() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let a = ModelState::init(&d, 7, &t).weights_fingerprint();
        let b = ModelState::init(&d, 7, &t).weights_fingerprint();
        assert_eq!(a, b, "same seed ⇒ same fingerprint");
        let c = ModelState::init(&d, 8, &t).weights_fingerprint();
        assert_ne!(a, c, "different seed ⇒ different fingerprint");
        let q = ModelState::init_with_quant(
            &d, 7, &t, crate::config::QuantMode::Q4)
            .weights_fingerprint();
        assert_ne!(a, q, "q4 fingerprints the packed bytes, not the f32s");
        let q2 = ModelState::init_with_quant(
            &d, 7, &t, crate::config::QuantMode::Q4)
            .weights_fingerprint();
        assert_eq!(q, q2);
    }

    #[test]
    fn tracker_accounts_weights() {
        let t = MemoryTracker::new();
        let d = presets::qwen25_05b(8, 8); // tiny seq; weights dominate
        // don't actually allocate 0.5B params here — use toy and check > 0
        let m = ModelState::init(&toy_dims(), 1, &t);
        assert!(t.live() > 0);
        drop(m);
        assert_eq!(t.live(), 0, "all weight bytes released");
        let _ = d;
    }
}
