//! Model state: frozen transformer weights + trainable LoRA adapters.
//!
//! Weights are generated *in Rust* with a seeded PRNG and passed to the
//! AOT artifacts as arguments — Python never owns parameters, so there is
//! no cross-language state to keep consistent. Frozen weights use a
//! residual-scaled init so a random ~100M-param model trains stably from
//! scratch in the end-to-end example (DESIGN.md §2: random weights replace
//! the unavailable Qwen checkpoints; memory behaviour is value-independent
//! and convergence claims are relative between methods).

pub mod quant;

use crate::config::{ModelDims, FROZEN, PROJS};
use crate::memory::{MemoryTracker, Tracked};
use crate::tensor::HostTensor;
use crate::util::Rng;

/// One block's frozen weights, in artifact ABI order (FROZEN).
#[derive(Debug)]
pub struct BlockWeights {
    pub tensors: Vec<Tracked<HostTensor>>,
}

/// One block's LoRA adapters: [a_q, b_q, a_k, b_k, …] in PROJS order —
/// exactly the artifact argument order.
#[derive(Debug)]
pub struct LoraBlock {
    pub tensors: Vec<HostTensor>,
    _guard: crate::memory::Guard,
}

impl LoraBlock {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flatten all A/B matrices into one contiguous vector (MeZO, metrics).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for t in &self.tensors {
            out.extend_from_slice(t.as_f32());
        }
        out
    }

    /// Inverse of `flatten` — scatter a contiguous vector back.
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.len();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }
}

/// Full model state.
pub struct ModelState {
    pub dims: ModelDims,
    pub embedding: Tracked<HostTensor>,
    pub final_norm: Tracked<HostTensor>,
    pub blocks: Vec<BlockWeights>,
    pub lora: Vec<LoraBlock>,
}

impl ModelState {
    /// Seeded initialization. Frozen weights: N(0, 0.02) with 1/sqrt(2L)
    /// residual scaling on output projections (wo, wd); norms at 1.0.
    /// LoRA: A ~ N(0, 1/sqrt(d_in)), B = 0 (standard LoRA init — the
    /// adapted model starts exactly at the base model).
    pub fn init(dims: &ModelDims, seed: u64, tracker: &MemoryTracker) -> Self {
        let base = Rng::new(seed);
        let mut rng = base.fork(0xe58);
        let emb = HostTensor::randn(&[dims.vocab, dims.d_model], 0.02, &mut rng);
        let emb_guard = tracker.track("weights:embedding", emb.bytes());
        let fnorm = HostTensor::f32(&[dims.d_model], vec![1.0; dims.d_model]);
        let fnorm_guard = tracker.track("weights:final_norm", fnorm.bytes());

        let resid_scale = 1.0 / ((2 * dims.n_layers) as f32).sqrt();
        let mut blocks = Vec::with_capacity(dims.n_layers);
        let mut lora = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut brng = base.fork(1000 + l as u64);
            let mut tensors = Vec::with_capacity(FROZEN.len());
            for name in FROZEN {
                let shape = dims.frozen_shape(name);
                let t = match name {
                    "ln1" | "ln2" => HostTensor::f32(
                        &shape, vec![1.0; shape.iter().product()]),
                    "wo" | "wd" => HostTensor::randn(
                        &shape, 0.02 * resid_scale, &mut brng),
                    _ => HostTensor::randn(&shape, 0.02, &mut brng),
                };
                let guard = tracker.track("weights:blocks", t.bytes());
                tensors.push(Tracked::new(t, guard));
            }
            blocks.push(BlockWeights { tensors });

            let mut lrng = base.fork(2000 + l as u64);
            let mut lt = Vec::with_capacity(2 * PROJS.len());
            let mut bytes = 0;
            for p in PROJS {
                let (din, dout) = dims.proj_dims(p);
                let a = HostTensor::randn(
                    &[din, dims.rank], 1.0 / (din as f32).sqrt(), &mut lrng);
                let b = HostTensor::zeros(&[dims.rank, dout]);
                bytes += a.bytes() + b.bytes();
                lt.push(a);
                lt.push(b);
            }
            let guard = tracker.track("params:lora", bytes);
            lora.push(LoraBlock { tensors: lt, _guard: guard });
        }
        ModelState {
            dims: dims.clone(),
            embedding: Tracked::new(emb, emb_guard),
            final_norm: Tracked::new(fnorm, fnorm_guard),
            blocks,
            lora,
        }
    }

    /// Total trainable (LoRA) parameter count.
    pub fn lora_param_count(&self) -> usize {
        self.lora.iter().map(|l| l.param_count()).sum()
    }

    /// Borrow a block's frozen + LoRA tensors in artifact argument order
    /// (frozen ×9 then lora ×14) — appended after the leading args.
    pub fn block_args<'a>(&'a self, layer: usize) -> Vec<&'a HostTensor> {
        let mut v: Vec<&HostTensor> = Vec::with_capacity(23);
        for t in &self.blocks[layer].tensors {
            v.push(&t.value);
        }
        for t in &self.lora[layer].tensors {
            v.push(t);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn toy_dims() -> ModelDims {
        ModelDims {
            name: "toy".into(), vocab: 256, d_model: 64, n_layers: 2,
            n_heads: 4, n_kv_heads: 2, head_dim: 16, d_ff: 128, seq: 32,
            batch: 1, rank: 4, alpha: 8.0,
        }
    }

    #[test]
    fn init_deterministic() {
        let t = MemoryTracker::new();
        let a = ModelState::init(&toy_dims(), 7, &t);
        let b = ModelState::init(&toy_dims(), 7, &t);
        assert_eq!(a.embedding.as_f32()[..8], b.embedding.as_f32()[..8]);
        assert_eq!(a.lora[0].tensors[0].as_f32(), b.lora[0].tensors[0].as_f32());
        let c = ModelState::init(&toy_dims(), 8, &t);
        assert_ne!(a.embedding.as_f32()[0], c.embedding.as_f32()[0]);
    }

    #[test]
    fn lora_b_starts_zero() {
        let t = MemoryTracker::new();
        let m = ModelState::init(&toy_dims(), 1, &t);
        for l in &m.lora {
            for (i, tt) in l.tensors.iter().enumerate() {
                if i % 2 == 1 {
                    assert!(tt.as_f32().iter().all(|v| *v == 0.0), "B not zero");
                }
            }
        }
    }

    #[test]
    fn param_count_matches_dims() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = ModelState::init(&d, 1, &t);
        assert_eq!(m.lora_param_count(), d.lora_params_total());
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let t = MemoryTracker::new();
        let mut m = ModelState::init(&toy_dims(), 3, &t);
        let flat = m.lora[0].flatten();
        let mut modified = flat.clone();
        modified[0] += 1.5;
        m.lora[0].unflatten(&modified);
        assert_eq!(m.lora[0].flatten(), modified);
    }

    #[test]
    fn block_args_order() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = ModelState::init(&d, 1, &t);
        let args = m.block_args(0);
        assert_eq!(args.len(), 9 + 14);
        // first frozen is ln1 [d]
        assert_eq!(args[0].shape, vec![d.d_model]);
        // first lora pair is a_q [d, r], b_q [r, qd]
        assert_eq!(args[9].shape, vec![d.d_model, d.rank]);
        assert_eq!(args[10].shape, vec![d.rank, d.q_dim()]);
    }

    #[test]
    fn tracker_accounts_weights() {
        let t = MemoryTracker::new();
        let d = presets::qwen25_05b(8, 8); // tiny seq; weights dominate
        // don't actually allocate 0.5B params here — use toy and check > 0
        let m = ModelState::init(&toy_dims(), 1, &t);
        assert!(t.live() > 0);
        drop(m);
        assert_eq!(t.live(), 0, "all weight bytes released");
        let _ = d;
    }
}
