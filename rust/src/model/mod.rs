//! Model state: frozen transformer weights + trainable LoRA adapters.
//!
//! Weights are generated *in Rust* with a seeded PRNG and passed to the
//! AOT artifacts as arguments — Python never owns parameters, so there is
//! no cross-language state to keep consistent. Frozen weights use a
//! residual-scaled init so a random ~100M-param model trains stably from
//! scratch in the end-to-end example (DESIGN.md §2: random weights replace
//! the unavailable Qwen checkpoints; memory behaviour is value-independent
//! and convergence claims are relative between methods).
//!
//! The model is split along the paper's fault line: [`FrozenModel`] is the
//! immutable base (embedding, final norm, per-block frozen tensors — f32
//! or int4-packed), shareable across any number of sessions behind an
//! `Arc` and internable in a [`cache::WeightCache`]; [`AdapterState`] is
//! the tiny per-session trainable half (LoRA A/B). Both halves are pure
//! functions of independent forks of the model seed, so either can be
//! built without the other — [`ModelSpec`] is the single entry point.

pub mod actquant;
pub mod cache;
pub mod quant;

pub use cache::WeightCache;

use std::sync::Arc;

use crate::config::{ModelDims, QuantMode, FROZEN, PROJS, QUANT_MATS};
use crate::memory::{Guard, MemoryTracker};
use crate::tensor::HostTensor;
use crate::util::Rng;

/// One block's LoRA adapters: [a_q, b_q, a_k, b_k, …] in PROJS order —
/// exactly the artifact argument order.
#[derive(Debug)]
pub struct LoraBlock {
    pub tensors: Vec<HostTensor>,
    _guard: crate::memory::Guard,
}

impl LoraBlock {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flatten all A/B matrices into one contiguous vector (MeZO, metrics).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for t in &self.tensors {
            out.extend_from_slice(t.as_f32());
        }
        out
    }

    /// Inverse of `flatten` — scatter a contiguous vector back.
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.len();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }
}

/// The immutable frozen half of a model: embedding, final norm and every
/// block's frozen tensors (FROZEN ×9 under f32, or
/// `[ln1, ln2, (packed u8, scales f32) × QUANT_MATS]` under q4 — exactly
/// the frozen argument run of the matching artifact family).
///
/// A `FrozenModel` is never mutated after construction and is shared
/// across sessions as `Arc<FrozenModel>`: N same-base jobs hold ONE copy
/// of the base weights, and the resident bytes are charged exactly once —
/// under the `weights:shared` tag of whichever tracker built it — for the
/// lifetime of the last `Arc`.
pub struct FrozenModel {
    /// Owned, shared dims: sessions and backends borrow these instead of
    /// cloning a `ModelDims` per session.
    pub dims: Arc<ModelDims>,
    /// The resolved model seed the weights were generated from.
    pub seed: u64,
    /// Resident precision of the block matrices.
    pub quant: QuantMode,
    pub embedding: HostTensor,
    pub final_norm: HostTensor,
    /// Per-layer frozen tensors in artifact ABI order.
    pub blocks: Vec<Vec<HostTensor>>,
    fingerprint: u64,
    _guard: Guard,
}

impl FrozenModel {
    /// FNV-1a 64 fingerprint of every resident frozen tensor (embedding,
    /// final norm, each block's tensors in artifact-ABI order — the
    /// int4-packed bytes + scales under q4, so a quantized model is
    /// fingerprinted in its packed form and never round-tripped through
    /// f32). Frozen weights are a pure function of the model stream
    /// seed, so session snapshots store only this hash: restore
    /// re-attaches to (or regenerates) the weights and refuses to resume
    /// on a mismatch.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// One block's frozen tensors in artifact ABI order.
    pub fn block_tensors(&self, layer: usize) -> &[HostTensor] {
        &self.blocks[layer]
    }

    /// Total resident bytes (embedding + final norm + all blocks) — the
    /// quantity the `weights:shared` guard holds, equal to
    /// `memory::model::resident_weight_bytes(dims, quant)`.
    pub fn resident_bytes(&self) -> u64 {
        self._guard.bytes()
    }
}

impl std::fmt::Debug for FrozenModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FrozenModel")
            .field("dims", &self.dims.name)
            .field("seed", &self.seed)
            .field("quant", &self.quant)
            .field("fingerprint", &format_args!("{:#018x}", self.fingerprint))
            .finish_non_exhaustive()
    }
}

/// The per-session trainable half: LoRA adapter blocks.
#[derive(Debug)]
pub struct AdapterState {
    pub lora: Vec<LoraBlock>,
}

impl AdapterState {
    /// Total trainable (LoRA) parameter count.
    pub fn lora_param_count(&self) -> usize {
        self.lora.iter().map(|l| l.param_count()).sum()
    }
}

/// Everything that determines a model's weights: dims, the resolved model
/// seed, and the resident precision. The single construction entry point
/// for both model halves — and the identity a [`cache::WeightCache`]
/// interns frozen weights under.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub dims: Arc<ModelDims>,
    pub seed: u64,
    pub quant: QuantMode,
}

impl ModelSpec {
    pub fn new(
        dims: impl Into<Arc<ModelDims>>,
        seed: u64,
        quant: QuantMode,
    ) -> ModelSpec {
        ModelSpec { dims: dims.into(), seed, quant }
    }

    /// Build both halves: the (freshly generated, privately owned) frozen
    /// base and this session's adapters. Fleet paths intern the frozen
    /// half through [`cache::WeightCache::get_or_build`] instead.
    pub fn build(
        &self,
        tracker: &MemoryTracker,
    ) -> (Arc<FrozenModel>, AdapterState) {
        (self.build_frozen(tracker), self.build_adapters(tracker))
    }

    /// Generate the frozen half. Frozen weights: N(0, 0.02) with
    /// 1/sqrt(2L) residual scaling on output projections (wo, wd); norms
    /// at 1.0. Under [`QuantMode::Q4`] each block's f32 matrices exist
    /// only transiently inside this loop — one block at a time, untracked
    /// generation scratch (the tracker's scope is tensors HELD across
    /// calls; the analytical model's per-block dequant term already
    /// over-bounds a one-f32-block transient for the exact-gradient
    /// methods) — and what the model holds, and the tracker charges once
    /// under `weights:shared`, is the int4-packed tensors. The weight RNG
    /// stream is identical in both modes: a q4 model quantizes exactly
    /// the weights its f32 twin trains on.
    pub fn build_frozen(&self, tracker: &MemoryTracker) -> Arc<FrozenModel> {
        let dims = &*self.dims;
        let base = Rng::new(self.seed);
        let mut rng = base.fork(0xe58);
        let embedding =
            HostTensor::randn(&[dims.vocab, dims.d_model], 0.02, &mut rng);
        let final_norm =
            HostTensor::f32(&[dims.d_model], vec![1.0; dims.d_model]);

        let resid_scale = 1.0 / ((2 * dims.n_layers) as f32).sqrt();
        let mut blocks = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut brng = base.fork(1000 + l as u64);
            let f32_tensors: Vec<HostTensor> = FROZEN
                .iter()
                .map(|name| {
                    let shape = dims.frozen_shape(name);
                    match *name {
                        "ln1" | "ln2" => HostTensor::f32(
                            &shape, vec![1.0; shape.iter().product()]),
                        "wo" | "wd" => HostTensor::randn(
                            &shape, 0.02 * resid_scale, &mut brng),
                        _ => HostTensor::randn(&shape, 0.02, &mut brng),
                    }
                })
                .collect();
            let tensors = match self.quant {
                QuantMode::F32 => f32_tensors,
                QuantMode::Q4 => {
                    let idx = |name: &str| {
                        FROZEN.iter().position(|w| *w == name).unwrap()
                    };
                    let mut packed_tensors = Vec::new();
                    for ln in ["ln1", "ln2"] {
                        packed_tensors.push(f32_tensors[idx(ln)].clone());
                    }
                    for mat in QUANT_MATS {
                        let t = &f32_tensors[idx(mat)];
                        let (din, dout) = (t.shape[0], t.shape[1]);
                        let (packed, scales) =
                            quant::quantize(t.as_f32(), din, dout);
                        packed_tensors
                            .push(HostTensor::u8(&[din / 2, dout], packed));
                        packed_tensors.push(HostTensor::f32(
                            &[din / quant::GROUP, dout], scales));
                    }
                    // f32_tensors drop here: the full-precision block was
                    // generation scratch, never resident state.
                    packed_tensors
                }
            };
            blocks.push(tensors);
        }

        let mut fingerprint: u64 = 0xcbf29ce484222325;
        fingerprint = crate::persist::fnv1a64_tensor(fingerprint, &embedding);
        fingerprint = crate::persist::fnv1a64_tensor(fingerprint, &final_norm);
        let mut bytes = embedding.bytes() + final_norm.bytes();
        for block in &blocks {
            for t in block {
                fingerprint = crate::persist::fnv1a64_tensor(fingerprint, t);
                bytes += t.bytes();
            }
        }
        let guard = tracker.track("weights:shared", bytes);
        Arc::new(FrozenModel {
            dims: self.dims.clone(),
            seed: self.seed,
            quant: self.quant,
            embedding,
            final_norm,
            blocks,
            fingerprint,
            _guard: guard,
        })
    }

    /// Generate this session's adapters. LoRA: A ~ N(0, 1/sqrt(d_in)),
    /// B = 0 (standard LoRA init — the adapted model starts exactly at
    /// the base model). Uses its own RNG forks of the model seed, so
    /// adapters are derivable without generating the frozen half.
    pub fn build_adapters(&self, tracker: &MemoryTracker) -> AdapterState {
        let dims = &*self.dims;
        let base = Rng::new(self.seed);
        let mut lora = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut lrng = base.fork(2000 + l as u64);
            let mut lt = Vec::with_capacity(2 * PROJS.len());
            let mut bytes = 0;
            for p in PROJS {
                let (din, dout) = dims.proj_dims(p);
                let a = HostTensor::randn(
                    &[din, dims.rank], 1.0 / (din as f32).sqrt(), &mut lrng);
                let b = HostTensor::zeros(&[dims.rank, dout]);
                bytes += a.bytes() + b.bytes();
                lt.push(a);
                lt.push(b);
            }
            let guard = tracker.track("params:lora", bytes);
            lora.push(LoraBlock { tensors: lt, _guard: guard });
        }
        AdapterState { lora }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn toy_dims() -> ModelDims {
        ModelDims {
            name: "toy".into(), vocab: 256, d_model: 64, n_layers: 2,
            n_heads: 4, n_kv_heads: 2, head_dim: 16, d_ff: 128, seq: 32,
            batch: 1, rank: 4, alpha: 8.0,
        }
    }

    fn spec(seed: u64, quant: QuantMode) -> ModelSpec {
        ModelSpec::new(toy_dims(), seed, quant)
    }

    #[test]
    fn build_deterministic() {
        let t = MemoryTracker::new();
        let (fa, aa) = spec(7, QuantMode::F32).build(&t);
        let (fb, ab) = spec(7, QuantMode::F32).build(&t);
        assert_eq!(fa.embedding.as_f32()[..8], fb.embedding.as_f32()[..8]);
        assert_eq!(aa.lora[0].tensors[0].as_f32(),
                   ab.lora[0].tensors[0].as_f32());
        let (fc, _) = spec(8, QuantMode::F32).build(&t);
        assert_ne!(fa.embedding.as_f32()[0], fc.embedding.as_f32()[0]);
    }

    #[test]
    fn adapters_derivable_without_frozen() {
        // The halves fork independent RNG streams: adapters built alone
        // are bitwise the adapters built alongside the frozen half.
        let t = MemoryTracker::new();
        let s = spec(3, QuantMode::F32);
        let (_frozen, together) = s.build(&t);
        let alone = s.build_adapters(&t);
        for (a, b) in together.lora.iter().zip(&alone.lora) {
            assert_eq!(a.flatten(), b.flatten());
        }
    }

    #[test]
    fn lora_b_starts_zero() {
        let t = MemoryTracker::new();
        let a = spec(1, QuantMode::F32).build_adapters(&t);
        for l in &a.lora {
            for (i, tt) in l.tensors.iter().enumerate() {
                if i % 2 == 1 {
                    assert!(tt.as_f32().iter().all(|v| *v == 0.0), "B not zero");
                }
            }
        }
    }

    #[test]
    fn param_count_matches_dims() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let a = ModelSpec::new(d.clone(), 1, QuantMode::F32).build_adapters(&t);
        assert_eq!(a.lora_param_count(), d.lora_params_total());
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let t = MemoryTracker::new();
        let mut a = spec(3, QuantMode::F32).build_adapters(&t);
        let flat = a.lora[0].flatten();
        let mut modified = flat.clone();
        modified[0] += 1.5;
        a.lora[0].unflatten(&modified);
        assert_eq!(a.lora[0].flatten(), modified);
    }

    #[test]
    fn block_tensor_order() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let (frozen, adapters) =
            ModelSpec::new(d.clone(), 1, QuantMode::F32).build(&t);
        assert_eq!(frozen.block_tensors(0).len(), 9);
        // first frozen is ln1 [d]
        assert_eq!(frozen.block_tensors(0)[0].shape, vec![d.d_model]);
        // first lora pair is a_q [d, r], b_q [r, qd]
        assert_eq!(adapters.lora[0].tensors[0].shape,
                   vec![d.d_model, d.rank]);
        assert_eq!(adapters.lora[0].tensors[1].shape,
                   vec![d.rank, d.q_dim()]);
    }

    #[test]
    fn q4_build_holds_packed_blocks_only() {
        let t = MemoryTracker::new();
        let d = toy_dims();
        let m = spec(7, QuantMode::Q4).build_frozen(&t);
        // q4 ABI order: ln1, ln2, then (packed, scales) × 7
        let b = m.block_tensors(0);
        assert_eq!(b.len(), 2 + 2 * QUANT_MATS.len());
        assert_eq!(b[0].shape, vec![d.d_model]); // ln1
        assert_eq!(b[2].dtype(), crate::tensor::DType::U8); // packed_wq
        assert_eq!(b[2].shape, vec![d.d_model / 2, d.q_dim()]);
        assert_eq!(b[3].shape,
                   vec![d.d_model / quant::GROUP, d.q_dim()]); // scales_wq
        // packed residents are a fraction of the f32 block bytes
        let f = spec(7, QuantMode::F32).build_frozen(&t);
        let q4_bytes: u64 = b.iter().map(|t| t.bytes()).sum();
        let f32_bytes: u64 =
            f.block_tensors(0).iter().map(|t| t.bytes()).sum();
        assert!(q4_bytes * 2 < f32_bytes, "{q4_bytes} !< {f32_bytes} / 2");
        // same seed ⇒ same underlying weights: the packed wq dequantizes
        // to within half a quantization step of the f32 wq
        let packed = b[2].as_u8();
        let scales = b[3].as_f32();
        let deq = quant::dequantize(packed, scales, d.d_model, d.q_dim());
        let wq = f.block_tensors(0)[1].as_f32();
        for (c, (a, b)) in deq.iter().zip(wq).enumerate() {
            let s = scales[(c / d.q_dim() / quant::GROUP) * d.q_dim()
                + c % d.q_dim()];
            assert!((a - b).abs() <= s / 2.0 + 1e-7, "elem {c}: {a} vs {b}");
        }
    }

    #[test]
    fn fingerprint_is_seed_and_quant_sensitive() {
        let t = MemoryTracker::new();
        let a = spec(7, QuantMode::F32).build_frozen(&t).fingerprint();
        let b = spec(7, QuantMode::F32).build_frozen(&t).fingerprint();
        assert_eq!(a, b, "same seed ⇒ same fingerprint");
        let c = spec(8, QuantMode::F32).build_frozen(&t).fingerprint();
        assert_ne!(a, c, "different seed ⇒ different fingerprint");
        let q = spec(7, QuantMode::Q4).build_frozen(&t).fingerprint();
        assert_ne!(a, q, "q4 fingerprints the packed bytes, not the f32s");
        let q2 = spec(7, QuantMode::Q4).build_frozen(&t).fingerprint();
        assert_eq!(q, q2);
    }

    #[test]
    fn tracker_charges_shared_weights_once_per_model() {
        let t = MemoryTracker::new();
        let d = presets::qwen25_05b(8, 8); // sim-only; never allocated here
        let m = spec(1, QuantMode::F32).build_frozen(&t);
        assert_eq!(t.tag_bytes("weights:shared"), m.resident_bytes());
        assert_eq!(
            m.resident_bytes(),
            crate::memory::resident_weight_bytes(&m.dims, QuantMode::F32),
            "guard bytes must equal the analytical resident term"
        );
        drop(m);
        assert_eq!(t.live(), 0, "all weight bytes released");
        let _ = d;
    }
}
