//! int4 group quantization — Rust mirror of `python/compile/quant.py`
//! (symmetric int4, group size 64 along d_in, two nibbles per byte, even
//! row in the low nibble). Used by the q4 artifact path and by the memory
//! model's byte accounting for 4-bit base weights.

pub const GROUP: usize = 64;

/// Quantize an f32 row-major [din, dout] matrix. Returns (packed bytes
/// [din/2, dout], scales [din/GROUP, dout]).
pub fn quantize(w: &[f32], din: usize, dout: usize) -> (Vec<u8>, Vec<f32>) {
    assert_eq!(w.len(), din * dout);
    assert!(din % GROUP == 0 && din % 2 == 0, "din={din}");
    let n_groups = din / GROUP;
    let mut scales = vec![0f32; n_groups * dout];
    for g in 0..n_groups {
        for c in 0..dout {
            let mut mx = 0f32;
            for r in 0..GROUP {
                mx = mx.max(w[(g * GROUP + r) * dout + c].abs());
            }
            // Degenerate groups must stay safe: an all-zero group gets an
            // exact 0.0 scale (its values quantize to 0 without touching
            // the division below), and a non-finite max (inf/NaN input)
            // is clamped to 0.0 as well — otherwise the scale itself
            // would be inf/NaN and dequantization would emit NaN.
            let s = mx / 7.0;
            scales[g * dout + c] = if s.is_finite() { s } else { 0.0 };
        }
    }
    let mut q = vec![0i8; din * dout];
    for r in 0..din {
        let g = r / GROUP;
        for c in 0..dout {
            let s = scales[g * dout + c];
            let v = if s == 0.0 { 0.0 } else { w[r * dout + c] / s };
            q[r * dout + c] = (v.round().clamp(-8.0, 7.0)) as i8;
        }
    }
    let mut packed = vec![0u8; din / 2 * dout];
    for r2 in 0..din / 2 {
        for c in 0..dout {
            let lo = (q[(2 * r2) * dout + c] as u8) & 0x0f;
            let hi = (q[(2 * r2 + 1) * dout + c] as u8) & 0x0f;
            packed[r2 * dout + c] = lo | (hi << 4);
        }
    }
    (packed, scales)
}

/// Dequantize back to f32 (host-side reference; the fused q4 kernels in
/// `runtime::kernels` produce bitwise-identical values panel by panel).
pub fn dequantize(packed: &[u8], scales: &[f32], din: usize, dout: usize) -> Vec<f32> {
    let mut out = vec![0f32; din * dout];
    dequantize_into(packed, scales, din, dout, &mut out);
    out
}

/// Dequantize into a caller-owned buffer (the naive-q4 oracle kernel
/// materializes into arena scratch instead of a fresh `Vec`).
pub fn dequantize_into(
    packed: &[u8],
    scales: &[f32],
    din: usize,
    dout: usize,
    out: &mut [f32],
) {
    assert_eq!(packed.len(), din / 2 * dout);
    assert_eq!(out.len(), din * dout);
    for r2 in 0..din / 2 {
        for c in 0..dout {
            let b = packed[r2 * dout + c];
            let lo = sign_extend(b & 0x0f);
            let hi = sign_extend((b >> 4) & 0x0f);
            let g = (2 * r2) / GROUP;
            let s = scales[g * dout + c];
            out[(2 * r2) * dout + c] = lo as f32 * s;
            let g2 = (2 * r2 + 1) / GROUP;
            out[(2 * r2 + 1) * dout + c] = hi as f32 * scales[g2 * dout + c];
        }
    }
}

/// Two's-complement sign extension of one int4 nibble. Shared with the
/// fused dequant kernels so host and in-kernel dequantization cannot
/// drift (their parity is asserted bitwise).
#[inline]
pub fn sign_extend(nibble: u8) -> i8 {
    if nibble > 7 {
        nibble as i8 - 16
    } else {
        nibble as i8
    }
}

/// Bytes for a quantized [din, dout] matrix (packed + f32 scales) —
/// memory-model input.
pub fn quantized_bytes(din: usize, dout: usize) -> u64 {
    (din as u64 / 2) * dout as u64 + (din as u64 / GROUP as u64) * dout as u64 * 4
}

/// Resident bytes of ONE q4 block in the packed layout: two f32 norm
/// gains plus a (packed, scales) pair per `QUANT_MATS` matrix. Single
/// source of truth for the admission charge
/// (`memory::model::resident_weight_bytes`) and the FLOP/byte inventory
/// (`kernels::flops::artifact_weight_bytes`) — a packing-scheme change
/// lands in both automatically.
pub fn packed_block_bytes(d: &crate::config::ModelDims) -> u64 {
    let norms = 2 * d.d_model as u64 * 4;
    norms
        + crate::config::QUANT_MATS
            .iter()
            .map(|w| {
                let s = d.frozen_shape(w);
                quantized_bytes(s[0], s[1])
            })
            .sum::<u64>()
}

/// Host-dequantize one block's q4-ABI tensor list
/// (`[ln1, ln2, (packed u8, scales f32) × QUANT_MATS]`) back to the
/// nine-tensor f32 FROZEN layout — the oracle form the parity and
/// gradcheck tests compare the fused kernels against. Single source of
/// truth for the q4 block tensor order on the host side.
pub fn dequantize_block(
    dims: &crate::config::ModelDims,
    q4_tensors: &[crate::tensor::HostTensor],
) -> Vec<crate::tensor::HostTensor> {
    use crate::config::{FROZEN, QUANT_MATS};
    assert_eq!(q4_tensors.len(), 2 + 2 * QUANT_MATS.len());
    FROZEN
        .iter()
        .map(|name| match *name {
            "ln1" => q4_tensors[0].clone(),
            "ln2" => q4_tensors[1].clone(),
            mat => {
                let i = QUANT_MATS.iter().position(|w| *w == mat).unwrap();
                let shape = dims.frozen_shape(mat);
                let w = dequantize(
                    q4_tensors[2 + 2 * i].as_u8(),
                    q4_tensors[2 + 2 * i + 1].as_f32(),
                    shape[0],
                    shape[1],
                );
                crate::tensor::HostTensor::f32(&shape, w)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_error_within_half_step() {
        let (din, dout) = (128, 16);
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(din * dout, 0.1);
        let (packed, scales) = quantize(&w, din, dout);
        let w2 = dequantize(&packed, &scales, din, dout);
        for r in 0..din {
            for c in 0..dout {
                let s = scales[(r / GROUP) * dout + c];
                let err = (w2[r * dout + c] - w[r * dout + c]).abs();
                assert!(err <= s / 2.0 + 1e-7, "err {err} > step/2 {}", s / 2.0);
            }
        }
    }

    #[test]
    fn zeros_survive() {
        let (packed, scales) = quantize(&vec![0.0; 128 * 4], 128, 4);
        let w2 = dequantize(&packed, &scales, 128, 4);
        assert!(w2.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend(0x0f), -1);
        assert_eq!(sign_extend(0x08), -8);
        assert_eq!(sign_extend(0x07), 7);
        assert_eq!(sign_extend(0), 0);
    }

    #[test]
    fn byte_accounting() {
        // 0.5 B/param packed + scale overhead
        let b = quantized_bytes(896, 896);
        let params = 896 * 896;
        assert!(b > params as u64 / 2);
        assert!(b < params as u64 * 6 / 10);
    }

    #[test]
    fn matches_python_scheme_on_known_case() {
        // one group, values exactly on the grid: w = k * scale, max=7*s
        let s = 0.02f32;
        let mut w = vec![0f32; GROUP * 1];
        for (i, v) in w.iter_mut().enumerate() {
            *v = ((i % 16) as f32 - 8.0) * s; // values in [-8s, 7s]
        }
        let (packed, scales) = quantize(&w, GROUP, 1);
        assert!((scales[0] - 8.0 * s / 7.0).abs() < 1e-7);
        let w2 = dequantize(&packed, &scales, GROUP, 1);
        for (a, b) in w.iter().zip(&w2) {
            assert!((a - b).abs() <= scales[0] / 2.0 + 1e-7);
        }
    }
}
