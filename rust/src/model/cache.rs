//! Process-wide interning of frozen base weights.
//!
//! The paper's economics: adapter state is tiny next to the frozen base
//! model, so fleet density should scale with adapter size, not model
//! size. A [`WeightCache`] makes that real — it interns
//! [`FrozenModel`]s behind `Weak` references, keyed by the full identity
//! of the weights: every [`crate::config::ModelDims`] field, the
//! resolved model seed, and the [`QuantMode`]. Two sessions whose specs
//! agree on all three share ONE `Arc<FrozenModel>`; the resident bytes
//! are charged exactly once, under the `weights:shared` tag of the
//! cache's tracker, when the first holder builds the model, and released
//! when the last holder drops its `Arc` (the cache itself holds only
//! `Weak`s and never pins weights alive).
//!
//! `fleet::admission` mirrors this accounting at admission time: the
//! first job admitted under a weight key is charged the resident bytes,
//! later same-key jobs are charged zero for weights, and the last
//! release returns the bytes to the budget — see
//! [`crate::fleet::job_weight_class`].
//!
//! Snapshot restore goes through the same path: a resumed session
//! re-attaches to the cached `FrozenModel` for its spec (or regenerates
//! it on a cold cache) and verifies the snapshot's stored
//! `weights_fingerprint` against [`FrozenModel::fingerprint`] before
//! touching any adapter state.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::config::QuantMode;
use crate::memory::MemoryTracker;

use super::{FrozenModel, ModelSpec};

/// The interning key: the complete weight identity. All dims fields
/// participate (the cache hands out its interned `Arc<ModelDims>`, so
/// two specs must not collide unless every field agrees), plus the
/// resolved model seed and the resident precision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    name: String,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    d_ff: usize,
    seq: usize,
    batch: usize,
    rank: usize,
    alpha_bits: u32,
    seed: u64,
    quant: QuantMode,
}

impl CacheKey {
    fn of(spec: &ModelSpec) -> CacheKey {
        let d = &*spec.dims;
        CacheKey {
            name: d.name.clone(),
            vocab: d.vocab,
            d_model: d.d_model,
            n_layers: d.n_layers,
            n_heads: d.n_heads,
            n_kv_heads: d.n_kv_heads,
            head_dim: d.head_dim,
            d_ff: d.d_ff,
            seq: d.seq,
            batch: d.batch,
            rank: d.rank,
            alpha_bits: d.alpha.to_bits(),
            seed: spec.seed,
            quant: spec.quant,
        }
    }
}

/// Clonable handle to a frozen-weight intern table. See the module docs.
#[derive(Clone)]
pub struct WeightCache {
    map: Arc<Mutex<HashMap<CacheKey, Weak<FrozenModel>>>>,
    tracker: MemoryTracker,
}

impl WeightCache {
    /// A fresh cache whose builds charge `tracker` (under
    /// `weights:shared`). The fleet scheduler passes a child of its
    /// aggregate tracker so shared weights count against the budget
    /// without being attributed to any single session.
    pub fn new(tracker: MemoryTracker) -> WeightCache {
        WeightCache { map: Arc::default(), tracker }
    }

    /// The process-wide cache (own tracker). Standalone sessions default
    /// to a private per-session cache so weights stay attributed to the
    /// session's tracker; use this when several independently-built
    /// sessions in one process should share bases.
    pub fn global() -> &'static WeightCache {
        static GLOBAL: OnceLock<WeightCache> = OnceLock::new();
        GLOBAL.get_or_init(|| WeightCache::new(MemoryTracker::new()))
    }

    /// Return the interned `FrozenModel` for `spec`, building (and
    /// charging) it on first use. Builds happen under the table lock:
    /// concurrent same-key callers block until the first finishes and
    /// then share its result, so the bytes are never charged twice even
    /// transiently.
    pub fn get_or_build(&self, spec: &ModelSpec) -> Arc<FrozenModel> {
        let key = CacheKey::of(spec);
        let mut map = self.map.lock().unwrap();
        if let Some(m) = map.get(&key).and_then(Weak::upgrade) {
            return m;
        }
        let built = spec.build_frozen(&self.tracker);
        map.insert(key, Arc::downgrade(&built));
        built
    }

    /// Number of entries whose `FrozenModel` is still alive. Prunes dead
    /// `Weak`s as a side effect.
    pub fn live_entries(&self) -> usize {
        let mut map = self.map.lock().unwrap();
        map.retain(|_, w| w.strong_count() > 0);
        map.len()
    }

    /// The tracker shared-weight builds are charged against.
    pub fn tracker(&self) -> &MemoryTracker {
        &self.tracker
    }
}

impl std::fmt::Debug for WeightCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WeightCache")
            .field("live_entries", &self.live_entries())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;

    fn toy_dims() -> ModelDims {
        ModelDims {
            name: "toy".into(), vocab: 256, d_model: 64, n_layers: 2,
            n_heads: 4, n_kv_heads: 2, head_dim: 16, d_ff: 128, seq: 32,
            batch: 1, rank: 4, alpha: 8.0,
        }
    }

    #[test]
    fn same_spec_shares_one_model_charged_once() {
        let t = MemoryTracker::new();
        let cache = WeightCache::new(t.clone());
        let spec = ModelSpec::new(toy_dims(), 7, QuantMode::F32);
        let a = cache.get_or_build(&spec);
        let single = t.tag_bytes("weights:shared");
        assert!(single > 0);
        let b = cache.get_or_build(&spec.clone());
        assert!(Arc::ptr_eq(&a, &b), "same key must intern to one model");
        assert_eq!(t.tag_bytes("weights:shared"), single,
                   "second holder charges nothing");
        assert_eq!(cache.live_entries(), 1);
    }

    #[test]
    fn distinct_seed_or_quant_gets_own_entry() {
        let t = MemoryTracker::new();
        let cache = WeightCache::new(t.clone());
        let a = cache.get_or_build(&ModelSpec::new(toy_dims(), 7, QuantMode::F32));
        let b = cache.get_or_build(&ModelSpec::new(toy_dims(), 8, QuantMode::F32));
        let c = cache.get_or_build(&ModelSpec::new(toy_dims(), 7, QuantMode::Q4));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(cache.live_entries(), 3);
        assert_eq!(
            t.tag_bytes("weights:shared"),
            a.resident_bytes() + b.resident_bytes() + c.resident_bytes()
        );
    }

    #[test]
    fn last_drop_releases_bytes_and_entry() {
        let t = MemoryTracker::new();
        let cache = WeightCache::new(t.clone());
        let spec = ModelSpec::new(toy_dims(), 7, QuantMode::F32);
        let a = cache.get_or_build(&spec);
        let b = cache.get_or_build(&spec);
        drop(a);
        assert!(t.tag_bytes("weights:shared") > 0, "b still holds the model");
        drop(b);
        assert_eq!(t.tag_bytes("weights:shared"), 0,
                   "last drop releases the tag");
        assert_eq!(cache.live_entries(), 0, "dead weak entries pruned");
        // rebuilding after eviction regenerates identical weights
        let c = cache.get_or_build(&spec);
        assert!(c.resident_bytes() > 0);
        assert_eq!(cache.live_entries(), 1);
    }
}
