//! int8 activation compression with structured outlier storage — the
//! HyC-LoRA-style "compressed cache" applied to BUFFERED activations:
//! store-h's saved `h = xA` and MeBP's between-phase residual window.
//!
//! Scheme: flatten the tensor set into one stream, quantize in groups of
//! [`GROUP`] with a symmetric per-group scale, and store the few
//! heavy-tail elements of each group EXACTLY as `(index, f32)` pairs —
//! the scale is then computed over the remaining inliers, so one spike
//! does not blow up the whole group's step size. Deterministic (no
//! data-dependent allocation beyond the capped outlier list) and lossy:
//! roundtrip error is ≤ scale/2 per inlier, 0 for outliers.
//!
//! Distinct from [`super::quant`] (int4 *weight* packing, done once at
//! session build): this runs on the training hot path, once per layer
//! per step, and must bound its own footprint —
//! [`compressed_bytes_bound`] is the admission/memory-model charge.

/// Elements per quantization group.
pub const GROUP: usize = 64;
/// Hard cap on exactly-stored outliers per group — bounds the compressed
/// size independent of the data (the memory model needs a shape-only
/// bound).
pub const MAX_OUTLIERS_PER_GROUP: usize = 4;
/// An element is an outlier candidate when `|v| > OUTLIER_MULT × rms` of
/// its group (a heavy tail relative to the group's energy).
const OUTLIER_MULT: f32 = 4.0;

/// One compressed activation blob.
#[derive(Debug, Clone)]
pub struct Compressed {
    /// Per-element int8 codes (outlier slots hold 0).
    pub data: Vec<i8>,
    /// Per-group symmetric scales (`absmax(inliers) / 127`).
    pub scales: Vec<f32>,
    /// Exactly-stored heavy-tail elements: (flat index, original value).
    pub outliers: Vec<(u32, f32)>,
    /// Uncompressed element count.
    pub len: usize,
}

impl Compressed {
    /// Host bytes this blob occupies (payload + scales + outlier pairs)
    /// — what the store-h guard charges while the blob is held.
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
            + self.scales.len() as u64 * 4
            + self.outliers.len() as u64 * 8
    }
}

/// Shape-only upper bound on [`Compressed::bytes`] for `elems` elements:
/// 1 B/element + per-group scale + the outlier cap. The memory model and
/// fleet admission charge this, so it must dominate any data.
pub fn compressed_bytes_bound(elems: u64) -> u64 {
    let groups = elems.div_ceil(GROUP as u64);
    elems + groups * (4 + MAX_OUTLIERS_PER_GROUP as u64 * 8)
}

/// Compress a flat f32 stream (callers concatenate their tensor set).
pub fn compress(x: &[f32]) -> Compressed {
    let mut data = vec![0i8; x.len()];
    let mut scales = Vec::with_capacity(x.len().div_ceil(GROUP));
    let mut outliers = Vec::new();
    for (g, chunk) in x.chunks(GROUP).enumerate() {
        let base = g * GROUP;
        let rms =
            (chunk.iter().map(|v| v * v).sum::<f32>() / chunk.len() as f32).sqrt();
        let threshold = OUTLIER_MULT * rms;
        // Up to MAX_OUTLIERS_PER_GROUP largest-|v| elements above the
        // heavy-tail threshold, stored exactly.
        let mut idx: Vec<usize> = (0..chunk.len())
            .filter(|&i| chunk[i].is_finite() && chunk[i].abs() > threshold)
            .collect();
        idx.sort_by(|&a, &b| {
            chunk[b].abs().partial_cmp(&chunk[a].abs()).unwrap()
        });
        idx.truncate(MAX_OUTLIERS_PER_GROUP);
        let is_out = |i: usize| idx.contains(&i);
        let mut mx = 0f32;
        for (i, &v) in chunk.iter().enumerate() {
            if !is_out(i) {
                mx = mx.max(v.abs());
            }
        }
        // Same degenerate-group discipline as quant::quantize: all-zero
        // or non-finite groups get an exact 0.0 scale.
        let s = mx / 127.0;
        let s = if s.is_finite() { s } else { 0.0 };
        scales.push(s);
        for (i, &v) in chunk.iter().enumerate() {
            if is_out(i) {
                outliers.push((base as u32 + i as u32, v));
            } else if s != 0.0 {
                data[base + i] = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
    Compressed { data, scales, outliers, len: x.len() }
}

/// Dequantize into a caller-owned buffer (the backward decompresses into
/// arena scratch or a reused host buffer).
pub fn decompress_into(c: &Compressed, out: &mut [f32]) {
    assert_eq!(out.len(), c.len);
    for (i, (o, &q)) in out.iter_mut().zip(&c.data).enumerate() {
        *o = q as f32 * c.scales[i / GROUP];
    }
    for &(i, v) in &c.outliers {
        out[i as usize] = v;
    }
}

/// Dequantize to a fresh `Vec`.
pub fn decompress(c: &Compressed) -> Vec<f32> {
    let mut out = vec![0f32; c.len];
    decompress_into(c, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_error_within_half_step() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(GROUP * 5 + 17, 0.3); // ragged tail group
        let c = compress(&x);
        let y = decompress(&c);
        let out: std::collections::HashSet<u32> =
            c.outliers.iter().map(|(i, _)| *i).collect();
        for (i, (a, b)) in x.iter().zip(&y).enumerate() {
            if out.contains(&(i as u32)) {
                assert_eq!(a, b, "outliers are exact");
            } else {
                let s = c.scales[i / GROUP];
                assert!((a - b).abs() <= s / 2.0 + 1e-7, "idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn outliers_stored_exactly_and_capped() {
        let mut x = vec![0.01f32; GROUP];
        x[3] = 100.0; // a spike 4 orders above the inliers
        x[40] = -50.0;
        let c = compress(&x);
        assert!(c.outliers.iter().any(|&(i, v)| i == 3 && v == 100.0));
        assert!(c.outliers.iter().any(|&(i, v)| i == 40 && v == -50.0));
        assert!(c.outliers.len() <= MAX_OUTLIERS_PER_GROUP);
        // the inlier scale is NOT poisoned by the spike: 0.01/127-ish
        assert!(c.scales[0] < 0.001, "scale {} poisoned by outlier", c.scales[0]);
        let y = decompress(&c);
        assert_eq!(y[3], 100.0);
        assert_eq!(y[40], -50.0);
        assert!((y[7] - 0.01).abs() < 0.001);
    }

    #[test]
    fn zeros_and_degenerate_groups_survive() {
        let c = compress(&vec![0.0f32; GROUP * 2]);
        assert!(decompress(&c).iter().all(|v| *v == 0.0));
        assert!(c.outliers.is_empty());
        // non-finite input must not poison the scale
        let mut x = vec![f32::NAN; GROUP];
        x[0] = 1.0;
        let c = compress(&x);
        assert!(c.scales[0].is_finite());
    }

    #[test]
    fn bytes_within_shape_bound_and_under_f32() {
        let mut rng = Rng::new(2);
        for n in [1, GROUP, GROUP * 7 + 5, 4096] {
            let x = rng.normal_vec(n, 1.0);
            let c = compress(&x);
            assert!(c.bytes() <= compressed_bytes_bound(n as u64),
                    "n={n}: {} > bound {}", c.bytes(), compressed_bytes_bound(n as u64));
        }
        // the whole point: well under the 4 B/element f32 cache
        let n = 4096u64;
        assert!(compressed_bytes_bound(n) * 2 < n * 4);
    }
}
