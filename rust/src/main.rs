//! `mesp` CLI — the launcher for the MeSP reproduction system.
//!
//! See `mesp help` (config::cli::USAGE) for the command reference. The
//! binary is fully self-contained on the default reference backend; with
//! `--features pjrt` it can instead execute the AOT artifact sets
//! produced by `make artifacts` (Python never runs on any code path
//! reachable from here).

use std::path::Path;

use mesp::config::cli::{Args, USAGE};
use mesp::config::{
    presets, ActCompress, BackendKind, KernelKind, Method, OptimizerKind,
    QuantMode, TrainConfig,
};
use mesp::coordinator::TrainSession;
use mesp::fleet::{self, FleetOptions, Scheduler};
use mesp::memory::model as memmodel;
use mesp::metrics::grad_quality;
use mesp::reproduce;
use mesp::util::stats::fmt_mb;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Exit codes (pinned in fleet::serve): 0 clean, 1 runtime failure,
    // 2 completed-with-job-failures, 3 startup failure. The long-running
    // commands (`fleet`, `serve`) classify their own errors and return
    // the code; anything that escapes as an Err is a runtime failure.
    let code = match run(args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            fleet::EXIT_RUNTIME
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> anyhow::Result<i32> {
    let args = Args::parse(argv)?;
    // Per-subcommand flag allowlists (config::cli::known_flags): typo'd
    // flags and unknown subcommands fail here with the USAGE text.
    args.validate()?;
    match args.command.as_str() {
        "train" => cmd_train(&args).map(|()| fleet::EXIT_OK),
        "fleet" => cmd_fleet(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "simulate" => cmd_simulate(&args).map(|()| fleet::EXIT_OK),
        "gradcheck" => cmd_gradcheck(&args).map(|()| fleet::EXIT_OK),
        "mezo-quality" => cmd_mezo_quality(&args).map(|()| fleet::EXIT_OK),
        "reproduce" => cmd_reproduce(&args).map(|()| fleet::EXIT_OK),
        "inspect" => cmd_inspect(&args).map(|()| fleet::EXIT_OK),
        "report" => cmd_report(&args).map(|()| fleet::EXIT_OK),
        "help" | "" => {
            println!("{USAGE}");
            Ok(fleet::EXIT_OK)
        }
        // validate() already rejected commands without an allowlist, so
        // reaching this arm means cli::known_flags knows a command this
        // match does not dispatch.
        other => anyhow::bail!(
            "command '{other}' has an allowlist but no handler — add a \
             match arm in main::run"
        ),
    }
}

fn train_config(args: &Args) -> anyhow::Result<TrainConfig> {
    Ok(TrainConfig {
        config: args.str("config", "toy"),
        backend: BackendKind::parse(&args.str("backend", "reference"))?,
        method: Method::parse(&args.str("method", "mesp"))?,
        steps: args.usize("steps", 10)?,
        lr: args.f32("lr", 1e-4)?,
        seed: args.u64("seed", 42)?,
        optimizer: OptimizerKind::parse(&args.str("optimizer", "sgd"))?,
        mezo_eps: args.f32("mezo-eps", 1e-3)?,
        log_every: args.usize("log-every", 10)?,
        spill_limit: args.u64("spill-limit", 0)?,
        metrics_path: args.opt_str("metrics"),
        artifacts_dir: args.str("artifacts", "artifacts"),
        kernel: KernelKind::parse(&args.str("kernel", "parallel"))?,
        threads: args.usize("threads", 0)?,
        quant: QuantMode::parse(&args.str("quant", "f32"))?,
        model_seed: None,
        trace_path: args.opt_str("trace"),
        metrics_out: args.opt_str("metrics-out"),
        loss_chunk: args.usize("loss-chunk", 0)?,
        act_compress: ActCompress::parse(&args.str("act-compress", "none"))?,
    })
}

/// `--tune`: sweep the GEMM tile candidates on the calibration set with
/// the detected ISA's micro-kernel, install the winner process-wide (so
/// every engine this run builds uses it), and persist it to the tuning
/// profile for later runs. Tile choice is scheduling-only, so tuning
/// never changes which loss bits a fixed profile produces — it only
/// changes which profile this process runs with.
fn maybe_tune(args: &Args) {
    if !args.bool("tune") {
        return;
    }
    let isa = mesp::runtime::kernels::simd::detect();
    let (outcome, written) = mesp::runtime::kernels::tune::tune_and_install(isa);
    let (best, best_ms) = outcome.table[0];
    println!(
        "tune: isa={} best tiles {} ({best_ms:.2} ms on the calibration set, \
         {} candidates)",
        isa.name(),
        best.label(),
        outcome.table.len()
    );
    match written {
        Some(p) => println!("tune: profile written: {}", p.display()),
        None => println!("tune: no writable profile path; winner used for this run only"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    maybe_tune(args);
    let cfg = train_config(args)?;
    let save_every = args.usize("save-every", 0)?;
    let snap_dir = std::path::PathBuf::from(args.str("snapshot-dir", "snapshots"));
    let steps = cfg.steps;
    let mut sess = match args.opt_str("resume") {
        Some(path) => {
            // The snapshot's identity (config/method/quant/optimizer/lr/
            // seed) wins over the flags; backend/kernel/threads wiring
            // stays with the caller — resume parity is bitwise on every
            // kernel variant and thread count.
            let sess = TrainSession::builder(cfg)
                .resume_from(Path::new(&path))
                .build()?;
            println!(
                "resumed {} from step {} (config={} method={} quant={} \
                 seed={})",
                path, sess.steps_done(), sess.cfg.config,
                sess.cfg.method.name(), sess.cfg.quant.name(), sess.cfg.seed
            );
            anyhow::ensure!(
                sess.steps_done() < steps,
                "snapshot is already at step {} >= --steps {steps}; nothing \
                 to resume (raise --steps)",
                sess.steps_done()
            );
            sess
        }
        None => TrainSession::builder(cfg).build()?,
    };
    let method = sess.cfg.method;
    let quant = sess.cfg.quant;
    println!(
        "training config={} backend={} method={} steps={} lr={} \
         optimizer={:?} kernel={} threads={} quant={}",
        sess.cfg.config, sess.cfg.backend.name(), method.name(), steps,
        sess.cfg.lr, sess.cfg.optimizer, sess.cfg.kernel.name(),
        if sess.cfg.threads == 0 {
            "auto".to_string()
        } else {
            sess.cfg.threads.to_string()
        },
        quant.name()
    );
    while sess.steps_done() < steps {
        sess.step_once()?;
        if save_every > 0 && sess.steps_done() % save_every == 0 {
            let path = snap_dir.join(format!("step-{}.snap", sess.steps_done()));
            let bytes = sess.save_snapshot(&path)?;
            println!(
                "snapshot: {} ({} bytes, step {})",
                path.display(), bytes, sess.steps_done()
            );
        }
    }
    let summary = sess.metrics.summary();
    summary.print(method.name());
    // Exact-precision final loss for the CI resume tier: a suspended-
    // at-k-then-resumed run must reproduce these BITS.
    println!(
        "final loss bits: 0x{:016x} ({:e})",
        summary.final_loss.to_bits(),
        summary.final_loss
    );
    // The deployment number the q4 path exists for: how many bytes of
    // base weights stay resident for the whole session. The host copy
    // lives in the process-wide cache ("weights:shared", charged once no
    // matter how many sessions attach); upload backends additionally
    // keep a per-session device copy ("weights:device").
    let resident = sess.tracker.tag_bytes("weights:shared")
        + sess.tracker.tag_bytes("weights:device");
    println!(
        "resident base weights ({}): {} MB",
        quant.name(),
        fmt_mb(resident)
    );
    println!("\nper-artifact execution stats:");
    print!("{}", mesp::metrics::exec_stats_table(&sess.engine.ctx().rt.exec_stats()));
    // Telemetry files, if asked for: the Chrome trace (--trace) and the
    // metrics-registry snapshot (--metrics-out). Observe-only — written
    // after training so they can never perturb the loss stream.
    sess.export_telemetry()?;
    if let Some(p) = &sess.cfg.trace_path {
        println!("trace written: {p} (chrome://tracing or ui.perfetto.dev)");
    }
    if let Some(p) = &sess.cfg.metrics_out {
        println!("metrics written: {p}");
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<i32> {
    maybe_tune(args);
    // Everything up to Scheduler::run is startup: bad flags, an
    // unparsable job file, an overflowing budget. Those failures exit 3
    // so wrappers can tell "never started" from "started and broke" (1)
    // from "finished but some jobs failed" (2).
    let (base, opts, jobs, budget_mb) = match fleet_setup(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(fleet::EXIT_STARTUP);
        }
    };
    if args.bool("print-cost") {
        // Script-friendly admission costs (CI sizes preemption and
        // shared-weight budgets with this: the per-job cost depends on
        // the machine's core count via the kernel packing-panel term,
        // and the weight class is charged once per distinct base).
        let mut seen = std::collections::BTreeSet::new();
        let mut classes = std::collections::BTreeSet::new();
        for job in &jobs {
            if seen.insert(job.spec.method.name()) {
                let c = fleet::job_cost_bytes(&job.spec)?;
                println!(
                    "cost {} {c} bytes ({} MB)",
                    job.spec.method.name(),
                    fmt_mb(c)
                );
            }
            let w = fleet::job_weight_class(&job.spec)?;
            if classes.insert(w.key) {
                println!(
                    "weights {:016x} {} bytes ({} MB, charged once per base)",
                    w.key,
                    w.bytes,
                    fmt_mb(w.bytes)
                );
            }
        }
        return Ok(fleet::EXIT_OK);
    }
    println!(
        "fleet: {} jobs on config {} | budget {budget_mb} MB | {} workers \
         | quant {}{}",
        jobs.len(), base.config, opts.workers, base.quant.name(),
        if opts.preempt || !opts.budget_schedule.is_empty() {
            " | preemption on"
        } else {
            ""
        }
    );
    let report = Scheduler::run(&opts, &base, jobs)?;
    print!("{}", report.render());
    if let Some(p) = &opts.trace_path {
        println!("trace written: {} (chrome://tracing or ui.perfetto.dev)",
                 p.display());
    }
    if let Some(p) = &opts.metrics_out {
        println!("metrics written: {}", p.display());
    }
    if report.failed() > 0 {
        eprintln!("{} fleet job(s) failed (see report)", report.failed());
        return Ok(fleet::EXIT_JOB_FAILURES);
    }
    Ok(fleet::EXIT_OK)
}

type FleetSetup = (TrainConfig, FleetOptions, Vec<fleet::Job>, u64);

fn fleet_setup(args: &Args) -> anyhow::Result<FleetSetup> {
    let base = TrainConfig {
        config: args.str("config", "toy"),
        backend: BackendKind::parse(&args.str("backend", "reference"))?,
        steps: args.usize("steps", 5)?,
        lr: args.f32("lr", 1e-4)?,
        seed: args.u64("seed", 42)?,
        optimizer: OptimizerKind::parse(&args.str("optimizer", "sgd"))?,
        log_every: usize::MAX, // per-step logs off; the report has it all
        artifacts_dir: args.str("artifacts", "artifacts"),
        kernel: KernelKind::parse(&args.str("kernel", "parallel"))?,
        // 0 = auto: the scheduler divides cores by its worker count
        threads: args.usize("threads", 0)?,
        quant: QuantMode::parse(&args.str("quant", "f32"))?,
        loss_chunk: args.usize("loss-chunk", 0)?,
        act_compress: ActCompress::parse(&args.str("act-compress", "none"))?,
        ..Default::default()
    };
    let budget_mb = args.u64("budget-mb", 1024)?;
    anyhow::ensure!(budget_mb > 0, "--budget-mb must be positive");
    let budget_bytes = budget_mb
        .checked_mul(1 << 20)
        .ok_or_else(|| anyhow::anyhow!("--budget-mb {budget_mb} overflows"))?;
    let budget_schedule = match args.opt_str("budget-schedule") {
        Some(s) => fleet::parse_budget_schedule(&s)?,
        None => Vec::new(),
    };
    let opts = FleetOptions {
        budget_bytes,
        workers: args.usize("workers", 4)?.max(1),
        preempt: args.bool("preempt"),
        snapshot_dir: args.opt_str("snapshot-dir").map(std::path::PathBuf::from),
        budget_schedule,
        trace_path: args.opt_str("trace").map(std::path::PathBuf::from),
        metrics_out: args.opt_str("metrics-out").map(std::path::PathBuf::from),
    };
    let jobs = match args.opt_str("job-file") {
        Some(path) => {
            anyhow::ensure!(
                args.opt_str("methods").is_none() && args.opt_str("jobs").is_none(),
                "--job-file conflicts with --methods/--jobs (the job file \
                 defines the jobs)"
            );
            fleet::load_jobs(Path::new(&path), &base)?
        }
        None => {
            let methods = Method::parse_list(&args.str("methods", "mesp,mebp"))?;
            fleet::grid(&base, &methods, args.usize("jobs", 8)?.max(1))
        }
    };
    Ok((base, opts, jobs, budget_mb))
}

fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    use mesp::fleet::{ServeOptions, Server};

    maybe_tune(args);
    let setup = || -> anyhow::Result<(ServeOptions, TrainConfig)> {
        let base = TrainConfig {
            config: args.str("config", "toy"),
            backend: BackendKind::parse(&args.str("backend", "reference"))?,
            steps: args.usize("steps", 5)?,
            lr: args.f32("lr", 1e-4)?,
            seed: args.u64("seed", 42)?,
            optimizer: OptimizerKind::parse(&args.str("optimizer", "sgd"))?,
            log_every: usize::MAX, // jobs log through `status`, not stdout
            artifacts_dir: args.str("artifacts", "artifacts"),
            kernel: KernelKind::parse(&args.str("kernel", "parallel"))?,
            threads: args.usize("threads", 0)?,
            quant: QuantMode::parse(&args.str("quant", "f32"))?,
            loss_chunk: args.usize("loss-chunk", 0)?,
            act_compress: ActCompress::parse(&args.str("act-compress", "none"))?,
            ..Default::default()
        };
        let budget_mb = args.u64("budget-mb", 1024)?;
        anyhow::ensure!(budget_mb > 0, "--budget-mb must be positive");
        let budget_bytes = budget_mb
            .checked_mul(1 << 20)
            .ok_or_else(|| anyhow::anyhow!("--budget-mb {budget_mb} overflows"))?;
        let budget_schedule = match args.opt_str("budget-schedule") {
            Some(s) => fleet::parse_budget_schedule(&s)?,
            None => Vec::new(),
        };
        let quotas = match args.opt_str("quota") {
            Some(s) => fleet::serve::parse_tenant_list(&s, "quota", true)?,
            None => Vec::new(),
        };
        let tenant_weights = match args.opt_str("tenant-weights") {
            Some(s) => fleet::serve::parse_tenant_list(&s, "weight", false)?,
            None => Vec::new(),
        };
        let defaults = ServeOptions::default();
        let opts = ServeOptions {
            socket: args
                .opt_str("socket")
                .map(std::path::PathBuf::from)
                .unwrap_or(defaults.socket),
            snapshot_dir: args
                .opt_str("snapshot-dir")
                .map(std::path::PathBuf::from)
                .unwrap_or(defaults.snapshot_dir),
            budget_bytes,
            workers: args.usize("workers", 2)?.max(1),
            checkpoint_every: args.usize("checkpoint-every", 0)?,
            budget_schedule,
            quotas,
            tenant_weights,
            metrics_out: args.opt_str("metrics-out").map(std::path::PathBuf::from),
        };
        Ok((opts, base))
    };
    // Startup failures — bad flags, a held lock, a corrupt recovery
    // sidecar, an unbindable socket — exit 3 so supervisors don't
    // confuse "never came up" with a crash of a running daemon (1).
    let server = match setup().and_then(|(opts, base)| Server::start(opts, base)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(fleet::EXIT_STARTUP);
        }
    };
    println!("serve: listening on {}", server.socket().display());
    let summary = server.run()?;
    print!("{}", summary.render());
    if summary.failed > 0 {
        eprintln!("{} serve job(s) failed (see status output)", summary.failed);
        return Ok(fleet::EXIT_JOB_FAILURES);
    }
    Ok(fleet::EXIT_OK)
}

fn cmd_loadgen(args: &Args) -> anyhow::Result<i32> {
    use mesp::fleet::loadgen;

    let setup = || -> anyhow::Result<loadgen::LoadgenOptions> {
        let d = loadgen::LoadgenOptions::default();
        Ok(loadgen::LoadgenOptions {
            socket: args
                .opt_str("socket")
                .map(std::path::PathBuf::from)
                .unwrap_or(d.socket),
            arrivals: args.usize("arrivals", d.arrivals)?,
            rate: args.f32("rate", d.rate as f32)? as f64,
            tenants: args.usize("tenants", d.tenants)?.max(1),
            sim_us: args.u64("sim-us", d.sim_us)?,
            seed: args.u64("seed", d.seed)?,
            steps: args.usize("steps", d.steps)?.max(1),
            time_scale: args.f32("time-scale", d.time_scale as f32)? as f64,
            diurnal_amp: args.f32("diurnal-amp", d.diurnal_amp as f32)? as f64,
            diurnal_period_s: args
                .f32("diurnal-period", d.diurnal_period_s as f32)?
                as f64,
            burst_every: args.usize("burst-every", d.burst_every)?,
            burst_len: args.usize("burst-len", d.burst_len)?,
            burst_x: args.f32("burst-x", d.burst_x as f32)? as f64,
            squeezes: match args.opt_str("squeeze") {
                Some(s) => loadgen::parse_squeezes(&s)?,
                None => Vec::new(),
            },
            real: args.bool("real"),
            shutdown: args.bool("shutdown"),
            out: args
                .opt_str("out")
                .map(std::path::PathBuf::from)
                .unwrap_or(d.out),
        })
    };
    let opts = match setup() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e:#}");
            return Ok(fleet::EXIT_STARTUP);
        }
    };
    let report = loadgen::run(&opts)?;
    print!("{}", report.render());
    println!("benchmark written: {}", opts.out.display());
    Ok(fleet::EXIT_OK)
}

/// `mesp report` — per-step memory profile from the tracker's event
/// timeline, cross-checked against the analytical envelope the fleet
/// admits jobs under. For each method: run a short session on a
/// timeline-enabled tracker, split the event stream at the `step:N`
/// markers the engines record, and assert every step's observed peak
/// stays inside `job_cost_bytes + weight-class bytes` (activations +
/// optimizer + batch queue + kernel scratch, plus the resident base this
/// unshared session is charged for itself).
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    use mesp::fleet::JobSpec;
    use mesp::memory::MemoryTracker;
    use mesp::metrics::TableBuilder;

    let methods = Method::parse_list(&args.str("methods", "mesp,mebp,storeh"))?;
    let steps = args.usize("steps", 3)?;
    anyhow::ensure!(steps > 0, "--steps must be positive");
    let mut table = TableBuilder::new(&[
        "Method", "Step", "Peak MB", "Live after MB", "Envelope MB",
        "Headroom",
    ]);
    for &method in &methods {
        let mut cfg = train_config(args)?;
        cfg.method = method;
        cfg.steps = steps;
        cfg.log_every = usize::MAX;
        let tracker = MemoryTracker::with_timeline();
        let mut sess = TrainSession::builder(cfg)
            .tracker(tracker.clone())
            .build()?;
        for _ in 0..steps {
            sess.step_once()?;
        }
        let spec = JobSpec::from_base(&sess.cfg);
        let envelope = fleet::job_cost_bytes(&spec)?
            + fleet::job_weight_class(&spec)?.bytes;
        anyhow::ensure!(
            tracker.timeline_dropped() == 0,
            "method {}: {} timeline events evicted — raise the ring \
             capacity or lower --steps",
            method.name(),
            tracker.timeline_dropped()
        );
        // Events between two `step:` markers belong to the step whose
        // marker CLOSES the segment (mark_step runs after the step body);
        // the first segment also covers session build + warmup, whose
        // allocations are still live during step 1.
        let mut seen = 0u64;
        let mut seg_peak = 0u64;
        for ev in tracker.timeline() {
            seg_peak = seg_peak.max(ev.live);
            let Some(n) = ev.tag.strip_prefix("step:") else { continue };
            let n: u64 = n.parse()?;
            anyhow::ensure!(
                seg_peak <= envelope,
                "method {} step {n}: observed peak {seg_peak} bytes \
                 exceeds the analytical envelope {envelope} bytes",
                method.name()
            );
            table.row(vec![
                method.name().to_string(),
                n.to_string(),
                fmt_mb(seg_peak),
                fmt_mb(ev.live),
                fmt_mb(envelope),
                format!("{:.1}%",
                        100.0 * (1.0 - seg_peak as f64 / envelope as f64)),
            ]);
            seen = n;
            seg_peak = 0;
        }
        anyhow::ensure!(
            seen == steps as u64,
            "method {}: timeline holds {seen} step markers, expected {steps}",
            method.name()
        );
    }
    print!("{}", table.render());
    println!(
        "report OK: {} methods x {steps} steps within the analytical \
         envelope",
        methods.len()
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = args.str("model", "0.5b");
    let seq = args.usize("seq", 256)?;
    let rank = args.usize("rank", 8)?;
    if args.bool("breakdown") {
        print!("{}", reproduce::breakdown(&model, seq, rank)?);
        return Ok(());
    }
    let dims = presets::by_name(&model, seq, rank)?;
    println!("analytical peak memory, {} (paper widths):", dims.name);
    for m in [Method::Mebp, Method::Mezo, Method::Mesp, Method::StoreH] {
        let bytes = memmodel::peak_bytes(m, &dims);
        let red = memmodel::reduction_vs_mebp(m, &dims);
        println!("  {:<8} {:>8} MB   ({:>5.1}% vs MeBP)", m.name(),
                 fmt_mb(bytes), red);
    }
    Ok(())
}

fn cmd_gradcheck(args: &Args) -> anyhow::Result<()> {
    let config = args.str("config", "toy");
    let seeds = args.usize("seeds", 3)?;
    let tol = args.f32("tol", 2e-4)? as f64;
    let mut worst: f64 = 0.0;
    for seed in 0..seeds as u64 {
        let base = TrainConfig {
            config: config.clone(),
            backend: BackendKind::parse(&args.str("backend", "reference"))?,
            seed: 1000 + seed,
            log_every: usize::MAX,
            artifacts_dir: args.str("artifacts", "artifacts"),
            kernel: KernelKind::parse(&args.str("kernel", "parallel"))?,
            threads: args.usize("threads", 0)?,
            quant: QuantMode::parse(&args.str("quant", "f32"))?,
            ..Default::default()
        };
        let mut grads = Vec::new();
        for method in [Method::Mesp, Method::Mebp, Method::StoreH] {
            let mut cfg = base.clone();
            cfg.method = method;
            let mut sess = TrainSession::builder(cfg).build()?;
            let (batch, _g) = sess.loader.next();
            grads.push((method, sess.engine.gradients(&batch)?));
        }
        let (_, ref mesp_g) = grads[0];
        for (method, g) in &grads[1..] {
            for (l, (a, b)) in mesp_g.iter().zip(g).enumerate() {
                let q = grad_quality(&[a.clone()], &[b.clone()]);
                let err = q[0].rel_error;
                worst = worst.max(err);
                anyhow::ensure!(
                    err < tol,
                    "seed {seed} layer {l}: MeSP vs {} rel err {err:.2e} > {tol:.0e}",
                    method.name()
                );
            }
        }
        println!("seed {seed}: MeSP ≡ MeBP ≡ store-h  ✓");
    }
    println!(
        "gradcheck PASSED over {seeds} seeds (worst rel err {worst:.2e} \
         < {tol:.0e}) — the paper's 'mathematically identical gradients'."
    );
    Ok(())
}

fn cmd_mezo_quality(args: &Args) -> anyhow::Result<()> {
    print!("{}", reproduce::table3(&args.str("config", "small"))?);
    Ok(())
}

fn cmd_reproduce(args: &Args) -> anyhow::Result<()> {
    let steps = args.usize("steps", 5)?;
    let mut output = String::new();
    if args.bool("all") {
        for n in 1..=11 {
            println!("=== table {n} ===");
            let s = reproduce::run_table(n, steps)?;
            println!("{s}");
            output.push_str(&s);
            output.push('\n');
        }
    } else if let Some(f) = args.opt_str("fig") {
        anyhow::ensure!(f == "2", "the paper has one figure with data: 2");
        let s = reproduce::run_table(11, steps.max(100))?;
        println!("{s}");
        output = s;
    } else {
        let n = args.usize("table", 1)?;
        let s = reproduce::run_table(n, steps)?;
        println!("{s}");
        output = s;
    }
    if let Some(path) = args.opt_str("out") {
        std::fs::write(Path::new(&path), output)?;
        println!("(written to {path})");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let backend = BackendKind::parse(&args.str("backend", "reference"))?;
    let config = args.str("config", "toy");
    let (dims, artifacts): (_, Vec<mesp::runtime::ArtifactSpec>) = match backend {
        BackendKind::Reference => {
            let dims = presets::compiled(&config)?;
            let be = mesp::runtime::ReferenceBackend::new(
                dims.clone(), mesp::memory::MemoryTracker::new());
            (dims, be.artifact_specs().to_vec())
        }
        BackendKind::Pjrt => {
            let dir = Path::new(&args.str("artifacts", "artifacts")).join(&config);
            let man = mesp::runtime::Manifest::load(&dir)?;
            (man.dims.clone(), man.artifacts.clone())
        }
    };
    println!(
        "config {} (backend {}): d={} L={} H={}/{} ff={} seq={} r={} \
         ({}M params, {}k LoRA)",
        dims.name, backend.name(), dims.d_model, dims.n_layers, dims.n_heads,
        dims.n_kv_heads, dims.d_ff, dims.seq, dims.rank,
        dims.frozen_params_total() / 1_000_000,
        dims.lora_params_total() / 1000
    );
    for a in &artifacts {
        // Analytical nominal FLOPs per call — inspect never executes, so
        // this is the same inventory the kernel engine instruments live.
        // The weight-bytes column is the byte half of the FLOP/byte
        // story: `_q4` artifacts stream ~1/7 of the frozen bytes their
        // f32 twins do at identical FLOPs.
        let gflop =
            mesp::runtime::kernels::flops::artifact(&dims, &a.name) as f64 / 1e9;
        let wmb = mesp::runtime::kernels::flops::artifact_weight_bytes(&dims, &a.name)
            as f64
            / (1024.0 * 1024.0);
        println!(
            "  {:<26} {:>2} args -> {:>2} outputs  {:>8.3} GFLOP/call  \
             {:>7.2} W-MB/call  ({})",
            a.name, a.args.len(), a.outputs, gflop, wmb,
            a.file.file_name().unwrap_or_default().to_string_lossy()
        );
    }
    Ok(())
}
