//! Named counters, gauges and histograms with JSONL snapshot export.
//!
//! The registry is the one place run-level numbers accumulate; the tables
//! the CLI prints ([`crate::metrics::tables::exec_stats_table`],
//! `FleetReport::render`) are *views* over it rather than ad-hoc printf
//! aggregation. Cloning shares the underlying store, so a fleet run hands
//! one registry to every session and gets fleet-wide step-latency
//! histograms for free.
//!
//! Histogram summaries use [`crate::util::stats::percentile`]
//! (nearest-rank), so a brute-force oracle over the raw samples must agree
//! exactly — that equivalence is pinned by tests.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::util::stats;

#[derive(Debug, Default)]
struct Store {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
}

/// Shared, thread-safe metrics store. Cheap to clone (Arc).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Store>>,
}

/// Summary statistics for one histogram (nearest-rank percentiles).
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSummary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter_add(&self, name: &str, n: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn gauge_set(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Record one histogram sample.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().push(value);
    }

    /// Raw samples for one histogram (test/oracle helper).
    pub fn samples(&self, name: &str) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn histogram(&self, name: &str) -> Option<HistogramSummary> {
        let g = self.inner.lock().unwrap();
        let xs = g.histograms.get(name)?;
        Some(summarize(xs))
    }

    /// All metric names, grouped `(counters, gauges, histograms)`, sorted.
    pub fn names(&self) -> (Vec<String>, Vec<String>, Vec<String>) {
        let g = self.inner.lock().unwrap();
        (
            g.counters.keys().cloned().collect(),
            g.gauges.keys().cloned().collect(),
            g.histograms.keys().cloned().collect(),
        )
    }

    /// Gauges whose name starts with `prefix` (key, value), sorted by key.
    pub fn gauges_with_prefix(&self, prefix: &str) -> Vec<(String, f64)> {
        let g = self.inner.lock().unwrap();
        g.gauges
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// One JSON object per metric, sorted by kind then name. This is the
    /// JSONL schema: `kind`, `name`, then `value` (counter/gauge) or
    /// `count/mean/min/max/p50/p90/p99` (histogram).
    pub fn snapshot_lines(&self) -> Vec<Json> {
        let g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (name, v) in &g.counters {
            out.push(Json::obj(vec![
                ("kind", Json::str("counter")),
                ("name", Json::str(name.clone())),
                ("value", Json::Num(*v as f64)),
            ]));
        }
        for (name, v) in &g.gauges {
            out.push(Json::obj(vec![
                ("kind", Json::str("gauge")),
                ("name", Json::str(name.clone())),
                ("value", Json::Num(*v)),
            ]));
        }
        for (name, xs) in &g.histograms {
            let s = summarize(xs);
            out.push(Json::obj(vec![
                ("kind", Json::str("histogram")),
                ("name", Json::str(name.clone())),
                ("count", Json::Num(s.count as f64)),
                ("mean", Json::Num(s.mean)),
                ("min", Json::Num(s.min)),
                ("max", Json::Num(s.max)),
                ("p50", Json::Num(s.p50)),
                ("p90", Json::Num(s.p90)),
                ("p99", Json::Num(s.p99)),
            ]));
        }
        out
    }

    /// Write the snapshot as JSON-lines to `path`.
    pub fn export_jsonl(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = String::new();
        for line in self.snapshot_lines() {
            out.push_str(&line.to_string());
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

fn summarize(xs: &[f64]) -> HistogramSummary {
    HistogramSummary {
        count: xs.len(),
        mean: stats::mean(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        p50: stats::percentile(xs, 50.0),
        p90: stats::percentile(xs, 90.0),
        p99: stats::percentile(xs, 99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_clones_share() {
        let reg = MetricsRegistry::new();
        let other = reg.clone();
        reg.counter_add("a", 2);
        other.counter_add("a", 3);
        assert_eq!(reg.counter("a"), 5);
        assert_eq!(reg.counter("never"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let reg = MetricsRegistry::new();
        reg.gauge_set("g", 1.0);
        reg.gauge_set("g", 2.5);
        assert_eq!(reg.gauge("g"), Some(2.5));
        assert_eq!(reg.gauge("missing"), None);
    }

    #[test]
    fn histogram_percentiles_match_brute_force_oracle() {
        let reg = MetricsRegistry::new();
        // Deterministic pseudo-random samples (LCG), deliberately unsorted.
        let mut x = 12345u64;
        let mut raw = Vec::new();
        for _ in 0..257 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (x >> 33) as f64 / 1e6;
            raw.push(v);
            reg.observe("h", v);
        }
        let s = reg.histogram("h").unwrap();
        assert_eq!(s.count, raw.len());
        // Brute-force oracle: sort and index by nearest rank, independent
        // of util::stats.
        let mut sorted = raw.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let oracle = |p: f64| {
            let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
            sorted[rank.min(sorted.len() - 1)]
        };
        assert_eq!(s.p50, oracle(50.0));
        assert_eq!(s.p90, oracle(90.0));
        assert_eq!(s.p99, oracle(99.0));
        assert_eq!(s.min, sorted[0]);
        assert_eq!(s.max, *sorted.last().unwrap());
        let mean = raw.iter().sum::<f64>() / raw.len() as f64;
        assert!((s.mean - mean).abs() < 1e-12);
    }

    #[test]
    fn snapshot_lines_are_valid_json_with_schema() {
        let reg = MetricsRegistry::new();
        reg.counter_add("steps", 4);
        reg.gauge_set("loss", 0.5);
        reg.observe("lat", 1.0);
        reg.observe("lat", 3.0);
        let lines = reg.snapshot_lines();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let parsed = Json::parse(&line.to_string()).unwrap();
            assert!(parsed.get("kind").and_then(Json::as_str).is_some());
            assert!(parsed.get("name").and_then(Json::as_str).is_some());
        }
        let hist = &lines[2];
        assert_eq!(hist.get("kind").unwrap().as_str(), Some("histogram"));
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(hist.get("max").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn export_jsonl_writes_one_line_per_metric() {
        let dir = std::env::temp_dir().join("mesp-obs-test-jsonl");
        let path = dir.join("m.jsonl");
        let reg = MetricsRegistry::new();
        reg.counter_add("c", 1);
        reg.gauge_set("g", 2.0);
        reg.export_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            Json::parse(l).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
