//! Structured trace spans/events with a Chrome `trace_event` exporter.
//!
//! The sink is a cheap handle: cloning shares one buffer, and the *disabled*
//! sink (the default everywhere) carries `None` so every instrumentation
//! point costs a single branch. Spans are RAII — [`TraceSink::span`] returns
//! a [`Span`] guard that records a Chrome `"X"` (complete) event when it
//! drops, which makes per-thread nesting well-formed by construction.
//! Lifecycle moments (fleet admit/park/resume, arena checkout) are recorded
//! as `"i"` (instant) events.
//!
//! Timestamps are microseconds from a monotonic clock anchored at sink
//! creation; thread ids come from a process-local sequential counter so the
//! export is stable-looking in Perfetto (std's `ThreadId` has no stable
//! integer accessor). Telemetry is observe-only: nothing in this module
//! feeds back into training, so traced and untraced runs stay bitwise
//! identical.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Process-local sequential thread ids (Chrome traces want small ints).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// One recorded event. `ph` is the Chrome trace-event phase: `'X'` for a
/// complete span (with duration), `'i'` for an instant.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub ph: char,
    /// Microseconds since the sink was created.
    pub ts_us: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: u64,
    pub tid: u64,
    /// Fleet job id, when the event was emitted through a job-scoped handle.
    pub job: Option<u64>,
    pub args: Vec<(&'static str, Json)>,
}

#[derive(Debug)]
struct SinkInner {
    start: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

/// Shared, thread-safe event sink. The default (disabled) sink records
/// nothing and costs one branch per instrumentation point.
#[derive(Debug, Clone, Default)]
pub struct TraceSink {
    inner: Option<Arc<SinkInner>>,
    job: Option<u64>,
}

impl TraceSink {
    /// A sink that drops everything — the zero-cost default.
    pub fn disabled() -> TraceSink {
        TraceSink::default()
    }

    /// A recording sink; timestamps are relative to this call.
    pub fn enabled() -> TraceSink {
        TraceSink {
            inner: Some(Arc::new(SinkInner {
                start: Instant::now(),
                events: Mutex::new(Vec::new()),
            })),
            job: None,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// A handle that tags every event with a fleet job id (shares the
    /// same underlying buffer).
    pub fn for_job(&self, job: u64) -> TraceSink {
        TraceSink {
            inner: self.inner.clone(),
            job: Some(job),
        }
    }

    /// Open a span; the returned guard records an `"X"` event on drop.
    pub fn span(&self, name: impl Into<String>, cat: &'static str) -> Span {
        match &self.inner {
            None => Span { rec: None },
            Some(inner) => Span {
                rec: Some(SpanRec {
                    inner: Arc::clone(inner),
                    name: name.into(),
                    cat,
                    started: Instant::now(),
                    ts_us: inner.start.elapsed().as_micros() as u64,
                    job: self.job,
                    args: Vec::new(),
                }),
            },
        }
    }

    /// Convenience: a `"gemm"` span carrying shape + FLOP args plus the
    /// micro-kernel ISA and `(mc, kc, nc)` blocking-tile tags. Kept as a
    /// method so kernel call sites stay one line.
    pub fn gemm(
        &self,
        name: &'static str,
        m: usize,
        k: usize,
        n: usize,
        isa: &'static str,
        tiles: (usize, usize, usize),
    ) -> Span {
        if self.inner.is_none() {
            return Span { rec: None };
        }
        let mut sp = self.span(name, "gemm");
        sp.arg("m", Json::Num(m as f64));
        sp.arg("k", Json::Num(k as f64));
        sp.arg("n", Json::Num(n as f64));
        sp.arg("flops", Json::Num(2.0 * m as f64 * k as f64 * n as f64));
        sp.arg("isa", Json::str(isa));
        sp.arg("tiles", Json::str(format!("{}x{}x{}", tiles.0, tiles.1, tiles.2)));
        sp
    }

    /// Record an instant (`"i"`) event.
    pub fn instant(&self, name: impl Into<String>, cat: &'static str, args: Vec<(&'static str, Json)>) {
        let Some(inner) = &self.inner else { return };
        let ev = TraceEvent {
            name: name.into(),
            cat,
            ph: 'i',
            ts_us: inner.start.elapsed().as_micros() as u64,
            dur_us: 0,
            tid: current_tid(),
            job: self.job,
            args,
        };
        inner.events.lock().unwrap().push(ev);
    }

    /// Snapshot of all recorded events (test/inspection helper).
    pub fn events(&self) -> Vec<TraceEvent> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => inner.events.lock().unwrap().clone(),
        }
    }

    /// Chrome trace-event JSON (`{"traceEvents": [...]}`), loadable in
    /// Perfetto / `chrome://tracing`.
    pub fn to_chrome_json(&self) -> Json {
        let events = self.events();
        let mut arr = Vec::with_capacity(events.len());
        for ev in &events {
            let mut pairs = vec![
                ("name", Json::str(ev.name.clone())),
                ("cat", Json::str(ev.cat)),
                ("ph", Json::str(ev.ph.to_string())),
                ("ts", Json::Num(ev.ts_us as f64)),
                ("pid", Json::num(1u32)),
                ("tid", Json::Num(ev.tid as f64)),
            ];
            if ev.ph == 'X' {
                pairs.push(("dur", Json::Num(ev.dur_us as f64)));
            }
            if ev.ph == 'i' {
                // Thread-scoped instants render as small arrows in Perfetto.
                pairs.push(("s", Json::str("t")));
            }
            let mut args = ev.args.clone();
            if let Some(job) = ev.job {
                args.push(("job", Json::Num(job as f64)));
            }
            if !args.is_empty() {
                pairs.push(("args", Json::obj(args)));
            }
            arr.push(Json::obj(pairs));
        }
        Json::obj(vec![("traceEvents", Json::Arr(arr))])
    }

    /// Write the Chrome trace to `path`.
    pub fn export_chrome(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_chrome_json().to_string())?;
        Ok(())
    }
}

struct SpanRec {
    inner: Arc<SinkInner>,
    name: String,
    cat: &'static str,
    started: Instant,
    ts_us: u64,
    job: Option<u64>,
    args: Vec<(&'static str, Json)>,
}

/// RAII span guard; records a complete event on drop. The disabled-path
/// guard is a `None` and drops for free.
pub struct Span {
    rec: Option<SpanRec>,
}

impl Span {
    /// Attach an argument after the span has opened (e.g. a FLOP delta
    /// only known once the work ran).
    pub fn arg(&mut self, key: &'static str, val: Json) {
        if let Some(rec) = &mut self.rec {
            rec.args.push((key, val));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(rec) = self.rec.take() else { return };
        let ev = TraceEvent {
            name: rec.name,
            cat: rec.cat,
            ph: 'X',
            ts_us: rec.ts_us,
            dur_us: rec.started.elapsed().as_micros() as u64,
            tid: current_tid(),
            job: rec.job,
            args: rec.args,
        };
        rec.inner.events.lock().unwrap().push(ev);
    }
}

impl std::fmt::Debug for SpanRec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpanRec")
            .field("name", &self.name)
            .field("cat", &self.cat)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing() {
        let sink = TraceSink::disabled();
        {
            let mut sp = sink.span("x", "test");
            sp.arg("k", Json::num(1u32));
        }
        sink.instant("i", "test", vec![]);
        assert!(!sink.is_enabled());
        assert!(sink.events().is_empty());
    }

    #[test]
    fn spans_nest_well_formed_per_thread() {
        let sink = TraceSink::enabled();
        {
            let _outer = sink.span("outer", "test");
            {
                let _inner = sink.span("inner", "test");
            }
            let _sibling = sink.span("sibling", "test");
        }
        let events = sink.events();
        assert_eq!(events.len(), 3);
        // Inner spans close first, so they appear first in the buffer.
        assert_eq!(events[0].name, "inner");
        // Every pair of spans on one thread must be disjoint or nested —
        // never partially overlapping.
        for a in &events {
            for b in &events {
                if a.tid != b.tid {
                    continue;
                }
                let (a0, a1) = (a.ts_us, a.ts_us + a.dur_us);
                let (b0, b1) = (b.ts_us, b.ts_us + b.dur_us);
                let disjoint = a1 <= b0 || b1 <= a0;
                let nested = (a0 >= b0 && a1 <= b1) || (b0 >= a0 && b1 <= a1);
                assert!(disjoint || nested, "partial overlap: {a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn threads_get_distinct_tids() {
        let sink = TraceSink::enabled();
        let s2 = sink.clone();
        let h = std::thread::spawn(move || {
            let _sp = s2.span("worker", "test");
        });
        h.join().unwrap();
        let _sp = sink.span("main", "test");
        drop(_sp);
        let events = sink.events();
        assert_eq!(events.len(), 2);
        assert_ne!(events[0].tid, events[1].tid);
    }

    #[test]
    fn chrome_export_round_trips_through_parser() {
        let sink = TraceSink::enabled();
        {
            let mut sp = sink.span("gemm", "kernel");
            sp.arg("m", Json::num(4u32));
        }
        sink.for_job(7).instant("admit", "fleet", vec![("bytes", Json::num(9u32))]);
        let text = sink.to_chrome_json().to_string();
        let parsed = Json::parse(&text).expect("valid JSON");
        let evs = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        for ev in evs {
            assert!(ev.get("name").and_then(Json::as_str).is_some());
            assert!(ev.get("ph").and_then(Json::as_str).is_some());
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("tid").and_then(Json::as_f64).is_some());
        }
        let admit = &evs[1];
        assert_eq!(admit.get("ph").unwrap().as_str(), Some("i"));
        let job = admit.req("args").unwrap().req("job").unwrap().as_f64();
        assert_eq!(job, Some(7.0));
    }

    #[test]
    fn gemm_span_carries_shape_and_flops() {
        let sink = TraceSink::enabled();
        drop(sink.gemm("matmul", 2, 3, 4, "avx2", (64, 256, 128)));
        let ev = &sink.events()[0];
        assert_eq!(ev.cat, "gemm");
        let arg = |key: &str| ev.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v.clone());
        assert_eq!(arg("flops").and_then(|v| v.as_f64()), Some(48.0));
        assert_eq!(arg("isa").as_ref().and_then(Json::as_str), Some("avx2"));
        assert_eq!(arg("tiles").as_ref().and_then(Json::as_str), Some("64x256x128"));
    }
}
