//! Observability: structured tracing, a metrics registry, and the views
//! that render them.
//!
//! The paper's claims are about *where* bytes and milliseconds go; this
//! module makes those visible without perturbing the run. Three pieces:
//!
//! - [`TraceSink`] — structured spans and instant events (monotonic
//!   timestamps, thread id, optional fleet job id) emitted from engine
//!   steps (`fwd`/`bwd`/`opt` phases), refmath artifact calls, per-GEMM
//!   kernel dispatch, arena scratch traffic, and fleet lifecycle
//!   (admit/park/resume/done). Exports Chrome `trace_event` JSON that
//!   opens directly in Perfetto (`--trace <path>`).
//! - [`MetricsRegistry`] — named counters/gauges/histograms (step
//!   latency, achieved GFLOP/s, bytes-by-tag, admission wait, preempt
//!   churn) with a JSONL snapshot export (`--metrics-out <path>`).
//!   Percentiles are nearest-rank via `util::stats::percentile`.
//! - [`views`] — the CLI tables (`exec_stats_table`,
//!   `FleetReport::render`) re-expressed as reads over the registry.
//!
//! Everything is observe-only and zero-dependency. The disabled trace
//! sink is a `None` behind one branch, and the registry is touched only
//! at step granularity, so instrumented and uninstrumented runs produce
//! bitwise-identical losses and adapter weights — pinned by tests.

mod metrics;
mod trace;
pub mod views;

pub use metrics::{HistogramSummary, MetricsRegistry};
pub use trace::{Span, TraceEvent, TraceSink};
