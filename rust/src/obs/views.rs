//! Views: rendering registry contents back into the tables the CLI prints.
//!
//! `exec_stats_table` / `FleetReport::render` used to aggregate ad-hoc;
//! they now load their numbers into a [`MetricsRegistry`] and render from
//! it, so the printed tables and the `--metrics-out` JSONL export are two
//! views over the same store and cannot drift.

use super::MetricsRegistry;
use crate::runtime::ExecStats;

/// Load per-artifact execution stats into the registry under
/// `artifact/<name>/{calls,total_secs,flops}`.
pub fn exec_stats_into(reg: &MetricsRegistry, stats: &[(String, ExecStats)]) {
    for (name, s) in stats {
        reg.counter_add(&format!("artifact/{name}/calls"), s.calls);
        reg.gauge_set(&format!("artifact/{name}/total_secs"), s.total_secs);
        reg.gauge_set(&format!("artifact/{name}/flops"), s.flops as f64);
    }
}

/// Render the per-artifact table (slowest first) from registry contents.
/// Layout matches the historical `exec_stats_table` exactly.
pub fn render_exec_stats(reg: &MetricsRegistry) -> String {
    let mut names: Vec<(String, f64)> = reg
        .gauges_with_prefix("artifact/")
        .into_iter()
        .filter_map(|(k, v)| {
            let name = k.strip_prefix("artifact/")?.strip_suffix("/total_secs")?;
            Some((name.to_string(), v))
        })
        .collect();
    names.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));

    let mut t = crate::metrics::TableBuilder::new(&[
        "Artifact", "Calls", "Total s", "ms/call", "GFLOP", "GFLOP/s",
    ]);
    for (name, total_secs) in names {
        let calls = reg.counter(&format!("artifact/{name}/calls"));
        let flops = reg
            .gauge(&format!("artifact/{name}/flops"))
            .unwrap_or(0.0);
        let ms_per_call = if calls > 0 {
            total_secs * 1e3 / calls as f64
        } else {
            0.0
        };
        let gflops_per_sec = if total_secs > 0.0 {
            flops / 1e9 / total_secs
        } else {
            0.0
        };
        t.row(vec![
            name,
            calls.to_string(),
            format!("{total_secs:.3}"),
            format!("{ms_per_call:.3}"),
            format!("{:.3}", flops / 1e9),
            format!("{gflops_per_sec:.2}"),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_stats_view_orders_slowest_first() {
        let reg = MetricsRegistry::new();
        exec_stats_into(
            &reg,
            &[
                ("fast".to_string(), ExecStats { calls: 1, total_secs: 0.1, flops: 1_000_000 }),
                ("slow".to_string(), ExecStats { calls: 2, total_secs: 3.0, flops: 6_000_000_000 }),
            ],
        );
        let s = render_exec_stats(&reg);
        let slow_at = s.find("slow").unwrap();
        let fast_at = s.find("fast").unwrap();
        assert!(slow_at < fast_at, "{s}");
        assert_eq!(reg.counter("artifact/slow/calls"), 2);
    }
}
