//! The compute-backend abstraction: everything above this line (engines,
//! coordinator, CLI, reproduce drivers) talks to a [`Backend`] trait
//! object and never to a concrete runtime.
//!
//! A backend exposes the paper's artifact surface by NAME — `embed_fwd`,
//! `block_fwd`, `block_bwd_mesp`, `lm_loss_grad`, … — with positional
//! arguments in the manifest ABI order. Two implementations exist:
//!
//! * [`crate::runtime::ReferenceBackend`] — pure Rust, in-process, no
//!   external toolchain; the default.
//! * [`crate::runtime::Runtime`] — the PJRT client over AOT-compiled HLO
//!   artifacts (cargo feature `pjrt`).

use crate::config::ModelDims;
use crate::memory::MemoryTracker;
use crate::tensor::HostTensor;

/// Cumulative per-artifact execution statistics (perf §L3).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    /// Nominal FLOPs across all calls: the reference backend counts live
    /// (GEMMs at `2·m·k·n` plus attention products); the PJRT runtime
    /// cannot see inside compiled executables and records the matching
    /// analytical inventory (`kernels::flops::artifact`) instead.
    pub flops: u64,
}

impl ExecStats {
    /// Achieved throughput in GFLOP/s (0.0 when nothing was counted).
    pub fn gflops_per_sec(&self) -> f64 {
        if self.total_secs > 0.0 {
            self.flops as f64 / self.total_secs / 1e9
        } else {
            0.0
        }
    }
}

/// Shared per-artifact stats bookkeeping both backends use.
#[derive(Debug, Default)]
pub struct StatsRecorder {
    inner: std::sync::Mutex<std::collections::HashMap<String, ExecStats>>,
}

impl StatsRecorder {
    pub fn new() -> StatsRecorder {
        StatsRecorder::default()
    }

    /// Record one call of `name` taking `secs` and executing `flops`.
    pub fn record(&self, name: &str, secs: f64, flops: u64) {
        let mut stats = self.inner.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += secs;
        e.flops += flops;
    }

    /// Snapshot, slowest artifact first.
    pub fn snapshot(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }
}

/// A backend-resident buffer: weights uploaded once and reused across
/// every call (the paper-equivalent of keeping frozen base weights
/// resident while only LoRA params move).
pub enum DeviceBuffer {
    /// The reference backend's "device" is host memory: a resident copy.
    Resident(HostTensor),
    /// A PJRT device buffer (CPU platform: device memory IS host memory).
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

/// An argument to [`Backend::execute`]: a host tensor uploaded for the
/// duration of the call, a persistent buffer from [`Backend::upload`],
/// or a borrow of process-resident shared weights.
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Device(&'a DeviceBuffer),
    /// A host tensor that is already resident for the session's lifetime
    /// (e.g. a [`crate::model::FrozenModel`] tensor shared across
    /// sessions). Validated like `Host`, but its bytes are NOT charged to
    /// the per-call `exec:<name>` tag: they are accounted once, at the
    /// owner (`weights:shared`), not per call per session. Only
    /// meaningful on backends where [`Backend::shares_host_memory`] is
    /// true; upload-based backends never see this variant.
    Resident(&'a HostTensor),
}

/// A compute backend serving the artifact surface.
///
/// Contract (every implementation must honour all four):
///
/// 1. **ABI** — `execute(name, args)` takes positional args in manifest
///    order and returns the artifact's output tuple in declared order;
///    arg count, shapes and dtypes of host args are validated against the
///    artifact spec before any compute runs.
/// 2. **Gradient parity** — `block_bwd_mesp`, `block_bwd_storeh` and the
///    `block_fwd_residuals`/`block_bwd_residuals` pair must produce
///    mathematically identical gradients for identical inputs (the paper's
///    §4 claim); tests/gradcheck.rs enforces this per backend.
/// 3. **Memory accounting** — transient host-arg bytes of every call are
///    registered with the shared [`MemoryTracker`] under `exec:<name>` for
///    the duration of the call, so step peaks include call overhead.
///    [`Arg::Resident`] borrows are exempt: they reference weights whose
///    bytes are already accounted at their owner (`weights:shared`), so
///    charging them per call would double-count shared state.
/// 4. **Statelessness** — backends hold no model state between calls
///    beyond buffers explicitly created via `upload`; all training state
///    lives in the engines.
pub trait Backend: Send + Sync {
    /// Human-readable backend name ("reference", "pjrt").
    fn kind(&self) -> &'static str;

    /// Model dimensions this backend was instantiated for.
    fn dims(&self) -> &ModelDims;

    /// The shared memory tracker call overhead is accounted against.
    fn tracker(&self) -> &MemoryTracker;

    /// Whether artifact `name` is available on this backend.
    fn has_artifact(&self, name: &str) -> bool;

    /// Prepare a set of artifacts (compile executables, etc.) so step
    /// timing excludes one-time setup. Unknown names are skipped.
    fn warmup(&self, names: &[&str]) -> anyhow::Result<()>;

    /// Upload a host tensor to a persistent backend-resident buffer.
    fn upload(&self, t: &HostTensor) -> anyhow::Result<DeviceBuffer>;

    /// Whether this backend computes directly on host memory, so
    /// session-lifetime host tensors (shared frozen weights) can be
    /// passed as [`Arg::Resident`] borrows instead of uploaded. Backends
    /// with a real device transfer (PJRT) return false and receive
    /// per-session `upload`s.
    fn shares_host_memory(&self) -> bool {
        false
    }

    /// Execute artifact `name` with positional `args`; returns the output
    /// tuple as host tensors in artifact output order.
    fn execute(&self, name: &str, args: &[Arg]) -> anyhow::Result<Vec<HostTensor>>;

    /// Snapshot of per-artifact execution stats, slowest first.
    fn exec_stats(&self) -> Vec<(String, ExecStats)>;
}
