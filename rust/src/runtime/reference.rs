//! The pure-Rust reference backend: implements the full artifact surface
//! in-process — `embed_fwd`, the three block-forward variants, the three
//! block-backward variants (MeSP fused recompute, store-h, MeBP
//! residuals), both loss heads, and the int4 `_q4` twin of every block
//! artifact (forwards AND backwards over packed base weights, paper
//! §4.5) — with no XLA toolchain, no Python artifacts and no files on
//! disk.
//!
//! Arguments are validated against programmatically generated
//! [`ArtifactSpec`]s that mirror what `python/compile/aot.py` writes into
//! `manifest.json`, so the ABI contract is enforced identically on both
//! backends. All math lives in [`super::refmath`]; the MeSP / store-h /
//! residual backward variants share one implementation of the paper's
//! Appendix-A VJPs and therefore return bitwise identical gradients.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::config::{ModelDims, FROZEN, PROJS};
use crate::memory::MemoryTracker;
use crate::model::quant;
use crate::obs::TraceSink;
use crate::runtime::backend::{Arg, Backend, DeviceBuffer, ExecStats, StatsRecorder};
use crate::runtime::kernels::{FrozenW, Kernels, KernelOptions, Q4View};
use crate::runtime::manifest::{ArgSpec, ArtifactSpec};
use crate::runtime::refmath as rm;
use crate::tensor::{DType, HostTensor, ScratchBuf};
use crate::util::json::Json;

/// Residual-set tensor names emitted by `block_fwd_residuals` (after y) —
/// must match `python/compile/model.py::RESIDUALS`.
pub const RESIDUALS: [&str; 19] = [
    "x", "h1", "h2", "x2", "q_rope", "k_rope", "v_heads", "probs",
    "attn_flat", "gate_out", "up_out", "silu_out",
    "h_q", "h_k", "h_v", "h_o", "h_gate", "h_up", "h_down",
];

/// The seven quantized projection matrices of the q4 path, ABI order
/// (canonical definition in `config`; re-exported here for the callers
/// that grew up next to the q4 artifacts).
pub use crate::config::QUANT_MATS;

pub struct ReferenceBackend {
    /// Shared, not cloned: sessions built from a cached
    /// [`crate::model::FrozenModel`] hand the cache's interned
    /// `Arc<ModelDims>` straight through, so N same-base sessions hold
    /// one dims allocation.
    dims: Arc<ModelDims>,
    specs: Vec<ArtifactSpec>,
    tracker: MemoryTracker,
    stats: StatsRecorder,
    kernels: Kernels,
    /// Artifact-call spans; disabled by default (one branch per call).
    trace: TraceSink,
    /// Loss-head tile rows (`--loss-chunk`): 0 = unchunked oracle,
    /// otherwise `lm_loss_fwd`/`lm_loss_grad` stream the sequence in
    /// tiles of this many rows (bitwise-identical result, only
    /// `chunk × vocab` logits floats live at once).
    loss_chunk: usize,
}

impl ReferenceBackend {
    /// Backend with the default kernel engine (`parallel`, auto threads).
    pub fn new(
        dims: impl Into<Arc<ModelDims>>,
        tracker: MemoryTracker,
    ) -> ReferenceBackend {
        Self::with_kernels(dims, tracker, KernelOptions::default())
    }

    /// Backend with an explicit kernel selection (`--kernel`/`--threads`;
    /// the fleet scheduler passes its per-worker thread budget here).
    pub fn with_kernels(
        dims: impl Into<Arc<ModelDims>>,
        tracker: MemoryTracker,
        opts: KernelOptions,
    ) -> ReferenceBackend {
        Self::with_telemetry(dims, tracker, opts, TraceSink::disabled())
    }

    /// Backend with an explicit kernel selection AND a trace sink: every
    /// artifact call becomes a span (cat `artifact`, FLOP + input-byte
    /// args) and the sink is threaded into the kernel engine so per-GEMM
    /// spans and arena instants nest inside it.
    pub fn with_telemetry(
        dims: impl Into<Arc<ModelDims>>,
        tracker: MemoryTracker,
        opts: KernelOptions,
        trace: TraceSink,
    ) -> ReferenceBackend {
        let dims = dims.into();
        let specs = build_specs(&dims);
        let kernels = Kernels::new(opts, tracker.clone()).with_trace(trace.clone());
        ReferenceBackend {
            dims,
            specs,
            tracker,
            stats: StatsRecorder::new(),
            kernels,
            trace,
            loss_chunk: 0,
        }
    }

    /// Route the loss head through the chunked implementation (`0`
    /// keeps the unchunked oracle). See [`rm::lm_loss_grad_chunked`].
    pub fn with_loss_chunk(mut self, chunk: usize) -> ReferenceBackend {
        self.loss_chunk = chunk;
        self
    }

    /// The kernel engine (kind, thread budget, arena stats, FLOP counter).
    pub fn kernels(&self) -> &Kernels {
        &self.kernels
    }

    /// The synthesized artifact specs (what `mesp inspect` lists).
    pub fn artifact_specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    fn spec(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.specs.iter().find(|s| s.name == name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not implemented by the reference backend \
                 (have: {})",
                self.specs.iter().map(|s| s.name.as_str())
                    .collect::<Vec<_>>().join(", ")
            )
        })
    }

    fn dispatch(&self, name: &str, t: &[&HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let d = &self.dims;
        let ks = &self.kernels;
        let (b, n, dm) = (d.batch, d.seq, d.d_model);
        let m = b * n;
        let r = d.rank;
        let bnd = [b, n, dm];
        let slices = |ts: &[&HostTensor]| -> Vec<&[f32]> { ts.iter().map(|t| t.as_f32()).collect() };
        // The `_q4` artifact variants share the f32 block arms: strip the
        // suffix and swap the frozen-weight views. f32 ABI: 9 frozen
        // tensors in FROZEN order. q4 ABI: ln1, ln2, then (packed u8,
        // scales f32) per QUANT_MATS — the projections stay int4-packed
        // all the way into the GEMM packing step.
        let (base, q4) = match name.strip_suffix("_q4") {
            Some(stripped) => (stripped, true),
            None => (name, false),
        };
        let nf = if q4 { 2 + 2 * QUANT_MATS.len() } else { FROZEN.len() };
        // Frozen views + LoRA slices for a block artifact whose leading
        // args end at offset `off` (ABI order after the leads).
        let frozen_at = |off: usize| frozen_views(d, t, off, q4, nf);
        let lora_at =
            |off: usize| -> Vec<&[f32]> { slices(&t[off + nf..off + nf + 2 * PROJS.len()]) };
        // Backward outputs escape the arena: detach each scratch buffer
        // into a HostTensor (the caller re-tracks the bytes as its own).
        let grad_tensors = |g_x: ScratchBuf, grads: Vec<ScratchBuf>| -> Vec<HostTensor> {
            let mut out = Vec::with_capacity(1 + grads.len());
            out.push(HostTensor::f32(&bnd, g_x.into_vec()));
            for (i, gv) in grads.into_iter().enumerate() {
                let (din, dout) = d.proj_dims(PROJS[i / 2]);
                let shape = if i % 2 == 0 { vec![din, r] } else { vec![r, dout] };
                out.push(HostTensor::f32(&shape, gv.into_vec()));
            }
            out
        };

        Ok(match base {
            "embed_fwd" => {
                let out = rm::embed_fwd(t[0].as_i32(), t[1].as_f32(), dm)?;
                vec![HostTensor::f32(&bnd, out)]
            }
            "block_fwd" => {
                let y = rm::block_forward_inference(
                    ks, d, t[0].as_f32(), &frozen_at(1), &lora_at(1),
                );
                vec![HostTensor::f32(&bnd, y.into_vec())]
            }
            "block_fwd_saveh" => {
                let c = rm::block_forward(
                    ks, d, t[0].as_f32(), &frozen_at(1), &lora_at(1),
                );
                let mut out = vec![HostTensor::f32(&bnd, c.y.into_vec())];
                for h in c.hs {
                    out.push(HostTensor::f32(&[m, r], h.into_vec()));
                }
                out
            }
            "block_fwd_residuals" => {
                let c = rm::block_forward(
                    ks, d, t[0].as_f32(), &frozen_at(1), &lora_at(1),
                );
                let residuals: Vec<HostTensor> = residual_shapes(d)
                    .into_iter()
                    .map(|(rname, shape)| {
                        HostTensor::f32(&shape, residual_of(&c, rname).to_vec())
                    })
                    .collect();
                let mut out = vec![HostTensor::f32(&bnd, c.y.into_vec())];
                out.extend(residuals);
                out
            }
            "block_bwd_mesp" => {
                // THE paper's contribution path: recompute the minimal
                // intermediate set (h = xA included) inside this one call.
                let frozen = frozen_at(2);
                let lora = lora_at(2);
                let c = rm::block_forward(ks, d, t[0].as_f32(), &frozen, &lora);
                let src = rm::BwdSource::Owned(Box::new(c));
                let (g_x, grads) = rm::block_backward(
                    ks, d, t[1].as_f32(), src, &frozen, &lora, None,
                );
                grad_tensors(g_x, grads)
            }
            "block_bwd_storeh" => {
                // Table-5 ablation: identical math, dB consumes stored h.
                let frozen = frozen_at(9);
                let lora = lora_at(9);
                let c = rm::block_forward(ks, d, t[0].as_f32(), &frozen, &lora);
                let hs = slices(&t[2..9]);
                let src = rm::BwdSource::Owned(Box::new(c));
                let (g_x, grads) = rm::block_backward(
                    ks, d, t[1].as_f32(), src, &frozen, &lora, Some(&hs),
                );
                grad_tensors(g_x, grads)
            }
            "block_bwd_residuals" => {
                // MeBP backward half: every intermediate comes from the
                // host-held residual set — no recompute in this call.
                let res = &t[1..20];
                let frozen = frozen_at(20);
                let lora = lora_at(20);
                let ctx = rm::BwdCtx {
                    x2d: res[0].as_f32(),
                    h1: res[1].as_f32(),
                    h2: res[2].as_f32(),
                    x2: res[3].as_f32(),
                    q_rope: res[4].as_f32(),
                    k_rope: res[5].as_f32(),
                    v_heads: res[6].as_f32(),
                    probs: res[7].as_f32(),
                    attn_flat: res[8].as_f32(),
                    gate_out: res[9].as_f32(),
                    up_out: res[10].as_f32(),
                    silu_out: res[11].as_f32(),
                };
                let hs: Vec<&[f32]> = res[12..19].iter().map(|t| t.as_f32()).collect();
                let src = rm::BwdSource::Borrowed(ctx);
                let (g_x, grads) = rm::block_backward(
                    ks, d, t[0].as_f32(), src, &frozen, &lora, Some(&hs),
                );
                grad_tensors(g_x, grads)
            }
            "lm_loss_fwd" => {
                let loss = match self.loss_chunk {
                    0 => rm::lm_loss(
                        ks, t[0].as_f32(), t[1].as_f32(), t[2].as_f32(),
                        t[3].as_i32(), m, dm, d.vocab,
                    )?,
                    c => rm::lm_loss_chunked(
                        ks, t[0].as_f32(), t[1].as_f32(), t[2].as_f32(),
                        t[3].as_i32(), m, dm, d.vocab, c,
                    )?,
                };
                vec![HostTensor::f32(&[1], vec![loss as f32])]
            }
            "lm_loss_grad" => {
                let (loss, g_h) = match self.loss_chunk {
                    0 => rm::lm_loss_grad(
                        ks, t[0].as_f32(), t[1].as_f32(), t[2].as_f32(),
                        t[3].as_i32(), m, dm, d.vocab,
                    )?,
                    c => rm::lm_loss_grad_chunked(
                        ks, t[0].as_f32(), t[1].as_f32(), t[2].as_f32(),
                        t[3].as_i32(), m, dm, d.vocab, c,
                    )?,
                };
                vec![
                    HostTensor::f32(&[1], vec![loss as f32]),
                    HostTensor::f32(&bnd, g_h.into_vec()),
                ]
            }
            other => anyhow::bail!("reference backend: unknown artifact '{other}'"),
        })
    }
}

/// The frozen-weight views of one block call: the `nf` tensors starting
/// at arg offset `off`, as f32 slices (f32 ABI) or packed views (q4
/// ABI).
fn frozen_views<'a>(
    d: &ModelDims,
    t: &[&'a HostTensor],
    off: usize,
    q4: bool,
    nf: usize,
) -> Vec<FrozenW<'a>> {
    if q4 {
        q4_frozen(d, t[off].as_f32(), t[off + 1].as_f32(), &t[off + 2..off + nf])
    } else {
        t[off..off + nf]
            .iter()
            .map(|ht| FrozenW::F32(ht.as_f32()))
            .collect()
    }
}

/// Frozen views of one q4 block call: norm gains f32, the seven
/// projections as packed [`Q4View`]s (FROZEN order). The f32 matrices
/// are never materialized here — dequantization happens panel-by-panel
/// inside the GEMM kernels (the naive oracle being the one exception).
fn q4_frozen<'a>(
    d: &ModelDims,
    ln1: &'a [f32],
    ln2: &'a [f32],
    qts: &[&'a HostTensor],
) -> Vec<FrozenW<'a>> {
    debug_assert_eq!(qts.len(), 2 * QUANT_MATS.len());
    let q = |i: usize| -> FrozenW<'a> {
        let shape = d.frozen_shape(QUANT_MATS[i]);
        FrozenW::Q4(Q4View::new(
            qts[2 * i].as_u8(),
            qts[2 * i + 1].as_f32(),
            shape[0],
            shape[1],
        ))
    };
    vec![
        FrozenW::F32(ln1), q(0), q(1), q(2), q(3),
        FrozenW::F32(ln2), q(4), q(5), q(6),
    ]
}

impl Backend for ReferenceBackend {
    fn kind(&self) -> &'static str {
        "reference"
    }

    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn tracker(&self) -> &MemoryTracker {
        &self.tracker
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.specs.iter().any(|s| s.name == name)
    }

    fn warmup(&self, _names: &[&str]) -> anyhow::Result<()> {
        Ok(()) // nothing to compile in-process
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<DeviceBuffer> {
        // "Device" memory IS host memory here: keep a resident copy so the
        // caller can free (or mutate) its own, exactly like a PJRT upload.
        Ok(DeviceBuffer::Resident(t.clone()))
    }

    fn shares_host_memory(&self) -> bool {
        true // shared frozen weights ride along as `Arg::Resident` borrows
    }

    fn execute(&self, name: &str, args: &[Arg]) -> anyhow::Result<Vec<HostTensor>> {
        let spec = self.spec(name)?;
        anyhow::ensure!(
            spec.args.len() == args.len(),
            "{name}: expected {} args, got {}",
            spec.args.len(),
            args.len()
        );
        let mut tensors: Vec<&HostTensor> = Vec::with_capacity(args.len());
        let mut in_bytes = 0u64;
        for (a, arg) in spec.args.iter().zip(args) {
            let t = match arg {
                Arg::Host(t) => {
                    in_bytes += t.bytes();
                    *t
                }
                // Session-lifetime shared weights: validated like a host
                // arg, but accounted once at the owner (`weights:shared`),
                // never per call (contract point 3).
                Arg::Resident(t) => *t,
                Arg::Device(DeviceBuffer::Resident(t)) => t,
                #[cfg(feature = "pjrt")]
                Arg::Device(DeviceBuffer::Pjrt(_)) => anyhow::bail!(
                    "{name}: PJRT device buffer passed to the reference backend"
                ),
            };
            anyhow::ensure!(
                a.shape == t.shape && a.dtype == t.dtype(),
                "{name}: arg '{}' shape/dtype {:?}/{:?} != expected {:?}/{:?}",
                a.name, t.shape, t.dtype(), a.shape, a.dtype
            );
            tensors.push(t);
        }
        // Transient call I/O is tracked for the duration of the call, the
        // same accounting discipline as the PJRT runtime.
        let _io_guard = self.tracker.track(&format!("exec:{name}"), in_bytes);

        // Calls of one session are serial (the engine drives them), so the
        // kernel-engine FLOP counter delta brackets exactly this call.
        let flops0 = self.kernels.flops();
        let start = Instant::now();
        let mut sp = self.trace.span(name, "artifact");
        let outputs = self.dispatch(name, &tensors)?;
        let flops = self.kernels.flops() - flops0;
        sp.arg("flops", Json::Num(flops as f64));
        sp.arg("in_bytes", Json::Num(in_bytes as f64));
        drop(sp);
        anyhow::ensure!(
            outputs.len() == spec.outputs,
            "{name}: spec promises {} outputs, got {}",
            spec.outputs,
            outputs.len()
        );
        self.stats.record(name, start.elapsed().as_secs_f64(), flops);
        Ok(outputs)
    }

    fn exec_stats(&self) -> Vec<(String, ExecStats)> {
        self.stats.snapshot()
    }
}

/// Access the cache field matching a residual name.
fn residual_of<'a>(c: &'a rm::BlockCache, name: &str) -> &'a [f32] {
    match name {
        "x" => &c.x2d[..],
        "h1" => &c.h1[..],
        "h2" => &c.h2[..],
        "x2" => &c.x2[..],
        "q_rope" => &c.q_rope[..],
        "k_rope" => &c.k_rope[..],
        "v_heads" => &c.v_heads[..],
        "probs" => &c.probs[..],
        "attn_flat" => &c.attn_flat[..],
        "gate_out" => &c.gate_out[..],
        "up_out" => &c.up_out[..],
        "silu_out" => &c.silu_out[..],
        "h_q" => &c.hs[0][..],
        "h_k" => &c.hs[1][..],
        "h_v" => &c.hs[2][..],
        "h_o" => &c.hs[3][..],
        "h_gate" => &c.hs[4][..],
        "h_up" => &c.hs[5][..],
        "h_down" => &c.hs[6][..],
        other => panic!("unknown residual {other}"),
    }
}

/// Shapes of the residual set, RESIDUALS order.
fn residual_shapes(d: &ModelDims) -> Vec<(&'static str, Vec<usize>)> {
    let m = d.m();
    let (b, n, hd) = (d.batch, d.seq, d.head_dim);
    RESIDUALS
        .iter()
        .map(|&name| {
            let shape = match name {
                "x" | "h1" | "h2" | "x2" => vec![m, d.d_model],
                "q_rope" => vec![b, d.n_heads, n, hd],
                "k_rope" | "v_heads" => vec![b, d.n_kv_heads, n, hd],
                "probs" => vec![b, d.n_heads, n, n],
                "attn_flat" => vec![m, d.q_dim()],
                "gate_out" | "up_out" | "silu_out" => vec![m, d.d_ff],
                _ => vec![m, d.rank], // the seven h = xA
            };
            (name, shape)
        })
        .collect()
}

/// Programmatically generate the artifact specs for `dims` — the same ABI
/// `python/compile/aot.py` writes into `manifest.json`.
fn build_specs(d: &ModelDims) -> Vec<ArtifactSpec> {
    let m = d.m();
    let bnd = vec![d.batch, d.seq, d.d_model];
    let bn = vec![d.batch, d.seq];
    let f = |name: &str, shape: Vec<usize>| ArgSpec {
        name: name.to_string(),
        shape,
        dtype: DType::F32,
    };
    let i = |name: &str, shape: Vec<usize>| ArgSpec {
        name: name.to_string(),
        shape,
        dtype: DType::I32,
    };
    let frozen_args = || -> Vec<ArgSpec> {
        FROZEN.iter().map(|&w| f(w, d.frozen_shape(w))).collect()
    };
    let lora_args = || -> Vec<ArgSpec> {
        let mut v = Vec::with_capacity(2 * PROJS.len());
        for p in PROJS {
            let (din, dout) = d.proj_dims(p);
            v.push(f(&format!("a_{p}"), vec![din, d.rank]));
            v.push(f(&format!("b_{p}"), vec![d.rank, dout]));
        }
        v
    };
    let h_args = || -> Vec<ArgSpec> {
        PROJS.iter().map(|p| f(&format!("h_{p}"), vec![m, d.rank])).collect()
    };
    let loss_args = || -> Vec<ArgSpec> {
        vec![
            f("h", bnd.clone()),
            f("norm_w", vec![d.d_model]),
            f("emb", vec![d.vocab, d.d_model]),
            i("targets", bn.clone()),
        ]
    };
    let spec = |name: &str, args: Vec<ArgSpec>, outputs: usize| ArtifactSpec {
        name: name.to_string(),
        file: PathBuf::from("<builtin:reference>"),
        args,
        outputs,
    };
    let block_args = |leads: Vec<ArgSpec>| -> Vec<ArgSpec> {
        let mut v = leads;
        v.extend(frozen_args());
        v.extend(lora_args());
        v
    };

    let mut specs = vec![
        spec(
            "embed_fwd",
            vec![i("tokens", bn.clone()), f("emb", vec![d.vocab, d.d_model])],
            1,
        ),
        spec("block_fwd", block_args(vec![f("x", bnd.clone())]), 1),
        spec(
            "block_fwd_saveh",
            block_args(vec![f("x", bnd.clone())]),
            1 + PROJS.len(),
        ),
        spec(
            "block_fwd_residuals",
            block_args(vec![f("x", bnd.clone())]),
            1 + RESIDUALS.len(),
        ),
        spec(
            "block_bwd_mesp",
            block_args(vec![f("x", bnd.clone()), f("g_y", bnd.clone())]),
            1 + 2 * PROJS.len(),
        ),
        spec(
            "block_bwd_storeh",
            block_args({
                let mut v = vec![f("x", bnd.clone()), f("g_y", bnd.clone())];
                v.extend(h_args());
                v
            }),
            1 + 2 * PROJS.len(),
        ),
        spec(
            "block_bwd_residuals",
            block_args({
                let mut v = vec![f("g_y", bnd.clone())];
                for (name, shape) in residual_shapes(d) {
                    v.push(f(name, shape));
                }
                v
            }),
            1 + 2 * PROJS.len(),
        ),
        spec("lm_loss_fwd", loss_args(), 1),
        spec("lm_loss_grad", loss_args(), 2),
    ];
    // q4 needs every quantized d_in divisible by the packing group. When
    // that holds, the WHOLE block surface gets a `_q4` twin: same leads,
    // but the frozen args are ln1/ln2 plus (packed u8, scales f32) pairs
    // per QUANT_MATS — so a training session can keep base weights
    // int4-resident through forward AND all three backward variants.
    let q4_ok = QUANT_MATS
        .iter()
        .all(|&w| d.frozen_shape(w)[0] % quant::GROUP == 0);
    if q4_ok {
        let u = |name: &str, shape: Vec<usize>| ArgSpec {
            name: name.to_string(),
            shape,
            dtype: DType::U8,
        };
        let q4_block_args = |leads: Vec<ArgSpec>| -> Vec<ArgSpec> {
            let mut v = leads;
            v.push(f("ln1", vec![d.d_model]));
            v.push(f("ln2", vec![d.d_model]));
            for w in QUANT_MATS {
                let shape = d.frozen_shape(w);
                let (din, dout) = (shape[0], shape[1]);
                v.push(u(&format!("packed_{w}"), vec![din / 2, dout]));
                v.push(f(&format!("scales_{w}"), vec![din / quant::GROUP, dout]));
            }
            v.extend(lora_args());
            v
        };
        specs.push(spec(
            "block_fwd_q4",
            q4_block_args(vec![f("x", bnd.clone())]),
            1,
        ));
        specs.push(spec(
            "block_fwd_saveh_q4",
            q4_block_args(vec![f("x", bnd.clone())]),
            1 + PROJS.len(),
        ));
        specs.push(spec(
            "block_fwd_residuals_q4",
            q4_block_args(vec![f("x", bnd.clone())]),
            1 + RESIDUALS.len(),
        ));
        specs.push(spec(
            "block_bwd_mesp_q4",
            q4_block_args(vec![f("x", bnd.clone()), f("g_y", bnd.clone())]),
            1 + 2 * PROJS.len(),
        ));
        specs.push(spec(
            "block_bwd_storeh_q4",
            q4_block_args({
                let mut v = vec![f("x", bnd.clone()), f("g_y", bnd.clone())];
                v.extend(h_args());
                v
            }),
            1 + 2 * PROJS.len(),
        ));
        specs.push(spec(
            "block_bwd_residuals_q4",
            q4_block_args({
                let mut v = vec![f("g_y", bnd.clone())];
                for (name, shape) in residual_shapes(d) {
                    v.push(f(name, shape));
                }
                v
            }),
            1 + 2 * PROJS.len(),
        ));
    }
    specs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::Rng;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(presets::compiled("toy").unwrap(), MemoryTracker::new())
    }

    #[test]
    fn specs_match_manifest_abi() {
        let be = backend();
        let bwd = be.spec("block_bwd_mesp").unwrap();
        assert_eq!(bwd.outputs, 15);
        assert_eq!(bwd.args.len(), 2 + 9 + 14);
        assert_eq!(bwd.args[0].name, "x");
        assert_eq!(bwd.args[0].shape, vec![1, 32, 64]);
        assert!(be.has_artifact("block_fwd_residuals"));
        assert!(be.has_artifact("block_fwd_q4"));
        assert!(!be.has_artifact("nope"));
        let res = be.spec("block_bwd_residuals").unwrap();
        assert_eq!(res.args.len(), 1 + 19 + 9 + 14);
    }

    #[test]
    fn q4_specs_cover_the_whole_block_surface() {
        let be = backend();
        for base in ["block_fwd", "block_fwd_saveh", "block_fwd_residuals",
                     "block_bwd_mesp", "block_bwd_storeh",
                     "block_bwd_residuals"] {
            let q4 = format!("{base}_q4");
            let fs = be.spec(base).unwrap();
            let qs = be.spec(&q4).unwrap();
            assert_eq!(fs.outputs, qs.outputs, "{base}: output arity drifted");
            // q4 swaps 9 frozen tensors for ln1+ln2+7 (packed, scales)
            assert_eq!(qs.args.len(), fs.args.len() - 9 + 16, "{base}");
        }
        let q4 = be.spec("block_bwd_mesp_q4").unwrap();
        assert_eq!(q4.args[0].name, "x");
        assert_eq!(q4.args[2].name, "ln1");
        assert_eq!(q4.args[4].name, "packed_wq");
        assert_eq!(q4.args[4].dtype, DType::U8);
        let d = be.dims();
        assert_eq!(q4.args[4].shape, vec![d.d_model / 2, d.q_dim()]);
        assert_eq!(q4.args[5].name, "scales_wq");
        assert_eq!(q4.args[5].shape, vec![d.d_model / quant::GROUP, d.q_dim()]);
    }

    #[test]
    fn arg_validation_rejects_bad_shapes() {
        let be = backend();
        let mut rng = Rng::new(1);
        let bad = HostTensor::randn(&[2, 2], 1.0, &mut rng);
        let emb = HostTensor::randn(&[256, 64], 0.02, &mut rng);
        let err = be
            .execute("embed_fwd", &[Arg::Host(&bad), Arg::Host(&emb)])
            .unwrap_err();
        assert!(err.to_string().contains("shape/dtype"), "{err}");
        // wrong arity
        let err2 = be.execute("embed_fwd", &[Arg::Host(&emb)]).unwrap_err();
        assert!(err2.to_string().contains("expected 2 args"), "{err2}");
    }

    #[test]
    fn embed_picks_rows() {
        let be = backend();
        let d = be.dims().clone();
        let mut rng = Rng::new(2);
        let emb = HostTensor::randn(&[d.vocab, d.d_model], 0.02, &mut rng);
        let tokens = HostTensor::i32(&[1, d.seq], (0..d.seq as i32).collect());
        let out = be
            .execute("embed_fwd", &[Arg::Host(&tokens), Arg::Host(&emb)])
            .unwrap();
        assert_eq!(out[0].shape, vec![1, d.seq, d.d_model]);
        assert_eq!(
            out[0].as_f32()[..d.d_model],
            emb.as_f32()[..d.d_model],
            "token 0 row"
        );
    }

    #[test]
    fn traced_execute_emits_artifact_span() {
        let sink = TraceSink::enabled();
        let be = ReferenceBackend::with_telemetry(
            presets::compiled("toy").unwrap(),
            MemoryTracker::new(),
            KernelOptions::default(),
            sink.clone(),
        );
        let d = be.dims().clone();
        let mut rng = Rng::new(7);
        let emb = HostTensor::randn(&[d.vocab, d.d_model], 0.02, &mut rng);
        let tokens = HostTensor::i32(&[1, d.seq], vec![0; d.seq]);
        be.execute("embed_fwd", &[Arg::Host(&tokens), Arg::Host(&emb)])
            .unwrap();
        let spans: Vec<_> = sink
            .events()
            .into_iter()
            .filter(|e| e.cat == "artifact")
            .collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "embed_fwd");
        let in_bytes = spans[0]
            .args
            .iter()
            .find(|(k, _)| *k == "in_bytes")
            .map(|(_, v)| v.clone())
            .unwrap();
        let expect = (tokens.bytes() + emb.bytes()) as f64;
        assert_eq!(in_bytes, Json::Num(expect));
    }

    #[test]
    fn exec_stats_accumulate() {
        let be = backend();
        let d = be.dims().clone();
        let mut rng = Rng::new(3);
        let emb = HostTensor::randn(&[d.vocab, d.d_model], 0.02, &mut rng);
        let tokens = HostTensor::i32(&[1, d.seq], vec![0; d.seq]);
        for _ in 0..3 {
            be.execute("embed_fwd", &[Arg::Host(&tokens), Arg::Host(&emb)])
                .unwrap();
        }
        let stats = be.exec_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].0, "embed_fwd");
        assert_eq!(stats[0].1.calls, 3);
    }
}
