//! PJRT runtime: loads HLO-text artifacts, compiles them once on the CPU
//! client, and executes them with shape/dtype-checked host tensors.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (the crate's XLA 0.5.1 rejects jax≥0.5 serialized protos). All
//! artifacts return a tuple; outputs are read back via literal decompose —
//! on the CPU platform "device" memory is host memory, so this is memcpy,
//! not PCIe. Executables are compiled lazily and cached for the process
//! lifetime; every call's transient I/O bytes are registered with the
//! memory tracker so step peaks include call overhead.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::memory::MemoryTracker;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::tensor::{Data, HostTensor};

/// Cumulative per-artifact execution statistics (perf §L3).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// An argument to `execute_mixed`: either a host tensor (uploaded for the
/// call) or a persistent device buffer (uploaded once — frozen weights,
/// embeddings). Keeping weights device-resident removed the dominant
/// memcpy cost at 100M scale (EXPERIMENTS.md §Perf: 19.5s → see log).
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Device(&'a xla::PjRtBuffer),
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: Mutex<HashMap<String, ExecStats>>,
    pub tracker: MemoryTracker,
}

impl Runtime {
    /// Load a compiled config from `artifacts_dir/<config_name>/`.
    pub fn load(artifacts_dir: &Path, config: &str, tracker: MemoryTracker)
        -> anyhow::Result<Runtime>
    {
        let dir = artifacts_dir.join(config);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
            tracker,
        })
    }

    pub fn dims(&self) -> &crate::config::ModelDims {
        &self.manifest.dims
    }

    /// Compile (or fetch cached) an artifact's executable.
    fn executable(&self, name: &str) -> anyhow::Result<()> {
        let mut exes = self.exes.lock().unwrap();
        if exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (so step timing excludes compiles).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            if self.manifest.has_artifact(n) {
                self.executable(n)?;
            }
        }
        Ok(())
    }

    fn check_args(spec: &ArtifactSpec, args: &[&HostTensor]) -> anyhow::Result<()> {
        if spec.args.len() != args.len() {
            anyhow::bail!(
                "{}: expected {} args, got {}",
                spec.name, spec.args.len(), args.len()
            );
        }
        for (a, t) in spec.args.iter().zip(args) {
            if a.shape != t.shape {
                anyhow::bail!(
                    "{}: arg '{}' shape {:?} != expected {:?}",
                    spec.name, a.name, t.shape, a.shape
                );
            }
            if a.dtype != t.dtype() {
                anyhow::bail!(
                    "{}: arg '{}' dtype {:?} != expected {:?}",
                    spec.name, a.name, t.dtype(), a.dtype
                );
            }
        }
        Ok(())
    }

    fn to_literal(t: &HostTensor) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
        let lit = match &t.data {
            Data::F32(v) => xla::Literal::vec1(v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))?,
            Data::I32(v) => xla::Literal::vec1(v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))?,
            Data::U8(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8, &t.shape, v,
            )
            .map_err(|e| anyhow::anyhow!("u8 literal: {e:?}"))?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow::anyhow!("literal ty: {e:?}"))?;
        Ok(match ty {
            xla::ElementType::F32 => HostTensor::f32(
                &dims,
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
            ),
            xla::ElementType::S32 => HostTensor::i32(
                &dims,
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
            ),
            xla::ElementType::U8 => HostTensor::u8(
                &dims,
                lit.to_vec::<u8>()
                    .map_err(|e| anyhow::anyhow!("to_vec u8: {e:?}"))?,
            ),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        })
    }

    /// Upload a host tensor to a persistent device buffer (weights path).
    /// On the CPU platform this is a one-time memcpy; buffers are reused
    /// across every subsequent `execute_mixed` call.
    pub fn upload(&self, t: &HostTensor) -> anyhow::Result<xla::PjRtBuffer> {
        let buf = match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer::<f32>(v, &t.shape, None),
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer::<i32>(v, &t.shape, None),
            // NOTE: not buffer_from_host_raw_bytes — the vendored crate
            // passes an ElementType discriminant where the C API expects
            // PrimitiveType, corrupting the buffer size for U8. The
            // literal path round-trips correctly.
            Data::U8(v) => {
                let lit = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::U8, &t.shape, v,
                )
                .map_err(|e| anyhow::anyhow!("u8 literal: {e:?}"))?;
                self.client.buffer_from_host_literal(None, &lit)
            }
        }
        .map_err(|e| anyhow::anyhow!("upload: {e:?}"))?;
        Ok(buf)
    }

    /// Execute with a mix of host tensors (uploaded per call) and
    /// persistent device buffers. Host args are shape/dtype-checked
    /// against the manifest; device args are trusted (validated at upload).
    pub fn execute_mixed(&self, name: &str, args: &[Arg])
        -> anyhow::Result<Vec<HostTensor>>
    {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(spec.args.len() == args.len(),
                        "{name}: expected {} args, got {}",
                        spec.args.len(), args.len());
        self.executable(name)?;

        let mut in_bytes = 0u64;
        for (a, arg) in spec.args.iter().zip(args) {
            if let Arg::Host(t) = arg {
                anyhow::ensure!(a.shape == t.shape && a.dtype == t.dtype(),
                                "{name}: arg '{}' shape/dtype mismatch \
                                 ({:?} vs {:?})", a.name, t.shape, a.shape);
                in_bytes += t.bytes();
            }
        }
        let _io_guard = self.tracker.track(&format!("exec:{name}"), in_bytes);

        let start = Instant::now();
        // upload transient host args; keep them alive for the call
        let mut transients: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::with_capacity(args.len()); // map
        for arg in args {
            if let Arg::Host(t) = arg {
                transients.push(self.upload(t)?);
                order.push(transients.len() - 1);
            } else {
                order.push(usize::MAX);
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .zip(&order)
            .map(|(a, o)| match a {
                Arg::Host(_) => &transients[*o],
                Arg::Device(b) => *b,
            })
            .collect();
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(name).expect("compiled above");
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        drop(exes);
        drop(refs);
        drop(transients);

        let mut tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback {name}: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose {name}: {e:?}"))?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(Self::from_literal)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(outputs.len() == spec.outputs,
                        "{name}: manifest promises {} outputs, got {}",
                        spec.outputs, outputs.len());

        let dt = start.elapsed().as_secs_f64();
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        Ok(outputs)
    }

    /// Execute artifact `name` with positional `args`. Returns the
    /// decomposed output tuple as host tensors, in artifact output order.
    pub fn execute(&self, name: &str, args: &[&HostTensor])
        -> anyhow::Result<Vec<HostTensor>>
    {
        let spec = self.manifest.artifact(name)?.clone();
        Self::check_args(&spec, args)?;
        self.executable(name)?;

        // Transient call I/O is tracked for the duration of the call.
        let in_bytes: u64 = args.iter().map(|t| t.bytes()).sum();
        let _io_guard = self.tracker.track(&format!("exec:{name}"), in_bytes);

        let start = Instant::now();
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| Self::to_literal(t))
            .collect::<anyhow::Result<_>>()?;
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(name).expect("compiled above");
        let out = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        drop(exes);
        drop(literals);

        let mut tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback {name}: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose {name}: {e:?}"))?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(Self::from_literal)
            .collect::<anyhow::Result<_>>()?;
        if outputs.len() != spec.outputs {
            anyhow::bail!(
                "{name}: manifest promises {} outputs, got {}",
                spec.outputs, outputs.len()
            );
        }

        let dt = start.elapsed().as_secs_f64();
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        Ok(outputs)
    }

    /// Snapshot of per-artifact execution stats.
    pub fn exec_stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }
}
