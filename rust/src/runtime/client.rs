//! PJRT runtime (cargo feature `pjrt`): loads HLO-text artifacts,
//! compiles them once on the CPU client, and executes them with
//! shape/dtype-checked host tensors — one of the two [`Backend`]
//! implementations.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (the crate's XLA 0.5.1 rejects jax≥0.5 serialized protos). All
//! artifacts return a tuple; outputs are read back via literal decompose —
//! on the CPU platform "device" memory is host memory, so this is memcpy,
//! not PCIe. Executables are compiled lazily and cached for the process
//! lifetime; every call's transient I/O bytes are registered with the
//! memory tracker so step peaks include call overhead.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::memory::MemoryTracker;
use crate::runtime::backend::{Arg, Backend, DeviceBuffer, ExecStats, StatsRecorder};
use crate::runtime::manifest::Manifest;
use crate::tensor::{Data, HostTensor};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: StatsRecorder,
    tracker: MemoryTracker,
}

impl Runtime {
    /// Load a compiled config from `artifacts_dir/<config_name>/`.
    pub fn load(artifacts_dir: &Path, config: &str, tracker: MemoryTracker)
        -> anyhow::Result<Runtime>
    {
        let dir = artifacts_dir.join(config);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: StatsRecorder::new(),
            tracker,
        })
    }

    /// Compile (or fetch cached) an artifact's executable.
    fn executable(&self, name: &str) -> anyhow::Result<()> {
        let mut exes = self.exes.lock().unwrap();
        if exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        exes.insert(name.to_string(), exe);
        Ok(())
    }

    fn from_literal(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow::anyhow!("literal ty: {e:?}"))?;
        Ok(match ty {
            xla::ElementType::F32 => HostTensor::f32(
                &dims,
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
            ),
            xla::ElementType::S32 => HostTensor::i32(
                &dims,
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
            ),
            xla::ElementType::U8 => HostTensor::u8(
                &dims,
                lit.to_vec::<u8>()
                    .map_err(|e| anyhow::anyhow!("to_vec u8: {e:?}"))?,
            ),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        })
    }

    /// Upload a host tensor to a persistent PJRT buffer (weights path).
    /// On the CPU platform this is a one-time memcpy; buffers are reused
    /// across every subsequent `execute` call.
    fn upload_buffer(&self, t: &HostTensor) -> anyhow::Result<xla::PjRtBuffer> {
        let buf = match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer::<f32>(v, &t.shape, None),
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer::<i32>(v, &t.shape, None),
            // NOTE: not buffer_from_host_raw_bytes — the vendored crate
            // passes an ElementType discriminant where the C API expects
            // PrimitiveType, corrupting the buffer size for U8. The
            // literal path round-trips correctly.
            Data::U8(v) => {
                let lit = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::U8, &t.shape, v,
                )
                .map_err(|e| anyhow::anyhow!("u8 literal: {e:?}"))?;
                self.client.buffer_from_host_literal(None, &lit)
            }
        }
        .map_err(|e| anyhow::anyhow!("upload: {e:?}"))?;
        Ok(buf)
    }
}

impl Backend for Runtime {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn dims(&self) -> &crate::config::ModelDims {
        &self.manifest.dims
    }

    fn tracker(&self) -> &MemoryTracker {
        &self.tracker
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.manifest.has_artifact(name)
    }

    /// Pre-compile a set of artifacts (so step timing excludes compiles).
    fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            if self.manifest.has_artifact(n) {
                self.executable(n)?;
            }
        }
        Ok(())
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<DeviceBuffer> {
        Ok(DeviceBuffer::Pjrt(self.upload_buffer(t)?))
    }

    /// Execute with a mix of host tensors (uploaded per call) and
    /// persistent device buffers. Host args are shape/dtype-checked
    /// against the manifest; device args are trusted (validated at upload).
    fn execute(&self, name: &str, args: &[Arg]) -> anyhow::Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(spec.args.len() == args.len(),
                        "{name}: expected {} args, got {}",
                        spec.args.len(), args.len());
        self.executable(name)?;

        let mut in_bytes = 0u64;
        for (a, arg) in spec.args.iter().zip(args) {
            if let Arg::Host(t) = arg {
                anyhow::ensure!(a.shape == t.shape && a.dtype == t.dtype(),
                                "{name}: arg '{}' shape/dtype mismatch \
                                 ({:?} vs {:?})", a.name, t.shape, a.shape);
                in_bytes += t.bytes();
            }
        }
        let _io_guard = self.tracker.track(&format!("exec:{name}"), in_bytes);

        let start = Instant::now();
        // upload transient host args; keep them alive for the call
        let mut transients: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::with_capacity(args.len()); // map
        for arg in args {
            match arg {
                Arg::Host(t) => {
                    transients.push(self.upload_buffer(t)?);
                    order.push(transients.len() - 1);
                }
                Arg::Device(_) => order.push(usize::MAX),
                // This backend reports `shares_host_memory() == false`, so
                // callers upload shared weights instead of borrowing them.
                Arg::Resident(_) => anyhow::bail!(
                    "{name}: Arg::Resident passed to an upload backend"
                ),
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (a, o) in args.iter().zip(&order) {
            match a {
                Arg::Host(_) => refs.push(&transients[*o]),
                Arg::Device(DeviceBuffer::Pjrt(b)) => refs.push(b),
                Arg::Device(DeviceBuffer::Resident(_)) => anyhow::bail!(
                    "{name}: reference-backend buffer passed to the PJRT runtime"
                ),
                Arg::Resident(_) => unreachable!("rejected above"),
            }
        }
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(name).expect("compiled above");
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        drop(exes);
        drop(refs);
        drop(transients);

        let mut tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback {name}: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose {name}: {e:?}"))?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(Self::from_literal)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(outputs.len() == spec.outputs,
                        "{name}: manifest promises {} outputs, got {}",
                        spec.outputs, outputs.len());

        // The PJRT runtime cannot see inside compiled executables, so it
        // reports the same analytical FLOP inventory the kernel engine
        // instruments in-process.
        self.stats.record(
            name,
            start.elapsed().as_secs_f64(),
            crate::runtime::kernels::flops::artifact(&self.manifest.dims, name),
        );
        Ok(outputs)
    }

    /// Snapshot of per-artifact execution stats.
    fn exec_stats(&self) -> Vec<(String, ExecStats)> {
        self.stats.snapshot()
    }
}
