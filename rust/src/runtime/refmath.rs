//! Pure-Rust reference math for the [`super::ReferenceBackend`]: the
//! Qwen2.5-style block (RMSNorm → GQA attention with RoPE → RMSNorm →
//! SwiGLU), LoRA adapters on all 7 projections, and the paper's
//! Appendix-A manual backward passes — including the MeSP discipline
//! where the LoRA intermediate `h = xA` is *recomputed* in the backward
//! instead of stored.
//!
//! This is the in-process mirror of `python/compile/model.py` +
//! `python/compile/kernels/ref.py`: same formulas, same operation order,
//! so the MeSP / store-h / residual backward variants produce bitwise
//! identical gradients for identical inputs.
//!
//! All GEMMs route through the [`Kernels`] engine (`--kernel
//! naive|tiled|parallel`), and every intermediate is checked out of its
//! [`crate::tensor::TensorArena`] — reused across calls and tracked under
//! the `scratch` tag. Gradients stay bitwise identical across the three
//! backward variants *within* one kernel kind; across kinds they agree to
//! float tolerance (tiling changes the k-summation bracketing).
//!
//! Layout conventions: 2-D tensors are row-major `[rows, cols]` slices;
//! per-head tensors are flattened `[batch, heads, seq, head_dim]`.

use crate::config::ModelDims;
use crate::tensor::ScratchBuf;

use super::kernels::{FrozenW, Kernels};

/// RMSNorm epsilon (matches ModelConfig.eps).
pub const EPS: f32 = 1e-6;
/// RoPE base (matches ModelConfig.rope_theta).
pub const ROPE_THETA: f32 = 10000.0;

// ------------------------------------------------------------- primitives

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

fn added(ks: &Kernels, a: &[f32], b: &[f32]) -> ScratchBuf {
    debug_assert_eq!(a.len(), b.len());
    let mut out = ks.arena().take(a.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
    out
}

// --------------------------------------------------------------- RMSNorm

/// `x_hat = x / rms(x) * w`, rms over the last axis; `x: [rows, d]`.
pub fn rmsnorm(ks: &Kernels, x: &[f32], w: &[f32], d: usize) -> ScratchBuf {
    let rows = x.len() / d;
    let mut out = ks.arena().take(x.len());
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..d {
            out[r * d + i] = xr[i] * inv * w[i];
        }
    }
    out
}

/// dL/dx of RMSNorm with frozen weight `w` (paper eq. 22 + weight):
/// with `u = x / rms(x)` and `gw = g ⊙ w`:
/// `dx = (gw - u · mean(gw ⊙ u)) / rms`.
pub fn rmsnorm_bwd(ks: &Kernels, x: &[f32], w: &[f32], g: &[f32], d: usize) -> ScratchBuf {
    let rows = x.len() / d;
    let mut out = ks.arena().take(x.len());
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let gr = &g[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let mut dot = 0.0f32;
        for i in 0..d {
            dot += gr[i] * w[i] * xr[i] * inv;
        }
        let mean = dot / d as f32;
        for i in 0..d {
            out[r * d + i] = (gr[i] * w[i] - xr[i] * inv * mean) * inv;
        }
    }
    out
}

// -------------------------------------------------------------- SiLU-mul

/// SwiGLU elementwise core: `silu(gate) ⊙ up`.
pub fn silu_mul(ks: &Kernels, gate: &[f32], up: &[f32]) -> ScratchBuf {
    let mut out = ks.arena().take(gate.len());
    for ((o, &g), &u) in out.iter_mut().zip(gate).zip(up) {
        let sig = 1.0 / (1.0 + (-g).exp());
        *o = g * sig * u;
    }
    out
}

/// Backward of `silu(gate)·up`; returns `(d_gate, d_up)`.
pub fn silu_mul_bwd(
    ks: &Kernels,
    gate: &[f32],
    up: &[f32],
    g: &[f32],
) -> (ScratchBuf, ScratchBuf) {
    let mut dg = ks.arena().take(gate.len());
    let mut du = ks.arena().take(up.len());
    for i in 0..gate.len() {
        let sig = 1.0 / (1.0 + (-gate[i]).exp());
        let silu = gate[i] * sig;
        let dsilu = sig * (1.0 + gate[i] * (1.0 - sig));
        dg[i] = g[i] * up[i] * dsilu;
        du[i] = g[i] * silu;
    }
    (dg, du)
}

// ------------------------------------------------------------------ RoPE

/// cos/sin tables `[n, hd/2]` (small; plain Vecs, not arena scratch).
pub fn rope_tables(seq: usize, head_dim: usize) -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = vec![0.0f32; seq * half];
    let mut sin = vec![0.0f32; seq * half];
    for p in 0..seq {
        for j in 0..half {
            let freq = 1.0 / ROPE_THETA.powf(j as f32 / half as f32);
            let ang = p as f32 * freq;
            cos[p * half + j] = ang.cos();
            sin[p * half + j] = ang.sin();
        }
    }
    (cos, sin)
}

/// Neox-style rotate-half RoPE on `[b, heads, n, hd]`; the VJP of a
/// rotation is the rotation by `-θ` (`inverse = true`).
#[allow(clippy::too_many_arguments)]
pub fn apply_rope(
    ks: &Kernels,
    x: &[f32],
    b: usize,
    heads: usize,
    n: usize,
    hd: usize,
    cos: &[f32],
    sin: &[f32],
    inverse: bool,
) -> ScratchBuf {
    let half = hd / 2;
    let mut out = ks.arena().take(x.len());
    for bi in 0..b {
        for h in 0..heads {
            for t in 0..n {
                let base = ((bi * heads + h) * n + t) * hd;
                for j in 0..half {
                    let c = cos[t * half + j];
                    let s = sin[t * half + j];
                    let x1 = x[base + j];
                    let x2 = x[base + half + j];
                    if inverse {
                        out[base + j] = x1 * c + x2 * s;
                        out[base + half + j] = x2 * c - x1 * s;
                    } else {
                        out[base + j] = x1 * c - x2 * s;
                        out[base + half + j] = x1 * s + x2 * c;
                    }
                }
            }
        }
    }
    out
}

// ----------------------------------------------------------- head layout

/// `[b*n, heads*hd] -> [b, heads, n, hd]`.
pub fn split_heads(
    ks: &Kernels,
    x2d: &[f32],
    b: usize,
    n: usize,
    heads: usize,
    hd: usize,
) -> ScratchBuf {
    let mut out = ks.arena().take(x2d.len());
    for bi in 0..b {
        for t in 0..n {
            for h in 0..heads {
                let src = (bi * n + t) * heads * hd + h * hd;
                let dst = ((bi * heads + h) * n + t) * hd;
                out[dst..dst + hd].copy_from_slice(&x2d[src..src + hd]);
            }
        }
    }
    out
}

/// `[b, heads, n, hd] -> [b*n, heads*hd]`.
pub fn merge_heads(
    ks: &Kernels,
    x4: &[f32],
    b: usize,
    heads: usize,
    n: usize,
    hd: usize,
) -> ScratchBuf {
    let mut out = ks.arena().take(x4.len());
    for bi in 0..b {
        for h in 0..heads {
            for t in 0..n {
                let src = ((bi * heads + h) * n + t) * hd;
                let dst = (bi * n + t) * heads * hd + h * hd;
                out[dst..dst + hd].copy_from_slice(&x4[src..src + hd]);
            }
        }
    }
    out
}

// ------------------------------------------------------------- attention

/// Causal softmax attention over GQA heads. `q: [b,H,n,hd]`,
/// `k/v: [b,KV,n,hd]` (each query head reads kv head `h / (H/KV)`).
/// Returns `(out [b,H,n,hd], probs [b,H,n,n])`; masked entries of probs
/// are exactly zero.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd(
    ks: &Kernels,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    heads: usize,
    kv_heads: usize,
    n: usize,
    hd: usize,
) -> (ScratchBuf, ScratchBuf) {
    let rep = heads / kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = ks.arena().take(b * heads * n * hd);
    let mut probs = ks.arena().take(b * heads * n * n);
    // QK and PV both do Σ_i (i+1)·hd multiply-adds per (batch, head).
    ks.add_flops((b * heads) as u64 * 2 * (n * (n + 1)) as u64 * hd as u64);
    let mut row = ks.arena().take(n); // score row, reused across queries
    for bi in 0..b {
        for h in 0..heads {
            let kvh = h / rep;
            let qb = (bi * heads + h) * n * hd;
            let kb = (bi * kv_heads + kvh) * n * hd;
            let pb = (bi * heads + h) * n * n;
            for i in 0..n {
                let qi = &q[qb + i * hd..qb + (i + 1) * hd];
                // causal: keys 0..=i
                let row = &mut row[..i + 1];
                let mut mx = f32::NEG_INFINITY;
                for (j, rj) in row.iter_mut().enumerate() {
                    let kj = &k[kb + j * hd..kb + (j + 1) * hd];
                    let mut s = 0.0f32;
                    for (a, c) in qi.iter().zip(kj) {
                        s += a * c;
                    }
                    *rj = s * scale;
                    mx = mx.max(*rj);
                }
                let mut denom = 0.0f32;
                for rj in row.iter_mut() {
                    *rj = (*rj - mx).exp();
                    denom += *rj;
                }
                let oi = &mut out[qb + i * hd..qb + (i + 1) * hd];
                for (j, rj) in row.iter().enumerate() {
                    let p = rj / denom;
                    probs[pb + i * n + j] = p;
                    let vj = &v[kb + j * hd..kb + (j + 1) * hd];
                    for (o, &vv) in oi.iter_mut().zip(vj) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    (out, probs)
}

/// Attention backward given saved `probs` (paper eq. 17-21):
/// `dv = probsᵀ g_out`, `dprobs = g_out vᵀ`,
/// `dscores = probs ⊙ (dprobs - rowsum(dprobs ⊙ probs))`,
/// `dq = dscores k · scale`, `dk = dscoresᵀ q · scale`.
/// KV-head grads are summed over the query-head group (the VJP of the
/// GQA repeat). Returns `(dq [b,H,n,hd], dk [b,KV,n,hd], dv [b,KV,n,hd])`.
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd(
    ks: &Kernels,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    g_out: &[f32],
    b: usize,
    heads: usize,
    kv_heads: usize,
    n: usize,
    hd: usize,
) -> (ScratchBuf, ScratchBuf, ScratchBuf) {
    let rep = heads / kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = ks.arena().take(b * heads * n * hd);
    let mut dk = ks.arena().take(b * kv_heads * n * hd);
    let mut dv = ks.arena().take(b * kv_heads * n * hd);
    // the softmax-VJP elementwise pass (GEMMs count themselves)
    ks.add_flops((b * heads * 3 * n * n) as u64);
    let mut ds = ks.arena().take(n * n);
    for bi in 0..b {
        for h in 0..heads {
            let kvh = h / rep;
            let qb = (bi * heads + h) * n * hd;
            let kb = (bi * kv_heads + kvh) * n * hd;
            let pb = (bi * heads + h) * n * n;
            let p = &probs[pb..pb + n * n];
            let go = &g_out[qb..qb + n * hd];
            let kh = &k[kb..kb + n * hd];
            let vh = &v[kb..kb + n * hd];
            let qh = &q[qb..qb + n * hd];
            // dv += pᵀ @ go  (accumulated into the kv head slot)
            let dvh = ks.matmul_at(p, go, n, n, hd);
            add_into(&mut dv[kb..kb + n * hd], &dvh);
            // dprobs = go @ vᵀ
            let dp = ks.matmul_bt(go, vh, n, hd, n);
            // dscores = p ⊙ (dp - rowsum(dp ⊙ p))
            for i in 0..n {
                let mut rowsum = 0.0f32;
                for j in 0..n {
                    rowsum += dp[i * n + j] * p[i * n + j];
                }
                for j in 0..n {
                    ds[i * n + j] = p[i * n + j] * (dp[i * n + j] - rowsum);
                }
            }
            // dq = ds @ k · scale
            let dqh = ks.matmul(&ds, kh, n, n, hd);
            for (d, s) in dq[qb..qb + n * hd].iter_mut().zip(&dqh[..]) {
                *d = s * scale;
            }
            // dk += dsᵀ @ q · scale
            let dkh = ks.matmul_at(&ds, qh, n, n, hd);
            for (d, s) in dk[kb..kb + n * hd].iter_mut().zip(&dkh[..]) {
                *d += s * scale;
            }
        }
    }
    (dq, dk, dv)
}

// ------------------------------------------------------------------ LoRA

/// Forward of a LoRA site (paper eq. 5): `y = x W + s (x A) B`. The
/// frozen `W` may be int4-packed (paper §4.5) — A/B stay f32 either way,
/// so `h = xA` and the LoRA delta are identical across quant modes.
/// Returns `(y [m,dout], h = xA [m,r])`.
#[allow(clippy::too_many_arguments)]
pub fn lora_fwd(
    ks: &Kernels,
    x: &[f32],
    w: FrozenW,
    a: &[f32],
    bb: &[f32],
    s: f32,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
) -> (ScratchBuf, ScratchBuf) {
    let h = ks.matmul(x, a, m, din, r);
    let mut y = ks.matmul_w(x, w, m, din, dout);
    let hb = ks.matmul(&h, bb, m, r, dout);
    for (yv, hv) in y.iter_mut().zip(&hb[..]) {
        *yv += s * hv;
    }
    (y, h)
}

/// Full LoRA-linear backward (paper eq. 10-13). If `stored_h` is given
/// (store-h / residual modes), `dB` consumes it; otherwise `h = xA` is
/// RECOMPUTED here — the paper's key insight (rank r ≪ d_in makes the
/// recompute nearly free, and nothing needs to be stored).
/// Returns `(gx [m,din], dA [din,r], dB [r,dout])`.
#[allow(clippy::too_many_arguments)]
pub fn lora_bwd(
    ks: &Kernels,
    x: &[f32],
    g: &[f32],
    w: FrozenW,
    a: &[f32],
    bb: &[f32],
    s: f32,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
    stored_h: Option<&[f32]>,
) -> (ScratchBuf, ScratchBuf, ScratchBuf) {
    let mut sg = ks.arena().take(g.len());
    for (o, v) in sg.iter_mut().zip(g) {
        *o = s * v;
    }
    let dh = ks.matmul_bt(&sg, bb, m, dout, r);
    let da = ks.matmul_at(x, &dh, m, din, r);
    let db = match stored_h {
        Some(h) => ks.matmul_at(h, &sg, m, r, dout),
        None => {
            let h = ks.matmul(x, a, m, din, r); // Appendix-A recompute
            ks.matmul_at(&h, &sg, m, r, dout)
        }
    };
    let mut gx = ks.matmul_bt(&dh, a, m, r, din);
    let gw = ks.matmul_wt(g, w, m, dout, din);
    add_into(&mut gx, &gw);
    (gx, da, db)
}

// ------------------------------------------------------------- the block

// Frozen-weight indices in the artifact ABI order (config::FROZEN).
const LN1: usize = 0;
const WQ: usize = 1;
const WK: usize = 2;
const WV: usize = 3;
const WO: usize = 4;
const LN2: usize = 5;
const WG: usize = 6;
const WU: usize = 7;
const WD: usize = 8;

/// Every intermediate a backward pass could need — the Rust mirror of
/// `_block_core`'s cache dict. All fields are arena scratch: alive only
/// while the artifact call that produced them runs (unless detached into
/// outputs via [`ScratchBuf::into_vec`]).
pub struct BlockCache {
    pub x2d: ScratchBuf,
    pub h1: ScratchBuf,
    pub h2: ScratchBuf,
    pub x2: ScratchBuf,
    pub q_rope: ScratchBuf,
    pub k_rope: ScratchBuf,
    pub v_heads: ScratchBuf,
    pub probs: ScratchBuf,
    pub attn_flat: ScratchBuf,
    pub gate_out: ScratchBuf,
    pub up_out: ScratchBuf,
    pub silu_out: ScratchBuf,
    /// The seven `h = xA` intermediates, PROJS order.
    pub hs: Vec<ScratchBuf>,
    /// Block output `[m, d]`.
    pub y: ScratchBuf,
}

/// Full block forward; `x: [m, d]`, frozen ×9 (f32 or int4-packed, ABI
/// order) and lora ×14 in ABI order.
pub fn block_forward(
    ks: &Kernels,
    dims: &ModelDims,
    x: &[f32],
    frozen: &[FrozenW],
    lora: &[&[f32]],
) -> BlockCache {
    let (b, n, d) = (dims.batch, dims.seq, dims.d_model);
    let (hh, kv, hd, ff, r) = (
        dims.n_heads,
        dims.n_kv_heads,
        dims.head_dim,
        dims.d_ff,
        dims.rank,
    );
    let m = b * n;
    let s = dims.scale();
    let (qd, kvd) = (dims.q_dim(), dims.kv_dim());

    let h1 = rmsnorm(ks, x, frozen[LN1].f32(), d);
    let (q2d, h_q) = lora_fwd(ks, &h1, frozen[WQ], lora[0], lora[1], s, m, d, qd, r);
    let (k2d, h_k) = lora_fwd(ks, &h1, frozen[WK], lora[2], lora[3], s, m, d, kvd, r);
    let (v2d, h_v) = lora_fwd(ks, &h1, frozen[WV], lora[4], lora[5], s, m, d, kvd, r);

    let (cos, sin) = rope_tables(n, hd);
    let q4 = apply_rope(
        ks, &split_heads(ks, &q2d, b, n, hh, hd), b, hh, n, hd, &cos, &sin, false,
    );
    let k4 = apply_rope(
        ks, &split_heads(ks, &k2d, b, n, kv, hd), b, kv, n, hd, &cos, &sin, false,
    );
    let v4 = split_heads(ks, &v2d, b, n, kv, hd);
    drop((q2d, k2d, v2d));

    let (attn_out, probs) = attention_fwd(ks, &q4, &k4, &v4, b, hh, kv, n, hd);
    let attn_flat = merge_heads(ks, &attn_out, b, hh, n, hd);
    drop(attn_out);

    let (o2d, h_o) = lora_fwd(ks, &attn_flat, frozen[WO], lora[6], lora[7], s, m, qd, d, r);
    let x2 = added(ks, x, &o2d);
    drop(o2d);

    let h2 = rmsnorm(ks, &x2, frozen[LN2].f32(), d);
    let (gate_out, h_gate) = lora_fwd(ks, &h2, frozen[WG], lora[8], lora[9], s, m, d, ff, r);
    let (up_out, h_up) = lora_fwd(ks, &h2, frozen[WU], lora[10], lora[11], s, m, d, ff, r);
    let silu_out = silu_mul(ks, &gate_out, &up_out);
    let (d2d, h_down) = lora_fwd(ks, &silu_out, frozen[WD], lora[12], lora[13], s, m, ff, d, r);
    let y = added(ks, &x2, &d2d);
    drop(d2d);

    BlockCache {
        x2d: ks.arena().take_from(x),
        h1,
        h2,
        x2,
        q_rope: q4,
        k_rope: k4,
        v_heads: v4,
        probs,
        attn_flat,
        gate_out,
        up_out,
        silu_out,
        hs: vec![h_q, h_k, h_v, h_o, h_gate, h_up, h_down],
        y,
    }
}

/// Forward pass for inference-only callers (`block_fwd`, `block_fwd_q4`:
/// the checkpoint sweep and both MeZO forwards): identical math and
/// operation order to [`block_forward`] — the y it returns is bitwise
/// the same — but every intermediate is dropped back to the arena the
/// moment the dataflow is done with it, so the tracked scratch peak is
/// the inference working set, not the full cache.
pub fn block_forward_inference(
    ks: &Kernels,
    dims: &ModelDims,
    x: &[f32],
    frozen: &[FrozenW],
    lora: &[&[f32]],
) -> ScratchBuf {
    let (b, n, d) = (dims.batch, dims.seq, dims.d_model);
    let (hh, kv, hd, ff, r) = (
        dims.n_heads,
        dims.n_kv_heads,
        dims.head_dim,
        dims.d_ff,
        dims.rank,
    );
    let m = b * n;
    let s = dims.scale();
    let (qd, kvd) = (dims.q_dim(), dims.kv_dim());

    let h1 = rmsnorm(ks, x, frozen[LN1].f32(), d);
    let (q2d, h_q) = lora_fwd(ks, &h1, frozen[WQ], lora[0], lora[1], s, m, d, qd, r);
    let (k2d, h_k) = lora_fwd(ks, &h1, frozen[WK], lora[2], lora[3], s, m, d, kvd, r);
    let (v2d, h_v) = lora_fwd(ks, &h1, frozen[WV], lora[4], lora[5], s, m, d, kvd, r);
    drop((h1, h_q, h_k, h_v));

    let (cos, sin) = rope_tables(n, hd);
    let q4 = apply_rope(
        ks, &split_heads(ks, &q2d, b, n, hh, hd), b, hh, n, hd, &cos, &sin, false,
    );
    let k4 = apply_rope(
        ks, &split_heads(ks, &k2d, b, n, kv, hd), b, kv, n, hd, &cos, &sin, false,
    );
    let v4 = split_heads(ks, &v2d, b, n, kv, hd);
    drop((q2d, k2d, v2d));

    let (attn_out, probs) = attention_fwd(ks, &q4, &k4, &v4, b, hh, kv, n, hd);
    drop((q4, k4, v4, probs));
    let attn_flat = merge_heads(ks, &attn_out, b, hh, n, hd);
    drop(attn_out);

    let (o2d, h_o) = lora_fwd(ks, &attn_flat, frozen[WO], lora[6], lora[7], s, m, qd, d, r);
    drop((attn_flat, h_o));
    let x2 = added(ks, x, &o2d);
    drop(o2d);

    let h2 = rmsnorm(ks, &x2, frozen[LN2].f32(), d);
    let (gate_out, h_gate) = lora_fwd(ks, &h2, frozen[WG], lora[8], lora[9], s, m, d, ff, r);
    let (up_out, h_up) = lora_fwd(ks, &h2, frozen[WU], lora[10], lora[11], s, m, d, ff, r);
    drop((h2, h_gate, h_up));
    let silu_out = silu_mul(ks, &gate_out, &up_out);
    drop((gate_out, up_out));
    let (d2d, h_down) = lora_fwd(ks, &silu_out, frozen[WD], lora[12], lora[13], s, m, ff, d, r);
    drop((silu_out, h_down));
    let y = added(ks, &x2, &d2d);
    drop((x2, d2d));
    y
}

/// Borrowed view of whichever intermediates exist (recomputed or
/// retrieved from host-held residuals).
pub struct BwdCtx<'a> {
    pub x2d: &'a [f32],
    pub h1: &'a [f32],
    pub h2: &'a [f32],
    pub x2: &'a [f32],
    pub q_rope: &'a [f32],
    pub k_rope: &'a [f32],
    pub v_heads: &'a [f32],
    pub probs: &'a [f32],
    pub attn_flat: &'a [f32],
    pub gate_out: &'a [f32],
    pub up_out: &'a [f32],
    pub silu_out: &'a [f32],
}

/// What the backward reads its intermediates from.
///
/// * `Owned` — the fused-recompute path (MeSP / store-h): the backward
///   OWNS the just-recomputed [`BlockCache`] and releases every tensor
///   back to the arena the moment its VJP consumed it — the paper's
///   "explicitly deallocate all intermediates" discipline. This is what
///   keeps the fused path's tracked scratch peak near the minimal set
///   instead of the full residual set.
/// * `Borrowed` — the MeBP residual path: intermediates are host-held
///   tensors owned by the caller; release is a no-op.
pub enum BwdSource<'a> {
    Owned(Box<BlockCache>),
    Borrowed(BwdCtx<'a>),
}

macro_rules! bwd_field {
    ($name:ident) => {
        fn $name(&self) -> &[f32] {
            match self {
                BwdSource::Owned(c) => &c.$name[..],
                BwdSource::Borrowed(b) => b.$name,
            }
        }
    };
}

impl BwdSource<'_> {
    bwd_field!(x2d);
    bwd_field!(h1);
    bwd_field!(h2);
    bwd_field!(x2);
    bwd_field!(q_rope);
    bwd_field!(k_rope);
    bwd_field!(v_heads);
    bwd_field!(probs);
    bwd_field!(attn_flat);
    bwd_field!(gate_out);
    bwd_field!(up_out);
    bwd_field!(silu_out);

    /// Free one owned cache tensor now (no-op for borrowed residuals).
    /// The selector must be a plain field projection, e.g.
    /// `src.release(|c| &mut c.silu_out)`.
    fn release(&mut self, field: fn(&mut BlockCache) -> &mut ScratchBuf) {
        if let BwdSource::Owned(c) = self {
            field(c).release();
        }
    }
}

/// The paper's Appendix-A backward, shared by the mesp / storeh /
/// residuals variants. `stored_h` (PROJS order) switches `dB` to
/// stored-h mode (Table 5 / MeBP residuals).
/// Returns `(g_x [m,d], 14 LoRA grads in (dA, dB) × PROJS order)`.
pub fn block_backward(
    ks: &Kernels,
    dims: &ModelDims,
    g_y: &[f32],
    mut src: BwdSource,
    frozen: &[FrozenW],
    lora: &[&[f32]],
    stored_h: Option<&[&[f32]]>,
) -> (ScratchBuf, Vec<ScratchBuf>) {
    let (b, n, d) = (dims.batch, dims.seq, dims.d_model);
    let (hh, kv, hd, ff, r) = (
        dims.n_heads,
        dims.n_kv_heads,
        dims.head_dim,
        dims.d_ff,
        dims.rank,
    );
    let m = b * n;
    let s = dims.scale();
    let (qd, kvd) = (dims.q_dim(), dims.kv_dim());
    let sh = |p: usize| stored_h.map(|hs| hs[p]);

    // The backward never reads y, and reads h = xA only via `stored_h`:
    // an owned cache can shed both up front.
    if let BwdSource::Owned(c) = &mut src {
        c.y.release();
        for h in &mut c.hs {
            h.release();
        }
    }

    // y = x2 + down(silu_mul(gate(h2), up(h2)))
    let (g_silu, da_down, db_down) = lora_bwd(
        ks, src.silu_out(), g_y, frozen[WD], lora[12], lora[13], s, m, ff, d, r, sh(6),
    );
    src.release(|c| &mut c.silu_out);
    let (g_gate, g_up) = silu_mul_bwd(ks, src.gate_out(), src.up_out(), &g_silu);
    drop(g_silu);
    src.release(|c| &mut c.gate_out);
    src.release(|c| &mut c.up_out);
    let (g_h2_a, da_gate, db_gate) = lora_bwd(
        ks, src.h2(), &g_gate, frozen[WG], lora[8], lora[9], s, m, d, ff, r, sh(4),
    );
    let (g_h2_b, da_up, db_up) = lora_bwd(
        ks, src.h2(), &g_up, frozen[WU], lora[10], lora[11], s, m, d, ff, r, sh(5),
    );
    drop((g_gate, g_up));
    src.release(|c| &mut c.h2);
    let mut g_x2 = ks.arena().take_from(g_y);
    add_into(
        &mut g_x2,
        &rmsnorm_bwd(ks, src.x2(), frozen[LN2].f32(), &added(ks, &g_h2_a, &g_h2_b), d),
    );
    drop((g_h2_a, g_h2_b));
    src.release(|c| &mut c.x2);

    // x2 = x + o(attn_flat)
    let (g_attn_flat, da_o, db_o) = lora_bwd(
        ks, src.attn_flat(), &g_x2, frozen[WO], lora[6], lora[7], s, m, qd, d, r, sh(3),
    );
    src.release(|c| &mut c.attn_flat);
    let g_attn_out = split_heads(ks, &g_attn_flat, b, n, hh, hd);
    drop(g_attn_flat);

    let (g_q4, g_k4, g_v4) = attention_bwd(
        ks, src.q_rope(), src.k_rope(), src.v_heads(), src.probs(), &g_attn_out, b, hh, kv, n, hd,
    );
    drop(g_attn_out);
    src.release(|c| &mut c.q_rope);
    src.release(|c| &mut c.k_rope);
    src.release(|c| &mut c.v_heads);
    src.release(|c| &mut c.probs);

    let (cos, sin) = rope_tables(n, hd);
    let g_q2d = merge_heads(
        ks, &apply_rope(ks, &g_q4, b, hh, n, hd, &cos, &sin, true), b, hh, n, hd,
    );
    let g_k2d = merge_heads(
        ks, &apply_rope(ks, &g_k4, b, kv, n, hd, &cos, &sin, true), b, kv, n, hd,
    );
    let g_v2d = merge_heads(ks, &g_v4, b, kv, n, hd);
    drop((g_q4, g_k4, g_v4));

    let (g_h1_q, da_q, db_q) = lora_bwd(
        ks, src.h1(), &g_q2d, frozen[WQ], lora[0], lora[1], s, m, d, qd, r, sh(0),
    );
    let (g_h1_k, da_k, db_k) = lora_bwd(
        ks, src.h1(), &g_k2d, frozen[WK], lora[2], lora[3], s, m, d, kvd, r, sh(1),
    );
    let (g_h1_v, da_v, db_v) = lora_bwd(
        ks, src.h1(), &g_v2d, frozen[WV], lora[4], lora[5], s, m, d, kvd, r, sh(2),
    );
    drop((g_q2d, g_k2d, g_v2d));
    src.release(|c| &mut c.h1);

    let mut g_h1 = added(ks, &g_h1_q, &g_h1_k);
    add_into(&mut g_h1, &g_h1_v);
    drop((g_h1_q, g_h1_k, g_h1_v));
    let mut g_x = g_x2;
    add_into(&mut g_x, &rmsnorm_bwd(ks, src.x2d(), frozen[LN1].f32(), &g_h1, d));

    let grads = vec![
        da_q, db_q, da_k, db_k, da_v, db_v, da_o, db_o, da_gate, db_gate,
        da_up, db_up, da_down, db_down,
    ];
    (g_x, grads)
}

// ------------------------------------------------------------- loss head
//
// Two implementations of the tied-lm-head CE loss share this section:
//
// * the **unchunked oracle** ([`lm_loss`], [`lm_loss_grad`]) — project
//   the full `[m, vocab]` logits, then walk the rows. Its scratch peak is
//   2× logits in the grad path (logits + g_logits live together); it is
//   kept verbatim as the bitwise test oracle, the same pattern as the
//   naive GEMM kernel vs the tiled/SIMD ones.
// * the **chunked path** ([`lm_loss_chunked`], [`lm_loss_grad_chunked`])
//   — stream the sequence dimension in tiles of `chunk` rows, forming
//   the CE gradient *in place over the chunk's logits buffer* and
//   contracting it back to `g_hn[chunk]` before the next tile projects.
//   Only `chunk × vocab` logits floats ever live.
//
// **Bitwise-parity scope.** Chunked ≡ unchunked bitwise *within one
// kernel kind/ISA* because every operation involved is row-local with an
// accumulation order the chunking cannot perturb: RMSNorm (fwd and bwd)
// normalizes each row independently; each GEMM output row sums its k
// terms in an order fixed by the kernel's k-blocking, never by how many
// rows the call carries; and the f64 loss accumulator visits rows
// 0..m in the same order whether or not chunk boundaries intervene.
// Across kernel kinds the usual float-tolerance caveat applies — exactly
// as for the block math above.

/// Per-row softmax-CE statistics, shared by the fwd and grad paths (and
/// by both the oracle and the chunked loop). Validates at the artifact
/// boundary: an out-of-range target id or a non-finite logit is a data /
/// numerics error that must fail loudly, not index-panic (targets) or
/// launder a poisoned forward into a plausible finite loss (`f32::max`
/// prefers its non-NaN argument, so a max-fold silently drops NaNs).
struct RowCe {
    mx: f32,
    denom: f64,
    logz: f64,
    /// Validated target index within the row.
    t: usize,
}

fn ce_row(row: &[f32], target: i32, pos: usize) -> anyhow::Result<RowCe> {
    let v = row.len();
    anyhow::ensure!(
        target >= 0 && (target as usize) < v,
        "target id {target} at position {pos} is outside the vocab (0..{v})"
    );
    let mut mx = f32::NEG_INFINITY;
    for (j, &l) in row.iter().enumerate() {
        anyhow::ensure!(
            l.is_finite(),
            "non-finite logit {l} at row {pos}, vocab index {j}: \
             the forward pass produced a poisoned activation"
        );
        // Identical to a max-fold for the finite values this admits.
        if l > mx {
            mx = l;
        }
    }
    let mut denom = 0.0f64;
    for &l in row {
        denom += ((l - mx) as f64).exp();
    }
    Ok(RowCe { mx, denom, logz: mx as f64 + denom.ln(), t: target as usize })
}

/// Overwrite one logits row with its softmax-CE gradient,
/// `(softmax - onehot) / m`. Each element is read before it is written,
/// so this is genuinely in place — the property the chunked path (and
/// `memory::model`'s `loss_head` term) relies on.
fn ce_grad_row_inplace(row: &mut [f32], ce: &RowCe, m: usize) {
    for (j, l) in row.iter_mut().enumerate() {
        let p = (((*l - ce.mx) as f64).exp() / ce.denom) as f32;
        let onehot = if j == ce.t { 1.0 } else { 0.0 };
        *l = (p - onehot) / m as f32;
    }
}

/// Tied-lm-head logits: `hn = rmsnorm(h)`, `logits = hn @ embᵀ`.
fn lm_logits(
    ks: &Kernels,
    h2d: &[f32],
    norm_w: &[f32],
    emb: &[f32],
    m: usize,
    d: usize,
    v: usize,
) -> ScratchBuf {
    let hn = rmsnorm(ks, h2d, norm_w, d);
    ks.matmul_bt(&hn, emb, m, d, v)
}

/// Mean causal-LM cross-entropy (targets pre-shifted by the data
/// pipeline). Accumulated in f64 for SPSA-grade precision. Unchunked
/// oracle: materializes the full `[m, vocab]` logits.
#[allow(clippy::too_many_arguments)]
pub fn lm_loss(
    ks: &Kernels,
    h2d: &[f32],
    norm_w: &[f32],
    emb: &[f32],
    targets: &[i32],
    m: usize,
    d: usize,
    v: usize,
) -> anyhow::Result<f64> {
    let logits = lm_logits(ks, h2d, norm_w, emb, m, d, v);
    let mut loss = 0.0f64;
    for i in 0..m {
        let row = &logits[i * v..(i + 1) * v];
        let ce = ce_row(row, targets[i], i)?;
        loss += ce.logz - row[ce.t] as f64;
    }
    Ok(loss / m as f64)
}

/// Loss + manual backward to `g_h` (softmax-CE grad, then the lm-head and
/// final-RMSNorm VJPs — no autodiff anywhere). Unchunked oracle: `logits`
/// and `g_logits` are live together, so the scratch peak is 2× logits —
/// `memory::model` charges the second buffer under its backend-extra
/// term. `--loss-chunk` routes to [`lm_loss_grad_chunked`] instead.
#[allow(clippy::too_many_arguments)]
pub fn lm_loss_grad(
    ks: &Kernels,
    h2d: &[f32],
    norm_w: &[f32],
    emb: &[f32],
    targets: &[i32],
    m: usize,
    d: usize,
    v: usize,
) -> anyhow::Result<(f64, ScratchBuf)> {
    let logits = lm_logits(ks, h2d, norm_w, emb, m, d, v);
    let mut loss = 0.0f64;
    let mut g_logits = ks.arena().take(m * v);
    for i in 0..m {
        let row = &logits[i * v..(i + 1) * v];
        let ce = ce_row(row, targets[i], i)?;
        loss += ce.logz - row[ce.t] as f64;
        let grow = &mut g_logits[i * v..(i + 1) * v];
        grow.copy_from_slice(row);
        ce_grad_row_inplace(grow, &ce, m);
    }
    drop(logits);
    let g_hn = ks.matmul(&g_logits, emb, m, v, d);
    let g_h = rmsnorm_bwd(ks, h2d, norm_w, &g_hn, d);
    Ok((loss / m as f64, g_h))
}

/// Chunked forward loss: identical f64 accumulation order to [`lm_loss`]
/// (rows 0..m in order), but only `chunk × vocab` logits live at a time.
#[allow(clippy::too_many_arguments)]
pub fn lm_loss_chunked(
    ks: &Kernels,
    h2d: &[f32],
    norm_w: &[f32],
    emb: &[f32],
    targets: &[i32],
    m: usize,
    d: usize,
    v: usize,
    chunk: usize,
) -> anyhow::Result<f64> {
    let chunk = chunk.clamp(1, m.max(1));
    let mut loss = 0.0f64;
    let mut c0 = 0;
    while c0 < m {
        let c = chunk.min(m - c0);
        let mut sp = ks.trace().span("loss_chunk", "loss");
        sp.arg("start", crate::util::Json::Num(c0 as f64));
        sp.arg("rows", crate::util::Json::Num(c as f64));
        let hn_c = rmsnorm(ks, &h2d[c0 * d..(c0 + c) * d], norm_w, d);
        let logits_c = ks.matmul_bt(&hn_c, emb, c, d, v);
        drop(hn_c);
        for i in 0..c {
            let row = &logits_c[i * v..(i + 1) * v];
            let ce = ce_row(row, targets[c0 + i], c0 + i)?;
            loss += ce.logz - row[ce.t] as f64;
        }
        c0 += c;
    }
    Ok(loss / m as f64)
}

/// Chunked loss + backward to `g_h`. Per tile: project the chunk's
/// logits, accumulate CE in f64, overwrite the chunk's logits buffer with
/// its softmax-CE gradient **in place**, and immediately contract to
/// `g_hn[chunk]` — the full `[m, vocab]` g_logits of the oracle never
/// exists. The persistent state across tiles is the `[m, d]` g_hn;
/// the final RMSNorm VJP runs once over the whole sequence, exactly as
/// in the oracle, so the result is bitwise identical (see the module
/// parity note above).
#[allow(clippy::too_many_arguments)]
pub fn lm_loss_grad_chunked(
    ks: &Kernels,
    h2d: &[f32],
    norm_w: &[f32],
    emb: &[f32],
    targets: &[i32],
    m: usize,
    d: usize,
    v: usize,
    chunk: usize,
) -> anyhow::Result<(f64, ScratchBuf)> {
    let chunk = chunk.clamp(1, m.max(1));
    let mut g_hn = ks.arena().take(m * d);
    let mut loss = 0.0f64;
    let mut c0 = 0;
    while c0 < m {
        let c = chunk.min(m - c0);
        let mut sp = ks.trace().span("loss_chunk", "loss");
        sp.arg("start", crate::util::Json::Num(c0 as f64));
        sp.arg("rows", crate::util::Json::Num(c as f64));
        let hn_c = rmsnorm(ks, &h2d[c0 * d..(c0 + c) * d], norm_w, d);
        let mut logits_c = ks.matmul_bt(&hn_c, emb, c, d, v);
        drop(hn_c);
        for i in 0..c {
            let row = &mut logits_c[i * v..(i + 1) * v];
            let ce = ce_row(row, targets[c0 + i], c0 + i)?;
            loss += ce.logz - row[ce.t] as f64;
            ce_grad_row_inplace(row, &ce, m);
        }
        let g_hn_c = ks.matmul(&logits_c, emb, c, v, d);
        logits_c.release();
        g_hn[c0 * d..(c0 + c) * d].copy_from_slice(&g_hn_c);
        c0 += c;
    }
    let g_h = rmsnorm_bwd(ks, h2d, norm_w, &g_hn, d);
    drop(g_hn);
    Ok((loss / m as f64, g_h))
}

/// Token embedding lookup: `tokens: [m] i32`, `emb: [V, d]` → `[m, d]`.
/// Plain `Vec` — the result is an artifact output, not scratch. Token ids
/// are validated here, at the artifact boundary, so a corrupt batch
/// reports the offending position instead of index-panicking.
pub fn embed_fwd(tokens: &[i32], emb: &[f32], d: usize) -> anyhow::Result<Vec<f32>> {
    let vocab = emb.len() / d;
    let mut out = vec![0.0f32; tokens.len() * d];
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            t >= 0 && (t as usize) < vocab,
            "token id {t} at position {i} is outside the embedding vocab (0..{vocab})"
        );
        let t = t as usize;
        out[i * d..(i + 1) * d].copy_from_slice(&emb[t * d..(t + 1) * d]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
        rng.normal_vec(n, std)
    }

    fn ks() -> Kernels {
        Kernels::for_tests()
    }

    #[test]
    fn matmul_identity() {
        // A @ I == A, and transposed variants agree with matmul
        let ks = ks();
        let mut rng = Rng::new(1);
        let a = randv(&mut rng, 3 * 4, 1.0);
        let mut eye = vec![0.0f32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1.0;
        }
        assert_eq!(&ks.matmul(&a, &eye, 3, 4, 4)[..], &a[..]);
        let b = randv(&mut rng, 4 * 5, 1.0);
        let c = ks.matmul(&a, &b, 3, 4, 5);
        // (aᵀ)ᵀ b via matmul_at on a manually transposed a
        let mut at = vec![0.0f32; 12];
        for i in 0..3 {
            for j in 0..4 {
                at[j * 3 + i] = a[i * 4 + j];
            }
        }
        let c2 = ks.matmul_at(&at, &b, 4, 3, 5);
        for (x, y) in c.iter().zip(&c2[..]) {
            assert!((x - y).abs() < 1e-5);
        }
        // a @ bᵀ via matmul_bt on manually transposed b
        let mut bt = vec![0.0f32; 20];
        for i in 0..4 {
            for j in 0..5 {
                bt[j * 4 + i] = b[i * 5 + j];
            }
        }
        let c3 = ks.matmul_bt(&a, &bt, 3, 4, 5);
        for (x, y) in c.iter().zip(&c3[..]) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn rmsnorm_bwd_matches_finite_difference() {
        let ks = ks();
        let mut rng = Rng::new(2);
        let (m, d) = (3, 8);
        let x = randv(&mut rng, m * d, 1.0);
        let w = randv(&mut rng, d, 0.5);
        let g = randv(&mut rng, m * d, 1.0);
        let analytic = rmsnorm_bwd(&ks, &x, &w, &g, d);
        let eps = 1e-2f32;
        for idx in [0, 5, m * d - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let lp: f64 = rmsnorm(&ks, &xp, &w, d).iter().zip(&g)
                .map(|(y, gg)| (*y as f64) * (*gg as f64)).sum();
            let lm: f64 = rmsnorm(&ks, &xm, &w, d).iter().zip(&g)
                .map(|(y, gg)| (*y as f64) * (*gg as f64)).sum();
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - analytic[idx]).abs() < 2e-2 * analytic[idx].abs().max(1.0),
                "idx {idx}: fd {fd} vs analytic {}",
                analytic[idx]
            );
        }
    }

    #[test]
    fn silu_mul_bwd_matches_finite_difference() {
        let ks = ks();
        let mut rng = Rng::new(3);
        let n = 16;
        let gate = randv(&mut rng, n, 1.0);
        let up = randv(&mut rng, n, 1.0);
        let g = randv(&mut rng, n, 1.0);
        let (dg, du) = silu_mul_bwd(&ks, &gate, &up, &g);
        let eps = 1e-2f32;
        for idx in [0, 7, 15] {
            let mut gp = gate.clone();
            gp[idx] += eps;
            let mut gm = gate.clone();
            gm[idx] -= eps;
            let f = |gv: &[f32]| -> f64 {
                silu_mul(&ks, gv, &up).iter().zip(&g)
                    .map(|(y, gg)| (*y as f64) * (*gg as f64)).sum()
            };
            let fd = ((f(&gp) - f(&gm)) / (2.0 * eps as f64)) as f32;
            assert!((fd - dg[idx]).abs() < 2e-2 * dg[idx].abs().max(1.0));
            // up is linear: exact
            let expect = g[idx] * gate[idx] / (1.0 + (-gate[idx]).exp());
            assert!((du[idx] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_inverse_is_inverse() {
        let ks = ks();
        let mut rng = Rng::new(4);
        let (b, h, n, hd) = (1, 2, 8, 8);
        let x = randv(&mut rng, b * h * n * hd, 1.0);
        let (cos, sin) = rope_tables(n, hd);
        let fwd = apply_rope(&ks, &x, b, h, n, hd, &cos, &sin, false);
        let back = apply_rope(&ks, &fwd, b, h, n, hd, &cos, &sin, true);
        for (a, c) in x.iter().zip(&back[..]) {
            assert!((a - c).abs() < 1e-5, "{a} vs {c}");
        }
    }

    #[test]
    fn split_merge_heads_roundtrip() {
        let ks = ks();
        let mut rng = Rng::new(5);
        let (b, n, h, hd) = (2, 4, 3, 5);
        let x = randv(&mut rng, b * n * h * hd, 1.0);
        let back = merge_heads(&ks, &split_heads(&ks, &x, b, n, h, hd), b, h, n, hd);
        assert_eq!(&x[..], &back[..]);
    }

    #[test]
    fn attention_probs_are_causal_rows() {
        let ks = ks();
        let mut rng = Rng::new(6);
        let (b, h, kv, n, hd) = (1, 4, 2, 6, 4);
        let q = randv(&mut rng, b * h * n * hd, 1.0);
        let k = randv(&mut rng, b * kv * n * hd, 1.0);
        let v = randv(&mut rng, b * kv * n * hd, 1.0);
        let (_, probs) = attention_fwd(&ks, &q, &k, &v, b, h, kv, n, hd);
        for hh in 0..h {
            for i in 0..n {
                let row = &probs[(hh * n + i) * n..(hh * n + i + 1) * n];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row sums to {sum}");
                for (j, p) in row.iter().enumerate() {
                    if j > i {
                        assert_eq!(*p, 0.0, "future position leaked");
                    }
                }
            }
        }
    }

    #[test]
    fn attention_bwd_matches_finite_difference() {
        let ks = ks();
        let mut rng = Rng::new(7);
        let (b, h, kv, n, hd) = (1, 2, 1, 4, 4);
        let q = randv(&mut rng, b * h * n * hd, 0.5);
        let k = randv(&mut rng, b * kv * n * hd, 0.5);
        let v = randv(&mut rng, b * kv * n * hd, 0.5);
        let g = randv(&mut rng, b * h * n * hd, 1.0);
        let (_, probs) = attention_fwd(&ks, &q, &k, &v, b, h, kv, n, hd);
        let (dq, dk, dv) = attention_bwd(&ks, &q, &k, &v, &probs, &g, b, h, kv, n, hd);
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            let (o, _) = attention_fwd(&ks, q, k, v, b, h, kv, n, hd);
            o.iter().zip(&g).map(|(y, gg)| (*y as f64) * (*gg as f64)).sum()
        };
        let eps = 1e-2f32;
        let check = |name: &str, fd: f32, an: f32| {
            assert!(
                (fd - an).abs() < 3e-2 * an.abs().max(0.5),
                "{name}: fd {fd} vs analytic {an}"
            );
        };
        for idx in [0, 9] {
            let mut qp = q.clone();
            qp[idx] += eps;
            let mut qm = q.clone();
            qm[idx] -= eps;
            check("dq",
                  ((loss(&qp, &k, &v) - loss(&qm, &k, &v)) / (2.0 * eps as f64)) as f32,
                  dq[idx]);
            let mut kp = k.clone();
            kp[idx] += eps;
            let mut km = k.clone();
            km[idx] -= eps;
            check("dk",
                  ((loss(&q, &kp, &v) - loss(&q, &km, &v)) / (2.0 * eps as f64)) as f32,
                  dk[idx]);
            let mut vp = v.clone();
            vp[idx] += eps;
            let mut vm = v.clone();
            vm[idx] -= eps;
            check("dv",
                  ((loss(&q, &k, &vp) - loss(&q, &k, &vm)) / (2.0 * eps as f64)) as f32,
                  dv[idx]);
        }
    }

    #[test]
    fn lm_loss_grad_matches_finite_difference() {
        let ks = ks();
        let mut rng = Rng::new(8);
        let (m, d, v) = (4, 8, 16);
        let h = randv(&mut rng, m * d, 0.5);
        let w = vec![1.0f32; d];
        let emb = randv(&mut rng, v * d, 0.2);
        let targets: Vec<i32> = (0..m).map(|i| (i * 3 % v) as i32).collect();
        let (loss, g_h) =
            lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v).unwrap();
        let loss2 = lm_loss(&ks, &h, &w, &emb, &targets, m, d, v).unwrap();
        assert!((loss - loss2).abs() < 1e-9, "fwd and grad paths disagree");
        let eps = 1e-2f32;
        for idx in [0, 17, m * d - 1] {
            let mut hp = h.clone();
            hp[idx] += eps;
            let mut hm = h.clone();
            hm[idx] -= eps;
            let fd = ((lm_loss(&ks, &hp, &w, &emb, &targets, m, d, v).unwrap()
                - lm_loss(&ks, &hm, &w, &emb, &targets, m, d, v).unwrap())
                / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g_h[idx]).abs() < 2e-2 * g_h[idx].abs().max(0.1),
                "idx {idx}: fd {fd} vs analytic {}",
                g_h[idx]
            );
        }
    }

    #[test]
    fn chunked_loss_bitwise_matches_unchunked_oracle() {
        // The tentpole's parity claim: streaming the loss head in tiles
        // of any size — 1, ragged, exactly m, larger than m — reproduces
        // the oracle BITWISE within one kernel kind/ISA (see the module
        // parity note). Sweep every micro-kernel ISA; unsupported ones
        // fall back to the detected best, which still exercises the
        // chunked-vs-oracle comparison on that engine.
        use super::super::kernels::{simd, KernelOptions};
        let mut rng = Rng::new(11);
        let (m, d, v) = (6, 8, 32);
        let h = randv(&mut rng, m * d, 0.5);
        let w = randv(&mut rng, d, 0.5).iter().map(|x| 1.0 + x).collect::<Vec<_>>();
        let emb = randv(&mut rng, v * d, 0.2);
        let targets: Vec<i32> = (0..m).map(|i| (i * 5 % v) as i32).collect();
        for isa in simd::Isa::ALL {
            let ks = Kernels::new(
                KernelOptions { kind: crate::config::KernelKind::Tiled, threads: 1 },
                crate::memory::MemoryTracker::new(),
            )
            .with_isa(isa);
            let (loss_o, g_o) =
                lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v).unwrap();
            for chunk in [1, 3, 4, m, m + 5] {
                let loss_c = lm_loss_chunked(
                    &ks, &h, &w, &emb, &targets, m, d, v, chunk,
                ).unwrap();
                assert_eq!(
                    loss_o.to_bits(), loss_c.to_bits(),
                    "fwd loss bits differ at chunk {chunk} ({})", isa.name()
                );
                let (loss_g, g_c) = lm_loss_grad_chunked(
                    &ks, &h, &w, &emb, &targets, m, d, v, chunk,
                ).unwrap();
                assert_eq!(loss_o.to_bits(), loss_g.to_bits());
                for (i, (a, b)) in g_o.iter().zip(&g_c[..]).enumerate() {
                    assert_eq!(
                        a.to_bits(), b.to_bits(),
                        "g_h[{i}] differs at chunk {chunk} ({}): {a} vs {b}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn loss_rejects_out_of_range_targets_naming_position() {
        let ks = ks();
        let mut rng = Rng::new(12);
        let (m, d, v) = (4, 8, 16);
        let h = randv(&mut rng, m * d, 0.5);
        let w = vec![1.0f32; d];
        let emb = randv(&mut rng, v * d, 0.2);
        let mut targets: Vec<i32> = vec![0; m];
        targets[2] = 99;
        for err in [
            lm_loss(&ks, &h, &w, &emb, &targets, m, d, v).unwrap_err(),
            lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v)
                .map(|_| ()).unwrap_err(),
            lm_loss_chunked(&ks, &h, &w, &emb, &targets, m, d, v, 3)
                .map(|_| ()).unwrap_err(),
            lm_loss_grad_chunked(&ks, &h, &w, &emb, &targets, m, d, v, 3)
                .map(|_| ()).unwrap_err(),
        ] {
            let msg = err.to_string();
            assert!(
                msg.contains("target id 99 at position 2"),
                "error must name the id and position: {msg}"
            );
        }
        targets[2] = -1;
        let msg = lm_loss(&ks, &h, &w, &emb, &targets, m, d, v)
            .unwrap_err().to_string();
        assert!(msg.contains("target id -1 at position 2"), "{msg}");
    }

    #[test]
    fn loss_rejects_non_finite_logits_naming_the_row() {
        // A NaN logit used to be silently dropped by the max-fold
        // (f32::max prefers its non-NaN argument) and laundered into a
        // plausible finite loss. A poisoned embedding row makes every
        // logits row non-finite; the error must name row 0, not succeed.
        let ks = ks();
        let mut rng = Rng::new(13);
        let (m, d, v) = (4, 8, 16);
        let h = randv(&mut rng, m * d, 0.5);
        let w = vec![1.0f32; d];
        let mut emb = randv(&mut rng, v * d, 0.2);
        emb[3] = f32::INFINITY;
        let targets: Vec<i32> = vec![0; m];
        for err in [
            lm_loss(&ks, &h, &w, &emb, &targets, m, d, v).unwrap_err(),
            lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v)
                .map(|_| ()).unwrap_err(),
            lm_loss_grad_chunked(&ks, &h, &w, &emb, &targets, m, d, v, 2)
                .map(|_| ()).unwrap_err(),
        ] {
            let msg = err.to_string();
            assert!(msg.contains("non-finite logit"), "{msg}");
            assert!(msg.contains("row 0"), "must name the first bad row: {msg}");
        }
    }

    #[test]
    fn embed_fwd_rejects_bad_token_ids_naming_position() {
        let emb = vec![0.5f32; 4 * 3]; // vocab 4, d 3
        assert!(embed_fwd(&[0, 3, 1], &emb, 3).is_ok());
        let msg = embed_fwd(&[0, 5], &emb, 3).unwrap_err().to_string();
        assert!(msg.contains("token id 5 at position 1"), "{msg}");
        let msg = embed_fwd(&[-2], &emb, 3).unwrap_err().to_string();
        assert!(msg.contains("token id -2 at position 0"), "{msg}");
    }

    #[test]
    fn loss_scratch_peak_within_model_loss_head() {
        // Satellite regression for the mis-modeled loss-head peak: the
        // tracked scratch during the loss phase — oracle (2× logits at
        // its worst moment) AND chunked — must stay within the
        // analytical loss_head term at tracked widths. Naive 1-thread
        // kernels so no packing panels ride on the tag.
        use super::super::kernels::KernelOptions;
        use crate::memory::{model as memmodel, MemoryTracker, Widths};
        let dims = crate::config::presets::compiled("toy").unwrap();
        let (m, d, v) = (dims.m(), dims.d_model, dims.vocab);
        let mut rng = Rng::new(14);
        let h = randv(&mut rng, m * d, 0.5);
        let w = vec![1.0f32; d];
        let emb = randv(&mut rng, v * d, 0.2);
        let targets: Vec<i32> = (0..m).map(|i| (i % v) as i32).collect();
        let run = |chunk: usize| -> u64 {
            let tracker = MemoryTracker::new();
            let ks = Kernels::new(
                KernelOptions { kind: crate::config::KernelKind::Naive, threads: 1 },
                tracker.clone(),
            );
            let r = match chunk {
                0 => lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v),
                c => lm_loss_grad_chunked(&ks, &h, &w, &emb, &targets, m, d, v, c),
            };
            r.unwrap();
            tracker.tag_peak("scratch")
        };
        let budget = |chunk: usize| {
            memmodel::peak_opts(
                crate::config::Method::Mesp, &dims,
                crate::config::OptimizerKind::Sgd, Widths::tracked(),
                crate::config::QuantMode::F32,
                memmodel::MemOptions { loss_chunk: chunk, ..Default::default() },
            )
            .loss_head
        };
        for chunk in [0, 16] {
            let peak = run(chunk);
            let head = budget(chunk);
            assert!(peak > 0, "loss scratch must be tracked");
            assert!(
                peak <= head,
                "chunk {chunk}: tracked loss scratch {peak} exceeds the \
                 analytical loss_head {head}"
            );
        }
    }

    #[test]
    fn chunking_cuts_loss_scratch_at_least_4x() {
        // The acceptance shape scaled to unit-test dims: a vocab-heavy
        // head (v ≫ d) chunked at m/8 must cut the tracked loss-phase
        // scratch by at least 4×.
        use super::super::kernels::KernelOptions;
        use crate::memory::MemoryTracker;
        let (m, d, v) = (64, 16, 2048);
        let mut rng = Rng::new(15);
        let h = randv(&mut rng, m * d, 0.5);
        let w = vec![1.0f32; d];
        let emb = randv(&mut rng, v * d, 0.2);
        let targets: Vec<i32> = (0..m).map(|i| (i * 7 % v) as i32).collect();
        let run = |chunk: usize| -> u64 {
            let tracker = MemoryTracker::new();
            let ks = Kernels::new(
                KernelOptions { kind: crate::config::KernelKind::Naive, threads: 1 },
                tracker.clone(),
            );
            match chunk {
                0 => lm_loss_grad(&ks, &h, &w, &emb, &targets, m, d, v).unwrap(),
                c => lm_loss_grad_chunked(&ks, &h, &w, &emb, &targets, m, d, v, c)
                    .unwrap(),
            };
            tracker.tag_peak("scratch")
        };
        let (full, chunked) = (run(0), run(8));
        assert!(
            chunked * 4 <= full,
            "chunk 8 must cut loss scratch >=4x: {chunked} vs {full}"
        );
    }

    #[test]
    fn lora_bwd_stored_equals_recomputed() {
        let ks = ks();
        let mut rng = Rng::new(9);
        let (m, din, dout, r) = (6, 8, 10, 4);
        let x = randv(&mut rng, m * din, 0.5);
        let g = randv(&mut rng, m * dout, 0.5);
        let w = randv(&mut rng, din * dout, 0.1);
        let a = randv(&mut rng, din * r, 0.3);
        let bb = randv(&mut rng, r * dout, 0.3);
        let h = ks.matmul(&x, &a, m, din, r);
        let (gx1, da1, db1) = lora_bwd(
            &ks, &x, &g, FrozenW::F32(&w), &a, &bb, 2.0, m, din, dout, r, None,
        );
        let (gx2, da2, db2) = lora_bwd(
            &ks, &x, &g, FrozenW::F32(&w), &a, &bb, 2.0, m, din, dout, r, Some(&h),
        );
        assert_eq!(&gx1[..], &gx2[..]);
        assert_eq!(&da1[..], &da2[..]);
        assert_eq!(&db1[..], &db2[..], "stored h must equal recomputed h exactly");
    }

    #[test]
    fn block_scratch_returns_to_the_arena() {
        // A forward's entire cache is arena scratch: dropping it releases
        // every tracked byte and parks the capacity for the next call.
        let tracker = crate::memory::MemoryTracker::new();
        let ks = Kernels::new(
            super::super::kernels::KernelOptions {
                kind: crate::config::KernelKind::Tiled,
                threads: 1,
            },
            tracker.clone(),
        );
        let d = crate::config::presets::compiled("toy").unwrap();
        let mut rng = Rng::new(10);
        let frozen_v: Vec<Vec<f32>> = crate::config::FROZEN
            .iter()
            .map(|w| randv(&mut rng, d.frozen_shape(w).iter().product(), 0.05))
            .collect();
        let lora_v: Vec<Vec<f32>> = crate::config::PROJS
            .iter()
            .flat_map(|p| {
                let (din, dout) = d.proj_dims(p);
                [randv(&mut rng, din * d.rank, 0.1),
                 randv(&mut rng, d.rank * dout, 0.1)]
            })
            .collect();
        let frozen: Vec<FrozenW> =
            frozen_v.iter().map(|v| FrozenW::F32(v.as_slice())).collect();
        let lora: Vec<&[f32]> = lora_v.iter().map(|v| v.as_slice()).collect();
        let x = randv(&mut rng, d.m() * d.d_model, 0.5);
        {
            let c = block_forward(&ks, &d, &x, &frozen, &lora);
            assert!(tracker.live() > 0, "cache bytes are tracked as scratch");
            assert!(c.y.iter().all(|v| v.is_finite()));
        }
        assert_eq!(tracker.live(), 0, "dropping the cache frees all scratch");
        assert!(tracker.tag_peak("scratch") > 0);
        let before = ks.arena().stats().misses;
        let c2 = block_forward(&ks, &d, &x, &frozen, &lora);
        drop(c2);
        let after = ks.arena().stats();
        assert_eq!(after.misses, before, "second forward allocates nothing new");
    }
}
