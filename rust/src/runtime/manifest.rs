//! Artifact manifest: the ABI contract written by `python/compile/aot.py`
//! (`artifacts/<config>/manifest.json`) — model dims, and for every HLO
//! artifact its positional argument specs and output arity. The runtime
//! validates every call against this before touching PJRT.

use std::path::{Path, PathBuf};

use crate::config::ModelDims;
use crate::tensor::DType;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outputs: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dims: ModelDims,
    pub scale: f32,
    pub param_count: usize,
    pub lora_param_count: usize,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&src)?;
        let c = j.req("config")?;
        let get = |k: &str| -> anyhow::Result<usize> {
            c.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("config.{k} not a number"))
        };
        let dims = ModelDims {
            name: c.req("name")?.as_str().unwrap_or_default().to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            seq: get("seq")?,
            batch: get("batch")?,
            rank: get("rank")?,
            alpha: c.req("alpha")?.as_f64().unwrap_or(16.0) as f32,
        };
        let mut artifacts = Vec::new();
        for (name, spec) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("artifacts not an object"))?
        {
            let mut args = Vec::new();
            for a in spec.req("args")?.as_arr().unwrap_or(&[]) {
                args.push(ArgSpec {
                    name: a.req("name")?.as_str().unwrap_or_default().into(),
                    shape: a
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                    dtype: DType::parse(
                        a.req("dtype")?.as_str().unwrap_or("f32"),
                    )?,
                });
            }
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                file: dir.join(spec.req("file")?.as_str().unwrap_or_default()),
                args,
                outputs: spec.req("outputs")?.as_usize().unwrap_or(1),
            });
        }
        Ok(Manifest {
            dims,
            scale: c.req("scale")?.as_f64().unwrap_or(2.0) as f32,
            param_count: c.req("param_count")?.as_usize().unwrap_or(0),
            lora_param_count: c
                .req("lora_param_count")?
                .as_usize()
                .unwrap_or(0),
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.iter().any(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal manifest.json in the exact schema aot.py emits.
    const TOY_MANIFEST: &str = r#"{
        "config": {
            "name": "toy", "vocab": 256, "d_model": 64, "n_layers": 2,
            "n_heads": 4, "n_kv_heads": 2, "head_dim": 16, "d_ff": 128,
            "seq": 32, "batch": 1, "rank": 4, "alpha": 8.0, "scale": 2.0,
            "param_count": 368000, "lora_param_count": 9216
        },
        "artifacts": {
            "block_bwd_mesp": {
                "file": "block_bwd_mesp.hlo.txt",
                "args": [
                    {"name": "x", "shape": [1, 32, 64], "dtype": "f32"},
                    {"name": "g_y", "shape": [1, 32, 64], "dtype": "f32"}
                ],
                "outputs": 15
            },
            "embed_fwd": {
                "file": "embed_fwd.hlo.txt",
                "args": [
                    {"name": "tokens", "shape": [1, 32], "dtype": "i32"},
                    {"name": "emb", "shape": [256, 64], "dtype": "f32"}
                ],
                "outputs": 1
            }
        }
    }"#;

    /// Per-test dir: parallel test threads must not share one file.
    fn write_manifest(test: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mesp-manifest-{test}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), TOY_MANIFEST).unwrap();
        dir
    }

    #[test]
    fn parses_manifest_schema() {
        let dir = write_manifest("schema");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dims.d_model, 64);
        assert_eq!(m.dims.n_layers, 2);
        assert_eq!(m.dims.alpha, 8.0);
        assert_eq!(m.scale, 2.0);
        assert_eq!(m.lora_param_count, 9216);
        assert!(m.has_artifact("block_bwd_mesp"));
        let bwd = m.artifact("block_bwd_mesp").unwrap();
        assert_eq!(bwd.outputs, 15);
        assert_eq!(bwd.args[0].name, "x");
        assert_eq!(bwd.args[0].shape, vec![1, 32, 64]);
        assert_eq!(bwd.file, dir.join("block_bwd_mesp.hlo.txt"));
        let emb = m.artifact("embed_fwd").unwrap();
        assert_eq!(emb.args[0].dtype, crate::tensor::DType::I32);
    }

    #[test]
    fn missing_artifact_is_error() {
        let dir = write_manifest("missing-artifact");
        let m = Manifest::load(&dir).unwrap();
        let err = m.artifact("nope").unwrap_err();
        assert!(err.to_string().contains("not in manifest"));
    }

    #[test]
    fn missing_dir_is_error() {
        let dir = std::env::temp_dir().join("mesp-manifest-definitely-absent");
        assert!(Manifest::load(&dir).is_err());
    }
}
