//! PJRT runtime layer: artifact manifests + executable cache + tracked
//! execution. The Rust half of the AOT bridge (DESIGN.md §4); Python never
//! runs after `make artifacts`.

pub mod client;
pub mod manifest;

pub use client::{ExecStats, Runtime};
pub use manifest::{ArgSpec, ArtifactSpec, Manifest};
