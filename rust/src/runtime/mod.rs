//! Runtime layer: the pluggable compute-backend abstraction and its two
//! implementations.
//!
//! # The backend trait contract
//!
//! [`Backend`] is the seam between the training coordinator (L3) and
//! whatever executes the math. A backend serves the paper's artifact
//! surface **by name** — `embed_fwd`, `block_fwd`, `block_fwd_saveh`,
//! `block_fwd_residuals`, `block_bwd_mesp`, `block_bwd_storeh`,
//! `block_bwd_residuals`, `lm_loss_fwd`, `lm_loss_grad`, plus the `_q4`
//! twin of every block artifact (int4-packed frozen weights) — with
//! positional arguments in manifest ABI order (leading activations, then
//! the frozen block weights — 9 f32 tensors, or ln1/ln2 + 7 packed/scale
//! pairs on the `_q4` ABI — then the 14 LoRA tensors). Every
//! implementation must:
//!
//! 1. validate host-arg count/shape/dtype against the artifact spec
//!    before computing;
//! 2. produce mathematically identical gradients across the three
//!    backward variants (MeSP's fused recompute ≡ store-h ≡ MeBP's
//!    residual path — the paper's §4 claim, enforced per backend by
//!    `tests/gradcheck.rs`);
//! 3. register transient host-arg bytes of every call with the shared
//!    [`crate::memory::MemoryTracker`] under `exec:<name>` for the
//!    duration of the call, so step peaks include call overhead —
//!    excepting [`backend::Arg::Resident`] borrows of shared frozen
//!    weights, whose bytes are charged once at their owner
//!    (`weights:shared`) rather than per call per session;
//! 4. hold no training state between calls beyond buffers explicitly
//!    created via [`Backend::upload`].
//!
//! # Implementations
//!
//! * [`ReferenceBackend`] (default) — pure Rust, in-process
//!   ([`refmath`] holds the block/loss math with the paper's Appendix-A
//!   manual VJPs, recomputing `h = xA` in the backward; [`kernels`] is
//!   the GEMM engine underneath it — naive oracle / tiled / parallel
//!   variants, an arena for tracked scratch, and FLOP accounting).
//!   Builds and runs from a clean checkout with no XLA toolchain or
//!   Python artifacts.
//! * [`client::Runtime`] (cargo feature `pjrt`) — the PJRT client over
//!   AOT-compiled HLO artifacts described by `manifest.json`
//!   ([`manifest`] is the ABI contract written by
//!   `python/compile/aot.py`).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod kernels;
pub mod manifest;
pub mod reference;
pub mod refmath;

pub use backend::{Arg, Backend, DeviceBuffer, ExecStats};
#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use kernels::{FrozenW, KernelOptions, Kernels, Q4View};
pub use manifest::{ArgSpec, ArtifactSpec, Manifest};
pub use reference::ReferenceBackend;
