//! Cache-blocked, register-tiled GEMM (single thread) — the BLIS-style
//! three-loop blocking around a branch-free mr×nr micro-kernel.
//!
//! Structure: the `n` dimension is split into NC-column slabs, `k` into
//! KC-deep panels, `m` into MC-row panels (MC/KC/NC come from the
//! [`super::tune::Tiles`] the engine was built with — derived from the
//! machine's cache geometry or a persisted `--tune` profile). For each
//! (slab, panel) pair the operands are packed into contiguous
//! zero-padded buffers from the [`TensorArena`] — packing also absorbs
//! the transposed layouts, so one micro-kernel serves `a@b`, `aᵀ@b` and
//! `a@bᵀ` alike. The micro-kernel ([`super::simd::microkernel`],
//! dispatched per detected ISA with the portable scalar kernel as the
//! bitwise oracle) holds an mr×nr accumulator block in registers across
//! the whole KC depth, so C is loaded/stored once per k-panel instead of
//! once per k step (the main win over the naive triple loop). The
//! micro-tile shape is the ISA's (`Isa::mr`/`Isa::nr`) and the packed
//! sliver layout follows it.
//!
//! Determinism: every output element accumulates its k-terms in strictly
//! ascending order (KC panels outer, k ascending inside), independent of
//! the row panel it lands in and of the ISA (all micro-kernels use
//! unfused multiply-then-add) — which is what makes [`super::parallel`]
//! bitwise identical to this kernel at any thread count and every SIMD
//! path bitwise identical to scalar, *at fixed tiles*. KC is the one
//! scheduling choice visible in the bits: each k-panel's partial sum is
//! folded in registers before being added to C, so a different KC
//! regroups the adds whenever `k > KC`. MC/NC/MR/NR never matter — they
//! only partition the output. All parity guarantees are therefore stated
//! per tile profile, which is constant within a process.
//!
//! q4 operands dequantize inside `pack_b` on SIMD lanes
//! ([`super::simd::dequant_run`]), evaluating exactly
//! `quant::dequantize`'s per-element expression.
//!
//! No data-dependent branches: unlike the naive oracle, zero inputs take
//! exactly the same time as dense ones.

use crate::model::quant;
use crate::tensor::TensorArena;

use super::simd::{self, Isa};
use super::tune::{Tiles, MAX_KC};
use super::{AView, BView};

/// `out[m,n] += A[row0..row0+m, :k] @ B[:k, :n]` with `out` zero on
/// entry. `row0` offsets the A rows only (the parallel kernel hands each
/// thread a row window over the same full operands).
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    arena: &TensorArena,
    isa: Isa,
    tiles: Tiles,
    a: AView,
    b: BView,
    row0: usize,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let (mr, nr) = (isa.mr(), isa.nr());
    let (mc_max, kc_lim, nc_max) = (tiles.mc(), tiles.kc(), tiles.nc());
    let mc_pad = mc_max.min(m).next_multiple_of(mr);
    let nc_pad = nc_max.min(n).next_multiple_of(nr);
    let kc_max = kc_lim.min(k);
    let mut apack = arena.take(mc_pad * kc_max);
    let mut bpack = arena.take(kc_max * nc_pad);

    let mut jc = 0;
    while jc < n {
        let nc = nc_max.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = kc_lim.min(k - pc);
            pack_b(&b, isa, k, n, pc, kc, jc, nc, &mut bpack);
            let mut ic = 0;
            while ic < m {
                let mc = mc_max.min(m - ic);
                pack_a(&a, mr, k, row0 + ic, mc, pc, kc, &mut apack);
                macro_kernel(&apack, &bpack, isa, mc, nc, kc, out, ic, jc, n);
                ic += mc_max;
            }
            pc += kc_lim;
        }
        jc += nc_max;
    }
}

/// Pack `A[grow0..grow0+mc, pc..pc+kc]` as mr-row slivers, each laid out
/// `[kc][mr]`, zero-padding the ragged row block.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &AView,
    mr: usize,
    k: usize,
    grow0: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    apack: &mut [f32],
) {
    let mbs = mc.div_ceil(mr);
    for ib in 0..mbs {
        let sliver = &mut apack[ib * kc * mr..(ib + 1) * kc * mr];
        let rows = mr.min(mc - ib * mr);
        match *a {
            AView::Rows(data) => {
                for r in 0..mr {
                    if r < rows {
                        let src = &data[(grow0 + ib * mr + r) * k + pc..][..kc];
                        for (l, &v) in src.iter().enumerate() {
                            sliver[l * mr + r] = v;
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * mr + r] = 0.0;
                        }
                    }
                }
            }
            AView::Cols { data, ld } => {
                for l in 0..kc {
                    let src = &data[(pc + l) * ld + grow0 + ib * mr..];
                    let dst = &mut sliver[l * mr..l * mr + mr];
                    for (r, d) in dst.iter_mut().enumerate() {
                        *d = if r < rows { src[r] } else { 0.0 };
                    }
                }
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` as nr-column slivers, each laid out
/// `[kc][nr]`, zero-padding the ragged column block.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &BView,
    isa: Isa,
    k: usize,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    bpack: &mut [f32],
) {
    let nr = isa.nr();
    let nbs = nc.div_ceil(nr);
    for jb in 0..nbs {
        let sliver = &mut bpack[jb * kc * nr..(jb + 1) * kc * nr];
        let cols = nr.min(nc - jb * nr);
        match *b {
            BView::Rows(data) => {
                for l in 0..kc {
                    let src = &data[(pc + l) * n + jc + jb * nr..];
                    let dst = &mut sliver[l * nr..l * nr + nr];
                    for (c, d) in dst.iter_mut().enumerate() {
                        *d = if c < cols { src[c] } else { 0.0 };
                    }
                }
            }
            BView::Cols(data) => {
                for c in 0..nr {
                    if c < cols {
                        let src = &data[(jc + jb * nr + c) * k + pc..][..kc];
                        for (l, &v) in src.iter().enumerate() {
                            sliver[l * nr + c] = v;
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * nr + c] = 0.0;
                        }
                    }
                }
            }
            // int4 operands dequantize here, inside packing, on SIMD
            // lanes: each panel row goes nibble → sign-extend → ×scale
            // straight into the packed sliver, so no f32 copy of W ever
            // exists beyond the panel (and the dequantized values are
            // bitwise the ones `quant::dequantize` would produce —
            // packing order does not change them, which keeps tiled-q4
            // ≡ parallel-q4 bitwise across every ISA).
            BView::Q4(q) => {
                let col0 = jc + jb * nr;
                for l in 0..kc {
                    let r = pc + l;
                    let dst = &mut sliver[l * nr..l * nr + nr];
                    if cols == nr {
                        // One B row is contiguous bytes across columns.
                        let bytes = &q.packed[(r / 2) * q.dout + col0..][..nr];
                        let scales = &q.scales[(r / quant::GROUP) * q.dout + col0..][..nr];
                        simd::dequant_run(isa, bytes, scales, r % 2 == 1, dst);
                    } else {
                        for (c, d) in dst.iter_mut().enumerate() {
                            *d = if c < cols { q.at(r, col0 + c) } else { 0.0 };
                        }
                    }
                }
            }
            BView::Q4T(q) => {
                // B = Wᵀ: column j of B is row j of the packed matrix —
                // a fixed-nibble, contiguous byte run along k. Dequant
                // the run on SIMD lanes into a stack buffer, then
                // scatter at stride nr into the sliver (KC ≤ MAX_KC by
                // the Tiles invariant).
                debug_assert!(kc <= MAX_KC);
                let mut tmp = [0.0f32; MAX_KC];
                for c in 0..nr {
                    if c < cols {
                        let wr = jc + jb * nr + c;
                        let bytes = &q.packed[(wr / 2) * q.dout + pc..][..kc];
                        let scales = &q.scales[(wr / quant::GROUP) * q.dout + pc..][..kc];
                        simd::dequant_run(isa, bytes, scales, wr % 2 == 1, &mut tmp[..kc]);
                        for (l, &v) in tmp[..kc].iter().enumerate() {
                            sliver[l * nr + c] = v;
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * nr + c] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// `out[ic.., jc..] += Apack @ Bpack` over all micro-tiles of one
/// (MC × NC × KC) block, through the ISA-dispatched micro-kernel.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    apack: &[f32],
    bpack: &[f32],
    isa: Isa,
    mc: usize,
    nc: usize,
    kc: usize,
    out: &mut [f32],
    ic: usize,
    jc: usize,
    n: usize,
) {
    let (mr, nr) = (isa.mr(), isa.nr());
    let mbs = mc.div_ceil(mr);
    let nbs = nc.div_ceil(nr);
    for ib in 0..mbs {
        let ap = &apack[ib * kc * mr..(ib + 1) * kc * mr];
        let rows = mr.min(mc - ib * mr);
        for jb in 0..nbs {
            let bp = &bpack[jb * kc * nr..(jb + 1) * kc * nr];
            let cols = nr.min(nc - jb * nr);
            let origin = (ic + ib * mr) * n + jc + jb * nr;
            simd::microkernel(isa, ap, bp, kc, &mut out[origin..], n, rows, cols);
        }
    }
}
