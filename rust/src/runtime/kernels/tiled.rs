//! Cache-blocked, register-tiled GEMM (single thread) — the BLIS-style
//! three-loop blocking around a branch-free MR×NR micro-kernel.
//!
//! Structure: the `n` dimension is split into NC-column slabs, `k` into
//! KC-deep panels, `m` into MC-row panels. For each (slab, panel) pair
//! the operands are packed into contiguous zero-padded buffers from the
//! [`TensorArena`] — packing also absorbs the transposed layouts, so one
//! micro-kernel serves `a@b`, `aᵀ@b` and `a@bᵀ` alike. The micro-kernel
//! holds an MR×NR accumulator block in registers across the whole KC
//! depth, so C is loaded/stored once per k-panel instead of once per k
//! step (the main win over the naive triple loop).
//!
//! Determinism: every output element accumulates its k-terms in strictly
//! ascending order (KC panels outer, k ascending inside), independent of
//! the row panel it lands in — which is what makes [`super::parallel`]
//! bitwise identical to this kernel at any thread count.
//!
//! No data-dependent branches: unlike the naive oracle, zero inputs take
//! exactly the same time as dense ones.

use crate::tensor::TensorArena;

use super::{AView, BView};

/// Micro-kernel rows (register block height). 6×8 accumulators fit the
/// baseline x86-64 SSE2 register file (12 vector registers of state plus
/// two B loads and an A broadcast) without spilling.
pub const MR: usize = 6;
/// Micro-kernel columns (register block width; kept a small multiple of
/// the f32 SIMD lane count so the inner loop auto-vectorizes).
pub const NR: usize = 8;
/// k-depth of one packed panel.
pub const KC: usize = 256;
/// Rows of one packed A panel.
pub const MC: usize = 64;
/// Columns of one packed B slab.
pub const NC: usize = 128;

/// Upper bound on one `gemm` invocation's packing checkout in f32
/// elements (apack ≤ (MC rounded up to MR)·KC, bpack ≤ KC·NC) —
/// `memory::model`'s scratch term charges this per kernel thread.
pub const PACK_BOUND_ELEMS: usize = (MC + MR) * KC + KC * NC;

/// `out[m,n] += A[row0..row0+m, :k] @ B[:k, :n]` with `out` zero on
/// entry. `row0` offsets the A rows only (the parallel kernel hands each
/// thread a row window over the same full operands).
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    arena: &TensorArena,
    a: AView,
    b: BView,
    row0: usize,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mc_pad = MC.min(m).next_multiple_of(MR);
    let nc_pad = NC.min(n).next_multiple_of(NR);
    let kc_max = KC.min(k);
    let mut apack = arena.take(mc_pad * kc_max);
    let mut bpack = arena.take(kc_max * nc_pad);

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(&b, k, n, pc, kc, jc, nc, &mut bpack);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(&a, k, row0 + ic, mc, pc, kc, &mut apack);
                macro_kernel(&apack, &bpack, mc, nc, kc, out, ic, jc, n);
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// Pack `A[grow0..grow0+mc, pc..pc+kc]` as MR-row slivers, each laid out
/// `[kc][MR]`, zero-padding the ragged row block.
fn pack_a(a: &AView, k: usize, grow0: usize, mc: usize, pc: usize, kc: usize, apack: &mut [f32]) {
    let mbs = mc.div_ceil(MR);
    for ib in 0..mbs {
        let sliver = &mut apack[ib * kc * MR..(ib + 1) * kc * MR];
        let rows = MR.min(mc - ib * MR);
        match *a {
            AView::Rows(data) => {
                for r in 0..MR {
                    if r < rows {
                        let src = &data[(grow0 + ib * MR + r) * k + pc..][..kc];
                        for (l, &v) in src.iter().enumerate() {
                            sliver[l * MR + r] = v;
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * MR + r] = 0.0;
                        }
                    }
                }
            }
            AView::Cols { data, ld } => {
                for l in 0..kc {
                    let src = &data[(pc + l) * ld + grow0 + ib * MR..];
                    let dst = &mut sliver[l * MR..l * MR + MR];
                    for (r, d) in dst.iter_mut().enumerate() {
                        *d = if r < rows { src[r] } else { 0.0 };
                    }
                }
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` as NR-column slivers, each laid out
/// `[kc][NR]`, zero-padding the ragged column block.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &BView,
    k: usize,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    bpack: &mut [f32],
) {
    let nbs = nc.div_ceil(NR);
    for jb in 0..nbs {
        let sliver = &mut bpack[jb * kc * NR..(jb + 1) * kc * NR];
        let cols = NR.min(nc - jb * NR);
        match *b {
            BView::Rows(data) => {
                for l in 0..kc {
                    let src = &data[(pc + l) * n + jc + jb * NR..];
                    let dst = &mut sliver[l * NR..l * NR + NR];
                    for (c, d) in dst.iter_mut().enumerate() {
                        *d = if c < cols { src[c] } else { 0.0 };
                    }
                }
            }
            BView::Cols(data) => {
                for c in 0..NR {
                    if c < cols {
                        let src = &data[(jc + jb * NR + c) * k + pc..][..kc];
                        for (l, &v) in src.iter().enumerate() {
                            sliver[l * NR + c] = v;
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * NR + c] = 0.0;
                        }
                    }
                }
            }
            // int4 operands dequantize here, inside packing: each panel
            // element goes nibble → sign-extend → ×scale straight into
            // the packed sliver, so no f32 copy of W ever exists beyond
            // the panel (and the dequantized values are bitwise the ones
            // `quant::dequantize` would produce — packing order does not
            // change them, which keeps tiled-q4 ≡ parallel-q4 bitwise).
            BView::Q4(q) => {
                for l in 0..kc {
                    let r = pc + l;
                    let dst = &mut sliver[l * NR..l * NR + NR];
                    for (c, d) in dst.iter_mut().enumerate() {
                        *d = if c < cols { q.at(r, jc + jb * NR + c) } else { 0.0 };
                    }
                }
            }
            BView::Q4T(q) => {
                // B = Wᵀ: column j of B is row j of the packed matrix.
                for c in 0..NR {
                    if c < cols {
                        let wr = jc + jb * NR + c;
                        for l in 0..kc {
                            sliver[l * NR + c] = q.at(wr, pc + l);
                        }
                    } else {
                        for l in 0..kc {
                            sliver[l * NR + c] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// `out[ic.., jc..] += Apack @ Bpack` over all micro-tiles of one
/// (MC × NC × KC) block.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    apack: &[f32],
    bpack: &[f32],
    mc: usize,
    nc: usize,
    kc: usize,
    out: &mut [f32],
    ic: usize,
    jc: usize,
    n: usize,
) {
    let mbs = mc.div_ceil(MR);
    let nbs = nc.div_ceil(NR);
    for ib in 0..mbs {
        let ap = &apack[ib * kc * MR..(ib + 1) * kc * MR];
        let rows = MR.min(mc - ib * MR);
        for jb in 0..nbs {
            let bp = &bpack[jb * kc * NR..(jb + 1) * kc * NR];
            let cols = NR.min(nc - jb * NR);
            let mut acc = [[0.0f32; NR]; MR];
            for l in 0..kc {
                let av: &[f32; MR] = ap[l * MR..l * MR + MR].try_into().unwrap();
                let bv: &[f32; NR] = bp[l * NR..l * NR + NR].try_into().unwrap();
                for r in 0..MR {
                    let ar = av[r];
                    for (c, acc_rc) in acc[r].iter_mut().enumerate() {
                        *acc_rc += ar * bv[c];
                    }
                }
            }
            for r in 0..rows {
                let orow =
                    &mut out[(ic + ib * MR + r) * n + jc + jb * NR..][..cols];
                for (o, v) in orow.iter_mut().zip(&acc[r][..cols]) {
                    *o += v;
                }
            }
        }
    }
}
