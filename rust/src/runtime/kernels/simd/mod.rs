//! Runtime-dispatched SIMD micro-kernels for the tiled GEMM.
//!
//! One register-tiled micro-kernel per instruction set — x86-64 AVX2
//! (6×16) and SSE2 (6×8) in `core::arch::x86_64`, aarch64 NEON (6×8) in
//! `core::arch::aarch64` — behind runtime feature detection, plus the
//! portable scalar 6×8 kernel that is the bitwise **oracle pairing** for
//! all of them. Every path accumulates with *unfused* multiply-then-add
//! (`acc = add(acc, mul(a, b))`, two roundings) in the same ascending
//! k-order, never an FMA contraction: that is exactly the scalar
//! `acc += a * b` semantics, so SIMD ≡ scalar **bitwise** on every ISA —
//! which is what lets the existing tiled ≡ parallel and MeSP ≡ MeBP
//! parity guarantees carry over unchanged. (An AVX2+FMA machine still
//! dispatches the AVX2 kernel; it just issues separate `vmulps`/`vaddps`
//! so the extra rounding of the scalar oracle is preserved.)
//!
//! The micro-tile shape is per-ISA (`Isa::mr`/`Isa::nr`); packing lays
//! slivers out `[kc][mr]` / `[kc][nr]` to match. Differing tile shapes
//! cannot perturb results: padded rows/columns are discarded and each
//! output element still sums its k-terms in ascending order.
//!
//! q4: [`dequant_run`] vectorizes the int4 unpack + scale multiply
//! (nibble → `(x ^ 8) - 8` sign-extend → `cvt` → one `mul`) over a
//! contiguous run — element-for-element the exact expression
//! `model::quant::sign_extend(nib) as f32 * scale` evaluates, so fused
//! SIMD dequant stays bitwise equal to `quant::dequantize`.
//!
//! Selection: [`detect`] picks the best CPU-supported ISA once per
//! process; the `MESP_KERNEL_ISA` env var (`scalar|sse2|avx2|neon`)
//! overrides it — CI's parity tier forces `scalar` and diffs train
//! losses bitwise against the SIMD run.

use std::sync::OnceLock;

/// Env var that forces an ISA (`scalar|sse2|avx2|neon`); unsupported or
/// unrecognized values fall back to the detected best with a warning.
pub const ISA_ENV: &str = "MESP_KERNEL_ISA";

/// Largest `mr` any ISA uses — pack-buffer bounds are sized for this so
/// one arena checkout serves every dispatch.
pub const MR_MAX: usize = 8;
/// Largest `nr` any ISA uses (AVX2's 16-column tile).
pub const NR_MAX: usize = 16;

/// A micro-kernel instruction set. `Scalar` is always available and is
/// the bitwise oracle the SIMD paths are tested against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    Scalar,
    Sse2,
    Avx2,
    Neon,
}

impl Isa {
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon];

    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Micro-tile rows. 6 everywhere: with the widest (AVX2) tile that
    /// is 12 accumulator registers + 2 B loads + 1 A broadcast = 15 of
    /// 16 ymm, the classic no-spill budget.
    pub fn mr(self) -> usize {
        6
    }

    /// Micro-tile columns (one or two vector widths).
    pub fn nr(self) -> usize {
        match self {
            Isa::Avx2 => 16,
            _ => 8,
        }
    }
}

/// Whether the running CPU can execute `isa`'s micro-kernel.
pub fn cpu_supports(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        // SSE2 is part of the x86-64 baseline ABI; NEON is mandatory on
        // aarch64 — neither needs a runtime check.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        _ => false,
    }
}

/// Every ISA the running CPU supports (always includes `Scalar`) — the
/// parity tests and the scalar-vs-SIMD bench sweep this list.
pub fn supported() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|i| cpu_supports(*i)).collect()
}

/// The fastest CPU-supported ISA (widest vectors win).
pub fn best_available() -> Isa {
    [Isa::Avx2, Isa::Neon, Isa::Sse2, Isa::Scalar]
        .into_iter()
        .find(|i| cpu_supports(*i))
        .unwrap_or(Isa::Scalar)
}

/// Resolve an override string (the `MESP_KERNEL_ISA` value, if set)
/// against CPU support; pure so tests can drive it without touching the
/// environment.
pub fn from_env_or_best(val: Option<&str>) -> Isa {
    if let Some(s) = val {
        match Isa::parse(s) {
            Some(isa) if cpu_supports(isa) => return isa,
            Some(isa) => eprintln!(
                "warning: {ISA_ENV}={} is not supported on this CPU; \
                 using {}",
                isa.name(),
                best_available().name()
            ),
            None => eprintln!(
                "warning: {ISA_ENV}='{s}' is not one of \
                 scalar|sse2|avx2|neon; using {}",
                best_available().name()
            ),
        }
    }
    best_available()
}

/// The process-wide ISA choice: `MESP_KERNEL_ISA` override or the
/// detected best, resolved once. Per-engine overrides go through
/// `Kernels::with_isa` instead (benches compare ISAs in one process).
pub fn detect() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| from_env_or_best(std::env::var(ISA_ENV).ok().as_deref()))
}

/// `out[r*ldc + c] += Σ_l ap[l*mr + r] · bp[l*nr + c]` for the valid
/// `rows × cols` region of one micro-tile (`mr = isa.mr()`,
/// `nr = isa.nr()`; `ap`/`bp` are zero-padded packed slivers).
///
/// Unfused multiply-then-add in ascending `l` on every path, so the
/// result is bitwise identical across ISAs. An ISA whose kernel is not
/// compiled for this architecture falls back to scalar — safe precisely
/// because of that equivalence (the packing layout matches `isa`, not
/// the fallback, so the fallback reads `mr`/`nr` from `isa`).
#[allow(clippy::too_many_arguments)]
pub fn microkernel(
    isa: Isa,
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    out: &mut [f32],
    ldc: usize,
    rows: usize,
    cols: usize,
) {
    debug_assert!(ap.len() >= kc * isa.mr());
    debug_assert!(bp.len() >= kc * isa.nr());
    debug_assert!(rows <= isa.mr() && cols <= isa.nr());
    match isa {
        Isa::Scalar => micro_generic::<6, 8>(ap, bp, kc, out, ldc, rows, cols),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is in the x86-64 baseline.
        Isa::Sse2 => unsafe { x86::micro_sse2(ap, bp, kc, out, ldc, rows, cols) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if cpu_supports(Isa::Avx2) =>
        // SAFETY: guarded by the runtime AVX2 check on this arm.
        unsafe { x86::micro_avx2(ap, bp, kc, out, ldc, rows, cols) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is mandatory on aarch64.
        Isa::Neon => unsafe { neon::micro_neon(ap, bp, kc, out, ldc, rows, cols) },
        Isa::Avx2 => micro_generic::<6, 16>(ap, bp, kc, out, ldc, rows, cols),
        _ => micro_generic::<6, 8>(ap, bp, kc, out, ldc, rows, cols),
    }
}

/// The portable micro-kernel, monomorphized per tile shape. `<6, 8>` is
/// the pre-SIMD scalar kernel, byte-for-byte the same accumulation; the
/// other instantiations back the cross-arch fallbacks.
fn micro_generic<const MR: usize, const NR: usize>(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    out: &mut [f32],
    ldc: usize,
    rows: usize,
    cols: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kc {
        let av: &[f32; MR] = ap[l * MR..l * MR + MR].try_into().unwrap();
        let bv: &[f32; NR] = bp[l * NR..l * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let ar = av[r];
            for (c, acc_rc) in acc[r].iter_mut().enumerate() {
                *acc_rc += ar * bv[c];
            }
        }
    }
    for r in 0..rows {
        let orow = &mut out[r * ldc..][..cols];
        for (o, v) in orow.iter_mut().zip(&acc[r][..cols]) {
            *o += v;
        }
    }
}

/// `dst[i] = sign_extend(nibble(bytes[i])) as f32 * scales[i]` over a
/// contiguous run — the vectorized int4 dequant the q4 B-panel pack
/// fuses in. `hi` selects the high nibble (odd din row). Element
/// semantics are exactly `quant::sign_extend(nib) as f32 * scale`
/// (int4 → f32 conversion is exact; one multiply rounding), so every
/// path is bitwise equal to `quant::dequantize`.
pub fn dequant_run(isa: Isa, bytes: &[u8], scales: &[f32], hi: bool, dst: &mut [f32]) {
    debug_assert!(bytes.len() >= dst.len());
    debug_assert!(scales.len() >= dst.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if cpu_supports(Isa::Avx2) =>
        // SAFETY: guarded by the runtime AVX2 check on this arm.
        unsafe { x86::dequant_avx2(bytes, scales, hi, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is mandatory on aarch64.
        Isa::Neon => unsafe { neon::dequant_neon(bytes, scales, hi, dst) },
        _ => dequant_scalar(bytes, scales, hi, dst),
    }
}

fn dequant_scalar(bytes: &[u8], scales: &[f32], hi: bool, dst: &mut [f32]) {
    for (i, d) in dst.iter_mut().enumerate() {
        let nib = if hi { (bytes[i] >> 4) & 0x0f } else { bytes[i] & 0x0f };
        *d = crate::model::quant::sign_extend(nib) as f32 * scales[i];
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// SSE2 6×8: 12 xmm accumulators + 2 B loads + 1 A broadcast.
    ///
    /// # Safety
    /// SSE2 only (x86-64 baseline); slice bounds checked by the caller's
    /// debug asserts and the loads below staying inside `ap`/`bp`/`out`.
    pub unsafe fn micro_sse2(
        ap: &[f32],
        bp: &[f32],
        kc: usize,
        out: &mut [f32],
        ldc: usize,
        rows: usize,
        cols: usize,
    ) {
        const MR: usize = 6;
        const NR: usize = 8;
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        let mut acc = [[_mm_setzero_ps(); 2]; MR];
        for l in 0..kc {
            let b0 = _mm_loadu_ps(bpp.add(l * NR));
            let b1 = _mm_loadu_ps(bpp.add(l * NR + 4));
            for r in 0..MR {
                let a = _mm_set1_ps(*app.add(l * MR + r));
                acc[r][0] = _mm_add_ps(acc[r][0], _mm_mul_ps(a, b0));
                acc[r][1] = _mm_add_ps(acc[r][1], _mm_mul_ps(a, b1));
            }
        }
        if rows == MR && cols == NR {
            let op = out.as_mut_ptr();
            for (r, a) in acc.iter().enumerate() {
                let o = op.add(r * ldc);
                _mm_storeu_ps(o, _mm_add_ps(_mm_loadu_ps(o), a[0]));
                _mm_storeu_ps(o.add(4), _mm_add_ps(_mm_loadu_ps(o.add(4)), a[1]));
            }
        } else {
            // Ragged edge: spill the full tile, scalar-add the valid
            // region — still one final add per element, bitwise the
            // same as the direct path.
            let mut tmp = [0.0f32; MR * NR];
            for (r, a) in acc.iter().enumerate() {
                _mm_storeu_ps(tmp.as_mut_ptr().add(r * NR), a[0]);
                _mm_storeu_ps(tmp.as_mut_ptr().add(r * NR + 4), a[1]);
            }
            for r in 0..rows {
                let orow = &mut out[r * ldc..][..cols];
                for (c, o) in orow.iter_mut().enumerate() {
                    *o += tmp[r * NR + c];
                }
            }
        }
    }

    /// AVX2 6×16: 12 ymm accumulators + 2 B loads + 1 A broadcast — 15
    /// of 16 ymm, no spill. Separate `vmulps`/`vaddps` (never FMA) keeps
    /// the scalar oracle's two-rounding semantics.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn micro_avx2(
        ap: &[f32],
        bp: &[f32],
        kc: usize,
        out: &mut [f32],
        ldc: usize,
        rows: usize,
        cols: usize,
    ) {
        const MR: usize = 6;
        const NR: usize = 16;
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        for l in 0..kc {
            let b0 = _mm256_loadu_ps(bpp.add(l * NR));
            let b1 = _mm256_loadu_ps(bpp.add(l * NR + 8));
            for r in 0..MR {
                let a = _mm256_set1_ps(*app.add(l * MR + r));
                acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(a, b0));
                acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(a, b1));
            }
        }
        if rows == MR && cols == NR {
            let op = out.as_mut_ptr();
            for (r, a) in acc.iter().enumerate() {
                let o = op.add(r * ldc);
                _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), a[0]));
                _mm256_storeu_ps(o.add(8), _mm256_add_ps(_mm256_loadu_ps(o.add(8)), a[1]));
            }
        } else {
            let mut tmp = [0.0f32; MR * NR];
            for (r, a) in acc.iter().enumerate() {
                _mm256_storeu_ps(tmp.as_mut_ptr().add(r * NR), a[0]);
                _mm256_storeu_ps(tmp.as_mut_ptr().add(r * NR + 8), a[1]);
            }
            for r in 0..rows {
                let orow = &mut out[r * ldc..][..cols];
                for (c, o) in orow.iter_mut().enumerate() {
                    *o += tmp[r * NR + c];
                }
            }
        }
    }

    /// int4 dequant, 8 lanes at a time: byte → u32 widen, nibble
    /// mask/shift, `(x ^ 8) - 8` sign-extend, exact `cvtdq2ps`, one
    /// `mulps` by the scales.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2; `bytes`/`scales` must
    /// cover `dst.len()` (caller's debug asserts).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_avx2(bytes: &[u8], scales: &[f32], hi: bool, dst: &mut [f32]) {
        let n = dst.len();
        let mask = _mm256_set1_epi32(0x0f);
        let eight = _mm256_set1_epi32(8);
        let mut i = 0;
        while i + 8 <= n {
            let raw = _mm_loadl_epi64(bytes.as_ptr().add(i) as *const __m128i);
            let mut v = _mm256_cvtepu8_epi32(raw);
            if hi {
                v = _mm256_srli_epi32::<4>(v);
            }
            v = _mm256_and_si256(v, mask);
            v = _mm256_sub_epi32(_mm256_xor_si256(v, eight), eight);
            let f = _mm256_cvtepi32_ps(v);
            let s = _mm256_loadu_ps(scales.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(f, s));
            i += 8;
        }
        while i < n {
            let nib = if hi { (bytes[i] >> 4) & 0x0f } else { bytes[i] & 0x0f };
            dst[i] = crate::model::quant::sign_extend(nib) as f32 * scales[i];
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    /// NEON 6×8: 12 q-register accumulators + 2 B loads + 1 A broadcast.
    /// Explicit `vmulq`/`vaddq` (not `vmlaq`/`vfmaq`) — unfused, same
    /// two roundings as the scalar oracle.
    ///
    /// # Safety
    /// NEON only (mandatory on aarch64); bounds as per caller asserts.
    pub unsafe fn micro_neon(
        ap: &[f32],
        bp: &[f32],
        kc: usize,
        out: &mut [f32],
        ldc: usize,
        rows: usize,
        cols: usize,
    ) {
        const MR: usize = 6;
        const NR: usize = 8;
        let app = ap.as_ptr();
        let bpp = bp.as_ptr();
        let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
        for l in 0..kc {
            let b0 = vld1q_f32(bpp.add(l * NR));
            let b1 = vld1q_f32(bpp.add(l * NR + 4));
            for r in 0..MR {
                let a = vdupq_n_f32(*app.add(l * MR + r));
                acc[r][0] = vaddq_f32(acc[r][0], vmulq_f32(a, b0));
                acc[r][1] = vaddq_f32(acc[r][1], vmulq_f32(a, b1));
            }
        }
        if rows == MR && cols == NR {
            let op = out.as_mut_ptr();
            for (r, a) in acc.iter().enumerate() {
                let o = op.add(r * ldc);
                vst1q_f32(o, vaddq_f32(vld1q_f32(o), a[0]));
                vst1q_f32(o.add(4), vaddq_f32(vld1q_f32(o.add(4)), a[1]));
            }
        } else {
            let mut tmp = [0.0f32; MR * NR];
            for (r, a) in acc.iter().enumerate() {
                vst1q_f32(tmp.as_mut_ptr().add(r * NR), a[0]);
                vst1q_f32(tmp.as_mut_ptr().add(r * NR + 4), a[1]);
            }
            for r in 0..rows {
                let orow = &mut out[r * ldc..][..cols];
                for (c, o) in orow.iter_mut().enumerate() {
                    *o += tmp[r * NR + c];
                }
            }
        }
    }

    /// int4 dequant, 8 lanes per iteration via u8 → u16 → u32 widening.
    ///
    /// # Safety
    /// NEON only; `bytes`/`scales` must cover `dst.len()`.
    pub unsafe fn dequant_neon(bytes: &[u8], scales: &[f32], hi: bool, dst: &mut [f32]) {
        let n = dst.len();
        let mut i = 0;
        while i + 8 <= n {
            let raw = vld1_u8(bytes.as_ptr().add(i));
            let wide = vmovl_u8(raw);
            let halves = [vmovl_u16(vget_low_u16(wide)), vmovl_u16(vget_high_u16(wide))];
            for (j, part) in halves.into_iter().enumerate() {
                let mut v = part;
                if hi {
                    v = vshrq_n_u32::<4>(v);
                }
                v = vandq_u32(v, vdupq_n_u32(0x0f));
                let sv = vsubq_s32(
                    veorq_s32(vreinterpretq_s32_u32(v), vdupq_n_s32(8)),
                    vdupq_n_s32(8),
                );
                let f = vcvtq_f32_s32(sv);
                let s = vld1q_f32(scales.as_ptr().add(i + 4 * j));
                vst1q_f32(dst.as_mut_ptr().add(i + 4 * j), vmulq_f32(f, s));
            }
            i += 8;
        }
        while i < n {
            let nib = if hi { (bytes[i] >> 4) & 0x0f } else { bytes[i] & 0x0f };
            dst[i] = crate::model::quant::sign_extend(nib) as f32 * scales[i];
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference accumulation over packed slivers — plain f64-free
    /// scalar math in the exact k-order every kernel must follow.
    #[allow(clippy::too_many_arguments)]
    fn reference(
        isa: Isa,
        ap: &[f32],
        bp: &[f32],
        kc: usize,
        ldc: usize,
        rows: usize,
        cols: usize,
        out: &mut [f32],
    ) {
        let (mr, nr) = (isa.mr(), isa.nr());
        for r in 0..rows {
            for c in 0..cols {
                let mut acc = 0.0f32;
                for l in 0..kc {
                    acc += ap[l * mr + r] * bp[l * nr + c];
                }
                out[r * ldc + c] += acc;
            }
        }
    }

    #[test]
    fn every_supported_isa_matches_the_scalar_accumulation_bitwise() {
        let mut rng = Rng::new(42);
        for isa in supported() {
            let (mr, nr) = (isa.mr(), isa.nr());
            for kc in [1, 3, 17, 64] {
                let ap = rng.normal_vec(kc * mr, 1.0);
                let bp = rng.normal_vec(kc * nr, 1.0);
                for (rows, cols) in [(mr, nr), (1, 1), (mr - 1, nr - 3), (2, nr)] {
                    let ldc = nr + 5;
                    let mut want = vec![0.5f32; rows.max(1) * ldc];
                    let mut got = want.clone();
                    reference(isa, &ap, &bp, kc, ldc, rows, cols, &mut want);
                    microkernel(isa, &ap, &bp, kc, &mut got, ldc, rows, cols);
                    assert_eq!(want, got, "isa={} kc={kc} {rows}x{cols}", isa.name());
                }
            }
        }
    }

    #[test]
    fn dequant_run_matches_scalar_expression_bitwise() {
        let mut rng = Rng::new(43);
        for isa in supported() {
            for n in [1, 7, 8, 9, 31, 64] {
                let bytes: Vec<u8> = (0..n).map(|i| (i * 37 + 11) as u8).collect();
                let scales = rng.normal_vec(n, 0.1);
                for hi in [false, true] {
                    let mut want = vec![0.0f32; n];
                    let mut got = vec![0.0f32; n];
                    dequant_scalar(&bytes, &scales, hi, &mut want);
                    dequant_run(isa, &bytes, &scales, hi, &mut got);
                    assert_eq!(want, got, "isa={} n={n} hi={hi}", isa.name());
                }
            }
        }
    }

    #[test]
    fn dequant_scalar_matches_quant_sign_extension() {
        // All 16 nibble values through both nibble halves.
        let bytes: Vec<u8> = (0..=255u8).step_by(17).collect();
        let scales = vec![0.25f32; bytes.len()];
        let mut lo = vec![0.0f32; bytes.len()];
        let mut hi = vec![0.0f32; bytes.len()];
        dequant_scalar(&bytes, &scales, false, &mut lo);
        dequant_scalar(&bytes, &scales, true, &mut hi);
        for (i, &b) in bytes.iter().enumerate() {
            let expect = |nib: u8| crate::model::quant::sign_extend(nib) as f32 * 0.25;
            assert_eq!(lo[i], expect(b & 0x0f));
            assert_eq!(hi[i], expect((b >> 4) & 0x0f));
        }
    }

    #[test]
    fn detection_env_override_and_ranking() {
        assert!(cpu_supports(Isa::Scalar));
        assert!(supported().contains(&Isa::Scalar));
        assert_eq!(from_env_or_best(Some("scalar")), Isa::Scalar);
        // Unrecognized values fall back to the best available.
        assert_eq!(from_env_or_best(Some("avx999")), best_available());
        assert_eq!(from_env_or_best(None), best_available());
        assert!(supported().contains(&best_available()));
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert!(isa.mr() <= MR_MAX && isa.nr() <= NR_MAX);
        }
        assert_eq!(Isa::parse("riscv"), None);
    }
}
