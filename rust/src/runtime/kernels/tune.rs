//! Cache-aware tile autotuning for the tiled GEMM.
//!
//! The BLIS blocking parameters (MC row panel, KC k-depth, NC column
//! slab) were hard-coded 64/256/128; this module derives them from the
//! machine instead. [`CacheInfo::detect`] reads L1d/L2/cache-line
//! geometry from sysfs (conservative defaults when unavailable),
//! [`Tiles::derive`] sizes KC so one A sliver + one B sliver fit L1d and
//! MC/NC so the packed panels sit in half of L2, and `--tune` sweeps a
//! candidate grid on a calibration GEMM set, persisting the winner to a
//! small JSON profile (`MESP_TUNE_PROFILE` or `~/.cache/mesp/tune.json`)
//! that [`active_tiles`] loads on the next run.
//!
//! Tile sizes are a scheduling choice: every output element still
//! accumulates its k-terms in ascending order whatever MC/KC/NC are, so
//! at any fixed profile the bitwise parity guarantees (SIMD ≡ scalar,
//! tiled ≡ parallel — see [`super::simd`] and [`super::tiled`]) hold
//! unchanged. A different KC does regroup the panel partial sums when
//! `k > KC`, which is why the active profile is resolved once per
//! process and shared by every engine.
//!
//! Memory accounting follows the tiles: [`Tiles::pack_bound_elems`]
//! bounds one `gemm` invocation's packing checkout, and
//! `memory::model`'s per-thread packing-scratch term (hence fleet
//! admission and the `mesp report` envelope) charges the *active*
//! tiles' bound rather than a constant.

use std::path::{Path, PathBuf};
use std::sync::RwLock;
use std::time::Instant;

use crate::memory::MemoryTracker;
use crate::tensor::TensorArena;
use crate::util::{Json, Rng};

use super::simd::{Isa, MR_MAX, NR_MAX};
use super::{tiled, AView, BView};

/// Hard cap on KC: the q4 `Wᵀ` pack dequantizes one column run into a
/// fixed stack buffer of this many f32s, so every `Tiles` constructor
/// clamps to it.
pub const MAX_KC: usize = 512;

/// Cache geometry the tile derivation consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheInfo {
    pub l1d_bytes: usize,
    pub l2_bytes: usize,
    pub line_bytes: usize,
}

impl CacheInfo {
    /// Conservative fallback (32 KiB L1d / 1 MiB L2 / 64 B lines) —
    /// small enough to be safe on any phone-class core.
    pub const DEFAULT: CacheInfo =
        CacheInfo { l1d_bytes: 32 * 1024, l2_bytes: 1024 * 1024, line_bytes: 64 };

    /// Detect from sysfs (Linux); [`CacheInfo::DEFAULT`] elsewhere or on
    /// any parse failure. Cached per process.
    pub fn detect() -> CacheInfo {
        use std::sync::OnceLock;
        static DETECTED: OnceLock<CacheInfo> = OnceLock::new();
        *DETECTED.get_or_init(|| detect_sysfs().unwrap_or(CacheInfo::DEFAULT))
    }
}

/// Parse a sysfs cache size like `48K`, `2048K`, `1M` or a plain byte
/// count.
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (num, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<usize>().ok().map(|n| n * mult)
}

fn detect_sysfs() -> Option<CacheInfo> {
    let base = Path::new("/sys/devices/system/cpu/cpu0/cache");
    let read = |idx: usize, f: &str| -> Option<String> {
        std::fs::read_to_string(base.join(format!("index{idx}")).join(f))
            .ok()
            .map(|s| s.trim().to_string())
    };
    let (mut l1d, mut l2, mut line) = (None, None, None);
    for idx in 0..8 {
        let Some(level) = read(idx, "level") else { continue };
        let ty = read(idx, "type").unwrap_or_default();
        let size = read(idx, "size").and_then(|s| parse_size(&s));
        if level == "1" && ty == "Data" {
            l1d = l1d.or(size);
        }
        if level == "2" {
            l2 = l2.or(size);
        }
        if line.is_none() {
            line = read(idx, "coherency_line_size").and_then(|s| s.parse().ok());
        }
    }
    Some(CacheInfo {
        l1d_bytes: l1d?,
        l2_bytes: l2.unwrap_or(CacheInfo::DEFAULT.l2_bytes),
        line_bytes: line.unwrap_or(CacheInfo::DEFAULT.line_bytes),
    })
}

/// The blocking parameters of one `tiled::gemm` invocation. Fields are
/// private: every constructor normalizes (KC ≤ [`MAX_KC`], NC rounded up
/// to an [`NR_MAX`] multiple) so [`Tiles::pack_bound_elems`] is a true
/// upper bound on the packing checkout for any operand shape and ISA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiles {
    mc: usize,
    kc: usize,
    nc: usize,
}

impl Tiles {
    pub fn new(mc: usize, kc: usize, nc: usize) -> Tiles {
        Tiles {
            mc: mc.clamp(8, 256),
            kc: kc.clamp(32, MAX_KC),
            nc: nc.clamp(16, 1024).next_multiple_of(NR_MAX),
        }
    }

    /// The pre-autotuning constants (MC 64, KC 256, NC 128) — kept as
    /// the sweep's reference candidate and for tests.
    pub fn baseline() -> Tiles {
        Tiles::new(64, 256, 128)
    }

    /// Derive from cache geometry: KC sized so an A sliver + a B sliver
    /// (`(MR_MAX + NR_MAX) · KC` f32s) fill L1d, MC and NC sized so the
    /// packed `MC×KC` panel and `KC×NC` slab each sit in half of L2.
    pub fn derive(cache: CacheInfo) -> Tiles {
        let kc = (cache.l1d_bytes / (4 * (MR_MAX + NR_MAX)))
            .clamp(128, MAX_KC)
            / 32
            * 32;
        let panel = (cache.l2_bytes / 2) / (4 * kc);
        let mc = panel.clamp(32, 128) / 8 * 8;
        let nc = panel.clamp(64, 256);
        Tiles::new(mc, kc, nc)
    }

    pub fn mc(&self) -> usize {
        self.mc
    }

    pub fn kc(&self) -> usize {
        self.kc
    }

    pub fn nc(&self) -> usize {
        self.nc
    }

    /// Upper bound on one `gemm` invocation's packing checkout in f32
    /// elements: apack ≤ (MC rounded up to any ISA's mr) · KC, bpack ≤
    /// KC · NC (NC is already an NR_MAX multiple, so column rounding
    /// never exceeds it). `memory::model` charges this per kernel
    /// thread.
    pub fn pack_bound_elems(&self) -> usize {
        (self.mc + MR_MAX) * self.kc + self.kc * self.nc
    }

    /// `"mc×kc×nc"` label for traces, logs and the bench record.
    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.mc, self.kc, self.nc)
    }
}

static ACTIVE: RwLock<Option<Tiles>> = RwLock::new(None);

/// The process-wide tiles every [`super::Kernels`] is built with (unless
/// overridden per-instance via `with_tiles`): the persisted tuning
/// profile if one loads, otherwise [`Tiles::derive`] of the detected
/// cache geometry. Resolved once; [`install`] replaces it.
pub fn active_tiles() -> Tiles {
    if let Some(t) = *ACTIVE.read().unwrap() {
        return t;
    }
    let t = profile_path()
        .and_then(|p| load_profile(&p))
        .unwrap_or_else(|| Tiles::derive(CacheInfo::detect()));
    let mut w = ACTIVE.write().unwrap();
    if w.is_none() {
        *w = Some(t);
    }
    w.unwrap()
}

/// Replace the process-wide tiles (the `--tune` path; tests use
/// per-instance `with_tiles` instead to stay hermetic).
pub fn install(t: Tiles) {
    *ACTIVE.write().unwrap() = Some(t);
}

/// Where the tuning profile lives: `$MESP_TUNE_PROFILE`, else
/// `$HOME/.cache/mesp/tune.json`, else nowhere (persistence disabled).
pub fn profile_path() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("MESP_TUNE_PROFILE") {
        if !p.is_empty() {
            return Some(PathBuf::from(p));
        }
    }
    std::env::var_os("HOME")
        .map(|h| PathBuf::from(h).join(".cache").join("mesp").join("tune.json"))
}

/// Load a persisted profile; `None` on missing/garbled/wrong-version
/// files (the caller falls back to derivation — a stale profile must
/// never crash a run).
pub fn load_profile(path: &Path) -> Option<Tiles> {
    let root = Json::parse(&std::fs::read_to_string(path).ok()?).ok()?;
    if root.get("version")?.as_usize()? != 1 {
        return None;
    }
    let field = |k: &str| root.get(k)?.as_usize();
    Some(Tiles::new(field("mc")?, field("kc")?, field("nc")?))
}

/// Persist `tiles` (plus provenance: ISA, cache geometry, how it was
/// chosen) as the version-1 profile at `path`, creating parent dirs.
pub fn save_profile(path: &Path, tiles: Tiles, isa: Isa, source: &str) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let cache = CacheInfo::detect();
    let json = Json::obj(vec![
        ("version", Json::num(1u32)),
        ("mc", Json::num(tiles.mc as u32)),
        ("kc", Json::num(tiles.kc as u32)),
        ("nc", Json::num(tiles.nc as u32)),
        ("isa", Json::str(isa.name())),
        ("source", Json::str(source)),
        (
            "cache",
            Json::obj(vec![
                ("l1d_bytes", Json::num(cache.l1d_bytes as u32)),
                ("l2_bytes", Json::num(cache.l2_bytes as u32)),
                ("line_bytes", Json::num(cache.line_bytes as u32)),
            ]),
        ),
    ]);
    std::fs::write(path, json.to_string())
}

/// One sweep's outcome: the winning tiles and the timing table.
pub struct TuneOutcome {
    pub tiles: Tiles,
    /// `(candidate, calibration-set milliseconds)` — ascending by time.
    pub table: Vec<(Tiles, f64)>,
}

/// Calibration GEMMs: deep-k and wide-n shapes big enough that the
/// blocking actually cycles (k and n past one KC/NC panel), small enough
/// that a full sweep stays around a second.
const CAL_SHAPES: [(usize, usize, usize); 3] = [(96, 384, 256), (64, 768, 128), (192, 192, 320)];

/// The `--tune` candidate grid around the derived point.
fn candidates() -> Vec<Tiles> {
    let mut v = vec![Tiles::baseline(), Tiles::derive(CacheInfo::detect())];
    for kc in [128, 256, 384, 512] {
        for mc in [32, 64, 128] {
            for nc in [128, 256] {
                v.push(Tiles::new(mc, kc, nc));
            }
        }
    }
    v.dedup_by(|a, b| a == b);
    v
}

/// Sweep the default candidate grid on the calibration set with `isa`'s
/// micro-kernel and return the fastest tiles.
pub fn sweep(isa: Isa) -> TuneOutcome {
    sweep_candidates(isa, &candidates(), 2)
}

/// Sweep an explicit candidate list (`reps` timed runs each, best-of).
pub fn sweep_candidates(isa: Isa, cands: &[Tiles], reps: usize) -> TuneOutcome {
    let arena = TensorArena::new(MemoryTracker::new());
    let mut rng = Rng::new(5);
    let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = CAL_SHAPES
        .iter()
        .map(|&(m, k, n)| {
            (rng.normal_vec(m * k, 0.5), rng.normal_vec(k * n, 0.5), vec![0.0; m * n])
        })
        .collect();
    let mut table = Vec::with_capacity(cands.len());
    for &tiles in cands {
        let run = |data: &mut [(Vec<f32>, Vec<f32>, Vec<f32>)]| {
            for (&(m, k, n), (a, b, out)) in CAL_SHAPES.iter().zip(data.iter_mut()) {
                out.fill(0.0);
                tiled::gemm(&arena, isa, tiles, AView::Rows(a), BView::Rows(b), 0, m, k, n, out);
            }
        };
        let mut data = data.clone();
        run(&mut data); // warmup: page in the arena buffers for this size
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            run(&mut data);
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        table.push((tiles, best));
    }
    table.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    TuneOutcome { tiles: table[0].0, table }
}

/// The full `--tune` action: sweep, install process-wide, persist.
/// Returns the outcome plus the profile path if one was written.
pub fn tune_and_install(isa: Isa) -> (TuneOutcome, Option<PathBuf>) {
    let outcome = sweep(isa);
    install(outcome.tiles);
    let written = profile_path().and_then(|p| {
        save_profile(&p, outcome.tiles, isa, "tune").ok().map(|()| p)
    });
    (outcome, written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_normalize_to_the_invariants() {
        for t in [
            Tiles::new(1, 9999, 1),
            Tiles::new(500, 0, 4000),
            Tiles::baseline(),
            Tiles::derive(CacheInfo::DEFAULT),
            Tiles::derive(CacheInfo { l1d_bytes: 48 * 1024, l2_bytes: 2 << 20, line_bytes: 64 }),
            Tiles::derive(CacheInfo { l1d_bytes: 1, l2_bytes: 1, line_bytes: 1 }),
        ] {
            assert!(t.kc() >= 32 && t.kc() <= MAX_KC, "{t:?}");
            assert!(t.mc() >= 8 && t.mc() <= 256, "{t:?}");
            assert_eq!(t.nc() % NR_MAX, 0, "{t:?}");
            assert!(t.pack_bound_elems() >= (t.mc() + MR_MAX) * t.kc());
        }
        // The baseline reproduces the pre-autotuning constants.
        let b = Tiles::baseline();
        assert_eq!((b.mc(), b.kc(), b.nc()), (64, 256, 128));
        assert_eq!(b.label(), "64x256x128");
    }

    #[test]
    fn derived_tiles_grow_with_cache() {
        let small = Tiles::derive(CacheInfo::DEFAULT);
        let big = Tiles::derive(CacheInfo {
            l1d_bytes: 64 * 1024,
            l2_bytes: 4 << 20,
            line_bytes: 64,
        });
        assert!(big.kc() >= small.kc());
        assert!(big.pack_bound_elems() >= small.pack_bound_elems());
    }

    #[test]
    fn sysfs_size_strings_parse() {
        assert_eq!(parse_size("48K"), Some(48 * 1024));
        assert_eq!(parse_size("2048K"), Some(2048 * 1024));
        assert_eq!(parse_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_size("65536"), Some(65536));
        assert_eq!(parse_size("lots"), None);
    }

    #[test]
    fn profile_round_trips_and_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("mesp-tune-test-{}", std::process::id()));
        let path = dir.join("profile.json");
        let tiles = Tiles::new(96, 384, 176);
        save_profile(&path, tiles, Isa::Scalar, "test").unwrap();
        assert_eq!(load_profile(&path), Some(tiles));
        std::fs::write(&path, "not json at all").unwrap();
        assert_eq!(load_profile(&path), None);
        std::fs::write(&path, "{\"version\": 2, \"mc\": 64, \"kc\": 64, \"nc\": 64}").unwrap();
        assert_eq!(load_profile(&path), None, "future versions must not half-load");
        assert_eq!(load_profile(&dir.join("missing.json")), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_returns_a_listed_candidate() {
        let cands = [Tiles::baseline(), Tiles::new(32, 128, 128)];
        let out = sweep_candidates(Isa::Scalar, &cands, 1);
        assert!(cands.contains(&out.tiles));
        assert_eq!(out.table.len(), 2);
        assert!(out.table[0].1 <= out.table[1].1, "table must be ascending");
    }

    #[test]
    fn active_tiles_returns_normalized_tiles() {
        let t = active_tiles();
        assert!(t.kc() <= MAX_KC && t.nc() % NR_MAX == 0);
    }
}
