//! The scalar oracle kernels — the original `refmath` triple loops, moved
//! here verbatim. Every other variant is validated against these.
//!
//! The `if av == 0.0 { continue; }` fast path is deliberately KEPT here
//! and only here: it is correct (skipping a zero row contribution) and it
//! speeds the oracle up on sparse inputs (freshly-initialized LoRA B
//! matrices are all-zero), but it makes latency *data-dependent*, which
//! disqualifies it from the tiled/parallel production kernels — a step
//! time that changes with the weight values would poison every
//! before/after perf comparison.

/// `a[m,k] @ b[k,n]` accumulated into zeroed `out[m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * n..(l + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `aᵀ @ b` with `a[k,m]`, `b[k,n]` into zeroed `out[m,n]`.
pub fn matmul_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for l in 0..k {
        let arow = &a[l * m..(l + 1) * m];
        let brow = &b[l * n..(l + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `a @ bᵀ` with `a[m,k]`, `b[n,k]` into zeroed `out[m,n]`.
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}
