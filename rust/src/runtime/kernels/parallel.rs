//! Row-panel parallel GEMM: the tiled kernel fanned out over contiguous
//! row windows with `std::thread::scope`.
//!
//! The output is split into per-thread row panels with `chunks_mut`, so
//! no two threads ever touch the same cache line of C; each thread runs
//! the full [`super::tiled`] blocking over its window with the same
//! k-order, ISA and tiles, which keeps the result bitwise identical to
//! the single-threaded tiled kernel at any thread count. Packing buffers
//! are checked out of the shared [`TensorArena`] per thread (and
//! returned to the pool on exit, so a steady-state session allocates
//! nothing).
//!
//! Callers gate on [`super::parallel_min_madds`] — a shape-only
//! threshold scaled to the ISA's micro-kernel throughput — before
//! fanning out; this module assumes the work is big enough to be worth
//! the spawn/join cost.

use crate::tensor::TensorArena;

use super::simd::Isa;
use super::tune::Tiles;
use super::{tiled, AView, BView};

/// `out[m,n] = A @ B` across `threads` row panels.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    arena: &TensorArena,
    threads: usize,
    isa: Isa,
    tiles: Tiles,
    a: AView,
    b: BView,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    // Panels are mr-aligned so no micro-tile straddles two threads; a
    // panel count above m/mr would leave threads idle anyway.
    let mr = isa.mr();
    let panels = threads.clamp(1, m.div_ceil(mr));
    let rows_per = m.div_ceil(panels).next_multiple_of(mr);
    std::thread::scope(|s| {
        for (pi, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            s.spawn(move || {
                let row0 = pi * rows_per;
                let rows = chunk.len() / n;
                tiled::gemm(arena, isa, tiles, a, b, row0, rows, k, n, chunk);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemoryTracker;
    use crate::runtime::kernels::simd;
    use crate::util::Rng;

    fn tiled_want(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, isa: Isa) -> Vec<f32> {
        let arena = TensorArena::new(MemoryTracker::new());
        let mut want = vec![0.0; m * n];
        tiled::gemm(
            &arena, isa, Tiles::baseline(), AView::Rows(a), BView::Rows(b), 0, m, k, n, &mut want,
        );
        want
    }

    #[test]
    fn ragged_row_split_covers_every_row() {
        // 10 rows across 3 threads with mr alignment: panels of 4/4/2.
        let arena = TensorArena::new(MemoryTracker::new());
        let (m, k, n) = (10, 5, 3);
        let mut rng = Rng::new(1);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        for isa in simd::supported() {
            let mut got = vec![0.0; m * n];
            gemm(
                &arena, 3, isa, Tiles::baseline(),
                AView::Rows(&a), BView::Rows(&b), m, k, n, &mut got,
            );
            assert_eq!(got, tiled_want(&a, &b, m, k, n, isa), "isa={}", isa.name());
        }
    }

    #[test]
    fn more_threads_than_rows_is_fine() {
        let arena = TensorArena::new(MemoryTracker::new());
        let (m, k, n) = (2, 4, 4);
        let mut rng = Rng::new(2);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let isa = simd::detect();
        let mut got = vec![0.0; m * n];
        gemm(
            &arena, 16, isa, Tiles::baseline(),
            AView::Rows(&a), BView::Rows(&b), m, k, n, &mut got,
        );
        assert_eq!(got, tiled_want(&a, &b, m, k, n, isa));
    }
}
