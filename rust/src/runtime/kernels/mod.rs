//! The kernel engine: GEMM dispatch + scratch arena + FLOP accounting for
//! the pure-Rust reference backend.
//!
//! All heavy math in [`super::refmath`] goes through a [`Kernels`] handle,
//! which dispatches every GEMM to one of three variants
//! ([`crate::config::KernelKind`]):
//!
//! * [`naive`] — the original scalar triple loops, kept verbatim as the
//!   correctness **oracle**. This is the only variant allowed to keep the
//!   data-dependent `if av == 0.0 { continue; }` fast path (it makes step
//!   timing input-dependent, which a production kernel must not be).
//! * [`tiled`] — cache-blocked, register-tiled (MR×NR micro-kernel with
//!   packed operand panels, the classic BLIS structure), branch-free.
//! * [`parallel`] — the tiled kernel fanned out over contiguous row
//!   panels with `std::thread::scope`. Each output row is produced end to
//!   end by exactly one thread with the same k-blocking as `tiled`, so
//!   results are **bitwise identical** to `tiled` at any thread count.
//!
//! Thread budget: a lone session resolves `threads = 0` to all cores; the
//! fleet scheduler divides cores by its worker count before building the
//! backend so concurrent sessions never oversubscribe the machine.
//!
//! Scratch discipline: GEMM outputs and packing panels are checked out of
//! the engine's [`TensorArena`], so they are reused across calls and
//! tracked under the `scratch` tag (see `memory::model::scratch` for the
//! matching analytical term).
//!
//! FLOP accounting: each GEMM adds its nominal `2·m·k·n` to a shared
//! counter (the naive oracle's zero-skip still counts full work);
//! `refmath`'s attention loops add their products explicitly. The
//! reference backend snapshots the counter around each artifact call to
//! report per-artifact FLOPs and achieved GFLOP/s in `exec_stats`.

pub mod flops;
pub mod naive;
pub mod parallel;
pub mod tiled;

use std::sync::atomic::{AtomicU64, Ordering};

pub use crate::config::KernelKind;
use crate::memory::MemoryTracker;
use crate::tensor::{ScratchBuf, TensorArena};

/// How the kernel engine is configured (CLI: `--kernel`, `--threads`).
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelOptions {
    pub kind: KernelKind,
    /// Worker threads for the `parallel` kernel; 0 = all cores.
    pub threads: usize,
}

/// The number of threads `threads = 0` resolves to.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Operand view of the left GEMM input.
#[derive(Debug, Clone, Copy)]
pub enum AView<'a> {
    /// `[m, k]` row-major: `A(i, l) = data[i*k + l]`.
    Rows(&'a [f32]),
    /// Stored transposed `[k, ld]` with `ld` the FULL row count:
    /// `A(i, l) = data[l*ld + i]` (the parallel kernel offsets `i`, so
    /// the stride must stay the whole matrix's).
    Cols { data: &'a [f32], ld: usize },
}

/// Operand view of the right GEMM input.
#[derive(Debug, Clone, Copy)]
pub enum BView<'a> {
    /// `[k, n]` row-major: `B(l, j) = data[l*n + j]`.
    Rows(&'a [f32]),
    /// Stored transposed `[n, k]`: `B(l, j) = data[j*k + l]`.
    Cols(&'a [f32]),
}

/// GEMMs below this many multiply-adds stay single-threaded even under
/// the `parallel` kernel: thread spawn/join costs more than it saves.
/// Shape-dependent only — never data-dependent. 2^18 madds ≈ 130 µs of
/// tiled single-thread work — a few scoped-thread spawns still pay off.
pub const PARALLEL_MIN_MADDS: usize = 1 << 18;

/// The kernel engine handle: dispatch + arena + FLOP counter. One per
/// backend instance; shared by every artifact call of a session.
#[derive(Debug)]
pub struct Kernels {
    kind: KernelKind,
    threads: usize,
    arena: TensorArena,
    flops: AtomicU64,
}

impl Kernels {
    pub fn new(opts: KernelOptions, tracker: MemoryTracker) -> Kernels {
        let threads = match opts.threads {
            0 => auto_threads(),
            t => t,
        };
        Kernels {
            kind: opts.kind,
            // Clamped to the core count: oversubscribing never helps a
            // compute-bound GEMM, and `memory::model`'s packing-scratch
            // term charges one panel set per core — an unclamped
            // `--threads 64` could otherwise exceed the admission bound.
            threads: threads.clamp(1, auto_threads()),
            arena: TensorArena::new(tracker),
            flops: AtomicU64::new(0),
        }
    }

    /// Single-threaded naive engine on a throwaway tracker (unit tests).
    pub fn for_tests() -> Kernels {
        Kernels::new(
            KernelOptions { kind: KernelKind::Naive, threads: 1 },
            MemoryTracker::new(),
        )
    }

    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn arena(&self) -> &TensorArena {
        &self.arena
    }

    /// Cumulative nominal FLOPs since construction.
    pub fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    /// Credit explicitly-counted work (attention loops).
    pub fn add_flops(&self, n: u64) {
        self.flops.fetch_add(n, Ordering::Relaxed);
    }

    /// `a[m,k] @ b[k,n] -> [m,n]`.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul(a, b, m, k, n, &mut out),
            _ => self.gemm(AView::Rows(a), BView::Rows(b), m, k, n, &mut out),
        }
        out
    }

    /// `aᵀ @ b` with `a[k,m]`, `b[k,n] -> [m,n]`.
    pub fn matmul_at(&self, a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul_at(a, b, k, m, n, &mut out),
            _ => self.gemm(
                AView::Cols { data: a, ld: m }, BView::Rows(b), m, k, n, &mut out,
            ),
        }
        out
    }

    /// `a @ bᵀ` with `a[m,k]`, `b[n,k] -> [m,n]`.
    pub fn matmul_bt(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul_bt(a, b, m, k, n, &mut out),
            _ => self.gemm(AView::Rows(a), BView::Cols(b), m, k, n, &mut out),
        }
        out
    }

    fn gemm(&self, a: AView, b: BView, m: usize, k: usize, n: usize, out: &mut [f32]) {
        let fan_out = self.kind == KernelKind::Parallel
            && self.threads > 1
            && m * k * n >= PARALLEL_MIN_MADDS
            && m >= 2 * tiled::MR;
        if fan_out {
            parallel::gemm(&self.arena, self.threads, a, b, m, k, n, out);
        } else {
            tiled::gemm(&self.arena, a, b, 0, m, k, n, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(m * k, 1.0), rng.normal_vec(k * n, 1.0))
    }

    fn engine(kind: KernelKind, threads: usize) -> Kernels {
        Kernels::new(KernelOptions { kind, threads }, MemoryTracker::new())
    }

    fn assert_close(a: &[f32], b: &[f32], k: usize) {
        assert_eq!(a.len(), b.len());
        let tol = 1e-5 * (k as f32).sqrt().max(1.0);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * x.abs().max(1.0),
                "elem {i}: {x} vs {y} (k={k})"
            );
        }
    }

    #[test]
    fn tiled_matches_naive_on_awkward_shapes() {
        let nv = engine(KernelKind::Naive, 1);
        let td = engine(KernelKind::Tiled, 1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (17, 33, 9),
                          (64, 64, 64), (13, 300, 21), (70, 1, 70)] {
            let (a, b) = mats(m, k, n, (m * 1000 + k * 10 + n) as u64);
            assert_close(&nv.matmul(&a, &b, m, k, n), &td.matmul(&a, &b, m, k, n), k);
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let nv = engine(KernelKind::Naive, 1);
        let td = engine(KernelKind::Tiled, 1);
        let (m, k, n) = (19, 37, 23);
        let mut rng = Rng::new(3);
        let a_t = rng.normal_vec(k * m, 1.0); // a stored [k,m]
        let b = rng.normal_vec(k * n, 1.0);
        assert_close(&nv.matmul_at(&a_t, &b, k, m, n),
                     &td.matmul_at(&a_t, &b, k, m, n), k);
        let a = rng.normal_vec(m * k, 1.0);
        let b_t = rng.normal_vec(n * k, 1.0); // b stored [n,k]
        assert_close(&nv.matmul_bt(&a, &b_t, m, k, n),
                     &td.matmul_bt(&a, &b_t, m, k, n), k);
    }

    #[test]
    fn parallel_is_bitwise_identical_to_tiled() {
        let td = engine(KernelKind::Tiled, 1);
        // force fan-out with a shape over the threshold
        let (m, k, n) = (128, 96, 128);
        assert!(m * k * n >= PARALLEL_MIN_MADDS);
        let (a, b) = mats(m, k, n, 11);
        let want = td.matmul(&a, &b, m, k, n);
        for threads in [1, 2, 3, 5] {
            let pl = engine(KernelKind::Parallel, threads);
            let got = pl.matmul(&a, &b, m, k, n);
            assert_eq!(&want[..], &got[..], "threads={threads} must not change bits");
        }
    }

    #[test]
    fn flop_counter_is_nominal() {
        let ks = engine(KernelKind::Tiled, 1);
        let (a, b) = mats(4, 6, 8, 1);
        let _ = ks.matmul(&a, &b, 4, 6, 8);
        assert_eq!(ks.flops(), 2 * 4 * 6 * 8);
        ks.add_flops(10);
        assert_eq!(ks.flops(), 2 * 4 * 6 * 8 + 10);
    }

    #[test]
    fn gemm_outputs_come_from_the_arena() {
        let ks = engine(KernelKind::Tiled, 1);
        let (a, b) = mats(8, 8, 8, 2);
        {
            let _o = ks.matmul(&a, &b, 8, 8, 8);
        }
        // second call reuses the first call's output buffer
        let _o2 = ks.matmul(&a, &b, 8, 8, 8);
        assert!(ks.arena().stats().hits >= 1);
    }
}
