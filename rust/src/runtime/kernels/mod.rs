//! The kernel engine: GEMM dispatch + scratch arena + FLOP accounting for
//! the pure-Rust reference backend.
//!
//! All heavy math in [`super::refmath`] goes through a [`Kernels`] handle,
//! which dispatches every GEMM to one of three variants
//! ([`crate::config::KernelKind`]):
//!
//! * [`naive`] — the original scalar triple loops, kept verbatim as the
//!   correctness **oracle**. This is the only variant allowed to keep the
//!   data-dependent `if av == 0.0 { continue; }` fast path (it makes step
//!   timing input-dependent, which a production kernel must not be).
//! * [`tiled`] — cache-blocked, register-tiled (MR×NR micro-kernel with
//!   packed operand panels, the classic BLIS structure), branch-free.
//!   The inner micro-kernel is dispatched at runtime to the best
//!   supported SIMD path ([`simd`]: AVX2 / SSE2 / NEON, scalar oracle
//!   fallback — all bitwise identical) and blocking parameters come from
//!   the cache-derived, optionally autotuned [`tune`] profile.
//! * [`parallel`] — the tiled kernel fanned out over contiguous row
//!   panels with `std::thread::scope`. Each output row is produced end to
//!   end by exactly one thread with the same k-blocking as `tiled`, so
//!   results are **bitwise identical** to `tiled` at any thread count.
//!
//! Thread budget: a lone session resolves `threads = 0` to all cores; the
//! fleet scheduler divides cores by its worker count before building the
//! backend so concurrent sessions never oversubscribe the machine.
//!
//! q4 path: frozen weights may arrive int4-packed ([`Q4View`] via
//! [`FrozenW`]). The tiled/parallel kernels dequantize packed panels on
//! the fly inside `pack_b` — the full f32 matrix never exists — while
//! the naive oracle host-dequantizes into arena scratch first. Panel
//! dequant evaluates exactly `model::quant::dequantize`'s expression, so
//! fused and host dequantization agree bitwise and the tiled ≡ parallel
//! bitwise guarantee carries over to q4 unchanged.
//!
//! Scratch discipline: GEMM outputs and packing panels are checked out of
//! the engine's [`TensorArena`], so they are reused across calls and
//! tracked under the `scratch` tag (see `memory::model::scratch` for the
//! matching analytical term).
//!
//! FLOP accounting: each GEMM adds its nominal `2·m·k·n` to a shared
//! counter (the naive oracle's zero-skip still counts full work);
//! `refmath`'s attention loops add their products explicitly. The
//! reference backend snapshots the counter around each artifact call to
//! report per-artifact FLOPs and achieved GFLOP/s in `exec_stats`.

pub mod flops;
pub mod naive;
pub mod parallel;
pub mod simd;
pub mod tiled;
pub mod tune;

use std::sync::atomic::{AtomicU64, Ordering};

pub use crate::config::KernelKind;
use crate::memory::MemoryTracker;
use crate::model::quant;
use crate::obs::TraceSink;
use crate::tensor::{ScratchBuf, TensorArena};

/// How the kernel engine is configured (CLI: `--kernel`, `--threads`).
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelOptions {
    pub kind: KernelKind,
    /// Worker threads for the `parallel` kernel; 0 = all cores.
    pub threads: usize,
}

/// The number of threads `threads = 0` resolves to.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Operand view of the left GEMM input.
#[derive(Debug, Clone, Copy)]
pub enum AView<'a> {
    /// `[m, k]` row-major: `A(i, l) = data[i*k + l]`.
    Rows(&'a [f32]),
    /// Stored transposed `[k, ld]` with `ld` the FULL row count:
    /// `A(i, l) = data[l*ld + i]` (the parallel kernel offsets `i`, so
    /// the stride must stay the whole matrix's).
    Cols { data: &'a [f32], ld: usize },
}

/// Operand view of the right GEMM input.
#[derive(Debug, Clone, Copy)]
pub enum BView<'a> {
    /// `[k, n]` row-major: `B(l, j) = data[l*n + j]`.
    Rows(&'a [f32]),
    /// Stored transposed `[n, k]`: `B(l, j) = data[j*k + l]`.
    Cols(&'a [f32]),
    /// int4-packed `[k, n]` (`k = din`): `B(l, j) = W(l, j)` dequantized
    /// on the fly inside the packing step — the full f32 matrix is never
    /// materialized.
    Q4(Q4View<'a>),
    /// Transposed use of an int4-packed `[n, k]` matrix:
    /// `B(l, j) = W(j, l)`, dequantized on the fly while packing.
    Q4T(Q4View<'a>),
}

/// Borrowed view of one int4-quantized matrix `[din, dout]` in the
/// `model::quant` layout: two din-rows packed per byte (even row in the
/// low nibble) and per-(64-row group, column) f32 scales.
#[derive(Debug, Clone, Copy)]
pub struct Q4View<'a> {
    pub packed: &'a [u8],
    pub scales: &'a [f32],
    pub din: usize,
    pub dout: usize,
}

impl<'a> Q4View<'a> {
    pub fn new(packed: &'a [u8], scales: &'a [f32], din: usize, dout: usize) -> Q4View<'a> {
        debug_assert_eq!(packed.len(), din / 2 * dout);
        debug_assert_eq!(scales.len(), din / quant::GROUP * dout);
        Q4View { packed, scales, din, dout }
    }

    /// Dequantize element `(r, c)` — the exact expression
    /// `quant::dequantize` evaluates, so fused and host dequantization
    /// are bitwise identical.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        let b = self.packed[(r / 2) * self.dout + c];
        let nib = if r % 2 == 0 { b & 0x0f } else { (b >> 4) & 0x0f };
        quant::sign_extend(nib) as f32
            * self.scales[(r / quant::GROUP) * self.dout + c]
    }

    /// Logical bytes this matrix occupies packed (tracker/model input).
    pub fn bytes(&self) -> u64 {
        quant::quantized_bytes(self.din, self.dout)
    }
}

/// A frozen weight as the block math consumes it: either a plain f32
/// slice or an int4-packed matrix that stays packed for the whole
/// session (paper §4.5). LoRA adapters and the norm weights are always
/// f32 — only the seven projection GEMMs ever see the `Q4` arm.
#[derive(Debug, Clone, Copy)]
pub enum FrozenW<'a> {
    F32(&'a [f32]),
    Q4(Q4View<'a>),
}

impl<'a> FrozenW<'a> {
    /// The f32 slice of a weight that is never quantized (RMSNorm gains).
    pub fn f32(&self) -> &'a [f32] {
        match *self {
            FrozenW::F32(w) => w,
            FrozenW::Q4(_) => panic!("norm weights are never int4-packed"),
        }
    }
}

/// GEMMs below this many multiply-adds stay single-threaded even under
/// the `parallel` kernel: thread spawn/join costs more than it saves.
/// Shape-dependent only — never data-dependent. 2^18 madds ≈ 130 µs of
/// *scalar* tiled single-thread work — a few scoped-thread spawns still
/// pay off at that size. This constant is the scalar threshold; the
/// dispatch gate scales it by the active ISA's micro-kernel throughput
/// via [`parallel_min_madds`].
pub const PARALLEL_MIN_MADDS: usize = 1 << 18;

/// The fan-out threshold for `isa`: spawn/join overhead is fixed wall
/// clock, so the break-even GEMM size grows with micro-kernel speed —
/// ~2× for the 4-wide SSE2/NEON kernels, ~4× for the 8-wide AVX2 kernel
/// (measured speedups over scalar on the calibration set are 4–6×, but
/// fan-out below the threshold merely wastes less, so round down).
pub fn parallel_min_madds(isa: simd::Isa) -> usize {
    match isa {
        simd::Isa::Scalar => PARALLEL_MIN_MADDS,
        simd::Isa::Sse2 | simd::Isa::Neon => PARALLEL_MIN_MADDS << 1,
        simd::Isa::Avx2 => PARALLEL_MIN_MADDS << 2,
    }
}

/// The shape-only fan-out gate, exposed as a pure function so the
/// dispatch threshold is testable without a multi-core machine: `true`
/// iff a GEMM of this shape would run on the parallel kernel.
pub fn would_fan_out(
    kind: KernelKind,
    threads: usize,
    isa: simd::Isa,
    m: usize,
    k: usize,
    n: usize,
) -> bool {
    kind == KernelKind::Parallel
        && threads > 1
        && m * k * n >= parallel_min_madds(isa)
        && m >= 2 * isa.mr()
}

/// The kernel engine handle: dispatch + arena + FLOP counter. One per
/// backend instance; shared by every artifact call of a session.
#[derive(Debug)]
pub struct Kernels {
    kind: KernelKind,
    threads: usize,
    /// Micro-kernel ISA; detected best (or `MESP_KERNEL_ISA`) by
    /// default, overridable per instance via [`Kernels::with_isa`].
    isa: simd::Isa,
    /// Blocking parameters; the process-wide tuned/derived tiles by
    /// default, overridable per instance via [`Kernels::with_tiles`].
    tiles: tune::Tiles,
    arena: TensorArena,
    flops: AtomicU64,
    /// Per-GEMM span sink; disabled by default (one branch per call).
    trace: TraceSink,
}

impl Kernels {
    pub fn new(opts: KernelOptions, tracker: MemoryTracker) -> Kernels {
        let threads = match opts.threads {
            0 => auto_threads(),
            t => t,
        };
        Kernels {
            kind: opts.kind,
            // Clamped to the core count: oversubscribing never helps a
            // compute-bound GEMM, and `memory::model`'s packing-scratch
            // term charges one panel set per core — an unclamped
            // `--threads 64` could otherwise exceed the admission bound.
            threads: threads.clamp(1, auto_threads()),
            isa: simd::detect(),
            tiles: tune::active_tiles(),
            arena: TensorArena::new(tracker),
            flops: AtomicU64::new(0),
            trace: TraceSink::disabled(),
        }
    }

    /// Attach a trace sink: every GEMM emits a span (shape + FLOPs +
    /// ISA/tile tags) and the arena emits checkout/return instants.
    /// Consuming builder so `KernelOptions` stays a plain `Copy` struct.
    pub fn with_trace(mut self, trace: TraceSink) -> Kernels {
        self.arena = self.arena.with_trace(trace.clone());
        self.trace = trace;
        self
    }

    /// Force a micro-kernel ISA (benches compare ISAs in one process;
    /// tests pin the scalar oracle). An ISA the CPU cannot execute falls
    /// back to the detected best — results are bitwise identical either
    /// way, so the fallback is safe.
    pub fn with_isa(mut self, isa: simd::Isa) -> Kernels {
        self.isa = if simd::cpu_supports(isa) { isa } else { simd::detect() };
        self
    }

    /// Force blocking parameters (the tuner's sweep and hermetic tests;
    /// normal construction uses the process-wide [`tune::active_tiles`]).
    pub fn with_tiles(mut self, tiles: tune::Tiles) -> Kernels {
        self.tiles = tiles;
        self
    }

    /// Single-threaded naive engine on a throwaway tracker (unit tests).
    pub fn for_tests() -> Kernels {
        Kernels::new(
            KernelOptions { kind: KernelKind::Naive, threads: 1 },
            MemoryTracker::new(),
        )
    }

    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn isa(&self) -> simd::Isa {
        self.isa
    }

    pub fn tiles(&self) -> tune::Tiles {
        self.tiles
    }

    /// Whether a GEMM of this shape would fan out to the parallel
    /// kernel under this engine's configuration.
    pub fn fans_out(&self, m: usize, k: usize, n: usize) -> bool {
        would_fan_out(self.kind, self.threads, self.isa, m, k, n)
    }

    pub fn arena(&self) -> &TensorArena {
        &self.arena
    }

    /// The engine's span sink (disabled unless [`Kernels::with_trace`]
    /// attached one). Lets refmath emit sub-artifact spans — e.g. the
    /// per-chunk loss-head spans — through the same sink the per-GEMM
    /// spans use.
    pub fn trace(&self) -> &TraceSink {
        &self.trace
    }

    /// Cumulative nominal FLOPs since construction.
    pub fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    /// Credit explicitly-counted work (attention loops).
    pub fn add_flops(&self, n: u64) {
        self.flops.fetch_add(n, Ordering::Relaxed);
    }

    /// Open a per-GEMM trace span tagged with this engine's ISA and
    /// blocking tiles alongside the shape/FLOP args.
    fn gemm_span(&self, name: &'static str, m: usize, k: usize, n: usize) -> crate::obs::Span {
        self.trace.gemm(
            name, m, k, n,
            self.isa.name(),
            (self.tiles.mc(), self.tiles.kc(), self.tiles.nc()),
        )
    }

    /// `a[m,k] @ b[k,n] -> [m,n]`.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let _sp = self.gemm_span("matmul", m, k, n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul(a, b, m, k, n, &mut out),
            _ => self.gemm(AView::Rows(a), BView::Rows(b), m, k, n, &mut out),
        }
        out
    }

    /// `aᵀ @ b` with `a[k,m]`, `b[k,n] -> [m,n]`.
    pub fn matmul_at(&self, a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        let _sp = self.gemm_span("matmul_at", m, k, n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul_at(a, b, k, m, n, &mut out),
            _ => self.gemm(
                AView::Cols { data: a, ld: m }, BView::Rows(b), m, k, n, &mut out,
            ),
        }
        out
    }

    /// `a @ bᵀ` with `a[m,k]`, `b[n,k] -> [m,n]`.
    pub fn matmul_bt(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> ScratchBuf {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        let _sp = self.gemm_span("matmul_bt", m, k, n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => naive::matmul_bt(a, b, m, k, n, &mut out),
            _ => self.gemm(AView::Rows(a), BView::Cols(b), m, k, n, &mut out),
        }
        out
    }

    /// `a[m,k] @ W` with `W [k, n]` a frozen weight (f32 or int4-packed).
    pub fn matmul_w(&self, a: &[f32], w: FrozenW, m: usize, k: usize, n: usize) -> ScratchBuf {
        match w {
            FrozenW::F32(w) => self.matmul(a, w, m, k, n),
            FrozenW::Q4(q) => {
                debug_assert_eq!((q.din, q.dout), (k, n));
                self.matmul_q4(a, q, m)
            }
        }
    }

    /// `a[m,k] @ Wᵀ` with `W [n, k]` a frozen weight (f32 or int4-packed).
    pub fn matmul_wt(&self, a: &[f32], w: FrozenW, m: usize, k: usize, n: usize) -> ScratchBuf {
        match w {
            FrozenW::F32(w) => self.matmul_bt(a, w, m, k, n),
            FrozenW::Q4(q) => {
                debug_assert_eq!((q.din, q.dout), (n, k));
                self.matmul_bt_q4(a, q, m)
            }
        }
    }

    /// `a[m, din] @ dequant(W)` with `W` int4-packed `[din, dout]`. The
    /// tiled/parallel kernels dequantize int4 panels on the fly inside
    /// the packing step (no full f32 materialization); the naive oracle
    /// host-dequantizes the whole matrix into arena scratch first — its
    /// reference semantics, and the bound behind the memory model's
    /// dequant-buffer term.
    pub fn matmul_q4(&self, a: &[f32], w: Q4View, m: usize) -> ScratchBuf {
        let (k, n) = (w.din, w.dout);
        debug_assert_eq!(a.len(), m * k);
        let _sp = self.gemm_span("matmul_q4", m, k, n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => {
                let deq = self.dequant_full(w);
                naive::matmul(a, &deq, m, k, n, &mut out);
            }
            _ => self.gemm(AView::Rows(a), BView::Q4(w), m, k, n, &mut out),
        }
        out
    }

    /// `a[m, dout] @ dequant(W)ᵀ` with `W` int4-packed `[din, dout]` —
    /// the frozen-weight VJP (`g @ Wᵀ`) over packed weights.
    pub fn matmul_bt_q4(&self, a: &[f32], w: Q4View, m: usize) -> ScratchBuf {
        let (k, n) = (w.dout, w.din);
        debug_assert_eq!(a.len(), m * k);
        let _sp = self.gemm_span("matmul_bt_q4", m, k, n);
        let mut out = self.arena.take(m * n);
        self.add_flops(2 * (m * k * n) as u64);
        match self.kind {
            KernelKind::Naive => {
                let deq = self.dequant_full(w);
                naive::matmul_bt(a, &deq, m, k, n, &mut out);
            }
            _ => self.gemm(AView::Rows(a), BView::Q4T(w), m, k, n, &mut out),
        }
        out
    }

    /// Full host dequantization into arena scratch (naive oracle only).
    fn dequant_full(&self, w: Q4View) -> ScratchBuf {
        let mut out = self.arena.take(w.din * w.dout);
        quant::dequantize_into(w.packed, w.scales, w.din, w.dout, &mut out);
        out
    }

    fn gemm(&self, a: AView, b: BView, m: usize, k: usize, n: usize, out: &mut [f32]) {
        if self.fans_out(m, k, n) {
            parallel::gemm(
                &self.arena, self.threads, self.isa, self.tiles, a, b, m, k, n, out,
            );
        } else {
            tiled::gemm(&self.arena, self.isa, self.tiles, a, b, 0, m, k, n, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(m * k, 1.0), rng.normal_vec(k * n, 1.0))
    }

    fn engine(kind: KernelKind, threads: usize) -> Kernels {
        Kernels::new(KernelOptions { kind, threads }, MemoryTracker::new())
    }

    fn assert_close(a: &[f32], b: &[f32], k: usize) {
        assert_eq!(a.len(), b.len());
        let tol = 1e-5 * (k as f32).sqrt().max(1.0);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * x.abs().max(1.0),
                "elem {i}: {x} vs {y} (k={k})"
            );
        }
    }

    #[test]
    fn tiled_matches_naive_on_awkward_shapes() {
        let nv = engine(KernelKind::Naive, 1);
        let td = engine(KernelKind::Tiled, 1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (17, 33, 9),
                          (64, 64, 64), (13, 300, 21), (70, 1, 70)] {
            let (a, b) = mats(m, k, n, (m * 1000 + k * 10 + n) as u64);
            assert_close(&nv.matmul(&a, &b, m, k, n), &td.matmul(&a, &b, m, k, n), k);
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let nv = engine(KernelKind::Naive, 1);
        let td = engine(KernelKind::Tiled, 1);
        let (m, k, n) = (19, 37, 23);
        let mut rng = Rng::new(3);
        let a_t = rng.normal_vec(k * m, 1.0); // a stored [k,m]
        let b = rng.normal_vec(k * n, 1.0);
        assert_close(&nv.matmul_at(&a_t, &b, k, m, n),
                     &td.matmul_at(&a_t, &b, k, m, n), k);
        let a = rng.normal_vec(m * k, 1.0);
        let b_t = rng.normal_vec(n * k, 1.0); // b stored [n,k]
        assert_close(&nv.matmul_bt(&a, &b_t, m, k, n),
                     &td.matmul_bt(&a, &b_t, m, k, n), k);
    }

    #[test]
    fn parallel_is_bitwise_identical_to_tiled() {
        let td = engine(KernelKind::Tiled, 1);
        // force fan-out with a shape over the threshold
        let (m, k, n) = (128, 96, 128);
        assert!(m * k * n >= PARALLEL_MIN_MADDS);
        let (a, b) = mats(m, k, n, 11);
        let want = td.matmul(&a, &b, m, k, n);
        for threads in [1, 2, 3, 5] {
            let pl = engine(KernelKind::Parallel, threads);
            let got = pl.matmul(&a, &b, m, k, n);
            assert_eq!(&want[..], &got[..], "threads={threads} must not change bits");
        }
    }

    #[test]
    fn q4_fused_equals_host_dequant_bitwise() {
        // Panel dequant inside pack_b must reproduce quant::dequantize
        // exactly, so q4 GEMMs equal f32 GEMMs over the dequantized
        // matrix BITWISE — per kernel kind, both operand forms.
        let (m, k, n) = (9, 128, 24);
        let mut rng = Rng::new(21);
        let w = rng.normal_vec(k * n, 0.05);
        let (packed, scales) = quant::quantize(&w, k, n);
        let deq = quant::dequantize(&packed, &scales, k, n);
        let view = Q4View::new(&packed, &scales, k, n);
        let a = rng.normal_vec(m * k, 1.0);
        let g = rng.normal_vec(m * n, 1.0);
        for kind in [KernelKind::Naive, KernelKind::Tiled] {
            let ks = engine(kind, 1);
            assert_eq!(
                &ks.matmul_q4(&a, view, m)[..],
                &ks.matmul(&a, &deq, m, k, n)[..],
                "{}: x @ W", kind.name()
            );
            assert_eq!(
                &ks.matmul_bt_q4(&g, view, m)[..],
                &ks.matmul_bt(&g, &deq, m, n, k)[..],
                "{}: g @ Wᵀ", kind.name()
            );
        }
    }

    #[test]
    fn q4_parallel_is_bitwise_identical_to_tiled() {
        // Big enough to clear PARALLEL_MIN_MADDS so fan-out is real.
        let (m, k, n) = (128, 128, 128);
        assert!(m * k * n >= PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(22);
        let w = rng.normal_vec(k * n, 0.05);
        let (packed, scales) = quant::quantize(&w, k, n);
        let view = Q4View::new(&packed, &scales, k, n);
        let a = rng.normal_vec(m * k, 1.0);
        let td = engine(KernelKind::Tiled, 1);
        let want = td.matmul_q4(&a, view, m);
        for threads in [2, 3, 5] {
            let pl = engine(KernelKind::Parallel, threads);
            assert_eq!(&want[..], &pl.matmul_q4(&a, view, m)[..],
                       "threads={threads}");
            assert_eq!(&td.matmul_bt_q4(&a, view, m)[..],
                       &pl.matmul_bt_q4(&a, view, m)[..],
                       "bt threads={threads}");
        }
    }

    #[test]
    fn frozen_dispatch_routes_both_arms() {
        let (m, k, n) = (4, 64, 8);
        let mut rng = Rng::new(23);
        let w = rng.normal_vec(k * n, 0.05);
        let (packed, scales) = quant::quantize(&w, k, n);
        let deq = quant::dequantize(&packed, &scales, k, n);
        let a = rng.normal_vec(m * k, 1.0);
        let ks = engine(KernelKind::Tiled, 1);
        let f = ks.matmul_w(&a, FrozenW::F32(&deq), m, k, n);
        let q = ks.matmul_w(&a, FrozenW::Q4(Q4View::new(&packed, &scales, k, n)), m, k, n);
        assert_eq!(&f[..], &q[..]);
        assert_eq!(FrozenW::F32(&deq[..]).f32().len(), k * n);
    }

    #[test]
    #[should_panic(expected = "never int4-packed")]
    fn frozen_f32_accessor_rejects_q4() {
        let packed = vec![0u8; 64 / 2];
        let scales = vec![0.0f32; 1];
        let _ = FrozenW::Q4(Q4View::new(&packed, &scales, 64, 1)).f32();
    }

    #[test]
    fn flop_counter_is_nominal() {
        let ks = engine(KernelKind::Tiled, 1);
        let (a, b) = mats(4, 6, 8, 1);
        let _ = ks.matmul(&a, &b, 4, 6, 8);
        assert_eq!(ks.flops(), 2 * 4 * 6 * 8);
        ks.add_flops(10);
        assert_eq!(ks.flops(), 2 * 4 * 6 * 8 + 10);
    }

    #[test]
    fn traced_gemms_emit_shape_spans() {
        let sink = TraceSink::enabled();
        let ks = engine(KernelKind::Tiled, 1).with_trace(sink.clone());
        let (a, b) = mats(4, 6, 8, 9);
        let _o = ks.matmul(&a, &b, 4, 6, 8);
        let evs = sink.events();
        let gemm = evs
            .iter()
            .find(|e| e.cat == "gemm")
            .expect("a gemm span must be recorded");
        assert_eq!(gemm.name, "matmul");
        let arg = |key: &str| {
            gemm.args
                .iter()
                .find(|(k, _)| *k == key)
                .and_then(|(_, v)| v.as_f64())
        };
        assert_eq!(arg("m"), Some(4.0));
        assert_eq!(arg("k"), Some(6.0));
        assert_eq!(arg("n"), Some(8.0));
        assert_eq!(arg("flops"), Some(2.0 * 4.0 * 6.0 * 8.0));
        let strarg = |key: &str| {
            gemm.args
                .iter()
                .find(|(k, _)| *k == key)
                .and_then(|(_, v)| v.as_str().map(str::to_owned))
        };
        assert_eq!(strarg("isa").as_deref(), Some(ks.isa().name()));
        let t = ks.tiles();
        assert_eq!(
            strarg("tiles"),
            Some(format!("{}x{}x{}", t.mc(), t.kc(), t.nc()))
        );
        assert!(evs.iter().any(|e| e.name == "arena:take"));
    }

    #[test]
    fn fan_out_threshold_scales_with_isa() {
        // Exactly at the ISA's threshold → fan out; one madd below → stay
        // single-threaded. m and n are fixed so only k moves.
        let (m, n) = (64, 64);
        for isa in simd::Isa::ALL {
            let min = parallel_min_madds(isa);
            assert_eq!(min % (m * n), 0, "threshold divisible for exact k");
            let k_at = min / (m * n);
            assert!(would_fan_out(KernelKind::Parallel, 4, isa, m, k_at, n),
                    "{}: at threshold", isa.name());
            assert!(!would_fan_out(KernelKind::Parallel, 4, isa, m, k_at - 1, n),
                    "{}: below threshold", isa.name());
            // SIMD kernels need strictly more work than scalar to be
            // worth the spawn cost.
            if isa != simd::Isa::Scalar {
                assert!(parallel_min_madds(isa) > parallel_min_madds(simd::Isa::Scalar));
            }
        }
        // Never fans out single-threaded or off the parallel kind.
        let big = 1 << 12;
        assert!(!would_fan_out(KernelKind::Parallel, 1, simd::Isa::Scalar, big, big, big));
        assert!(!would_fan_out(KernelKind::Tiled, 4, simd::Isa::Scalar, big, big, big));
    }

    #[test]
    fn with_isa_rejects_unsupported_and_with_tiles_swaps_profile() {
        // Forcing an ISA the CPU lacks must fall back to the detected
        // one instead of dispatching into a SIGILL.
        for isa in simd::Isa::ALL {
            let ks = engine(KernelKind::Tiled, 1).with_isa(isa);
            assert!(simd::cpu_supports(ks.isa()), "{}", isa.name());
            if simd::cpu_supports(isa) {
                assert_eq!(ks.isa(), isa);
            } else {
                assert_eq!(ks.isa(), simd::detect());
            }
        }
        // Every supported ISA and a non-default tile profile produce the
        // same bits as the scalar/baseline engine (unfused accumulation,
        // same k-order — KC only regroups when k exceeds it, and both
        // profiles keep kc ≥ this k).
        let (m, k, n) = (13, 65, 29);
        let (a, b) = mats(m, k, n, 31);
        let want = engine(KernelKind::Tiled, 1)
            .with_isa(simd::Isa::Scalar)
            .matmul(&a, &b, m, k, n);
        for isa in simd::supported() {
            let ks = engine(KernelKind::Tiled, 1)
                .with_isa(isa)
                .with_tiles(tune::Tiles::new(40, 96, 48));
            let t = ks.tiles();
            assert!(t.kc() >= k);
            assert_eq!(&want[..], &ks.matmul(&a, &b, m, k, n)[..], "{}", isa.name());
        }
    }

    #[test]
    fn gemm_outputs_come_from_the_arena() {
        let ks = engine(KernelKind::Tiled, 1);
        let (a, b) = mats(8, 8, 8, 2);
        {
            let _o = ks.matmul(&a, &b, 8, 8, 8);
        }
        // second call reuses the first call's output buffer
        let _o2 = ks.matmul(&a, &b, 8, 8, 8);
        assert!(ks.arena().stats().hits >= 1);
    }
}
