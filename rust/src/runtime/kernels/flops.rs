//! Analytical per-artifact FLOP inventory — the exact mirror of what the
//! instrumented kernel engine counts at run time: every GEMM at its
//! nominal `2·m·k·n`, attention at its explicitly-credited products.
//! Elementwise work (norms, SiLU, RoPE, softmax normalization) is
//! uncounted on both sides, so `tests` can pin measured == analytical.
//! The counts are nominal — kernel kind, micro-kernel ISA and tile
//! profile change achieved GFLOP/s, never the FLOPs counted, so the
//! inventory needs no SIMD awareness.
//!
//! Used by `mesp inspect` (which never executes artifacts) and by the
//! GFLOP/s column sanity tests; `exec_stats` itself reports the measured
//! counter.

use crate::config::ModelDims;

fn gemm(m: usize, k: usize, n: usize) -> u64 {
    2 * (m as u64) * (k as u64) * (n as u64)
}

/// `y = xW + s(xA)B` on one LoRA site.
fn lora_fwd(m: usize, din: usize, dout: usize, r: usize) -> u64 {
    gemm(m, din, r) + gemm(m, din, dout) + gemm(m, r, dout)
}

/// Appendix-A LoRA backward; `stored_h` skips the `h = xA` recompute.
fn lora_bwd(m: usize, din: usize, dout: usize, r: usize, stored_h: bool) -> u64 {
    let recompute = if stored_h { 0 } else { gemm(m, din, r) };
    gemm(m, dout, r)        // dh = s·g @ Bᵀ
        + gemm(m, din, r)   // dA = xᵀ @ dh
        + recompute
        + gemm(m, r, dout)  // dB = hᵀ @ s·g
        + gemm(m, r, din)   // gx = dh @ Aᵀ
        + gemm(m, dout, din) // + g @ Wᵀ
}

fn attention_fwd(d: &ModelDims) -> u64 {
    let (b, h, n, hd) = (d.batch, d.n_heads, d.seq, d.head_dim);
    // QK and PV each do Σ_i (i+1)·hd multiply-adds per (batch, head).
    (b * h) as u64 * 2 * (n * (n + 1)) as u64 * hd as u64
}

fn attention_bwd(d: &ModelDims) -> u64 {
    let (b, h, n, hd) = (d.batch, d.n_heads, d.seq, d.head_dim);
    // per head: dv, dprobs, dq, dk GEMMs + the 3n² softmax-VJP pass
    (b * h) as u64 * (4 * gemm(n, n, hd) + 3 * (n * n) as u64)
}

/// Sum over the seven LoRA sites of `f(m, din, dout, r)`.
fn over_sites(d: &ModelDims, f: impl Fn(usize, usize, usize, usize) -> u64) -> u64 {
    let m = d.m();
    crate::config::PROJS
        .iter()
        .map(|p| {
            let (din, dout) = d.proj_dims(p);
            f(m, din, dout, d.rank)
        })
        .sum()
}

fn block_forward(d: &ModelDims) -> u64 {
    over_sites(d, lora_fwd) + attention_fwd(d)
}

fn block_backward(d: &ModelDims, stored_h: bool) -> u64 {
    over_sites(d, |m, din, dout, r| lora_bwd(m, din, dout, r, stored_h))
        + attention_bwd(d)
}

fn lm_logits(d: &ModelDims) -> u64 {
    gemm(d.m(), d.d_model, d.vocab)
}

/// Nominal FLOPs of one call of artifact `name` at dims `d` (0 for pure
/// data movement like `embed_fwd`, and for unknown names). The `_q4`
/// variants count the same GEMM work as their f32 counterparts: the
/// in-panel dequant multiply is elementwise O(k·n) bookkeeping that the
/// instrumented kernel engine does not count either, so measured ==
/// analytical holds on both paths.
pub fn artifact(d: &ModelDims, name: &str) -> u64 {
    let name = name.strip_suffix("_q4").unwrap_or(name);
    match name {
        "block_fwd" | "block_fwd_saveh" | "block_fwd_residuals" => block_forward(d),
        // MeSP's fused call recomputes the forward in-call; store-h only
        // skips the seven h = xA recomputes; the residual path does no
        // forward at all.
        "block_bwd_mesp" => block_forward(d) + block_backward(d, false),
        "block_bwd_storeh" => block_forward(d) + block_backward(d, true),
        "block_bwd_residuals" => block_backward(d, true),
        "lm_loss_fwd" => lm_logits(d),
        "lm_loss_grad" => lm_logits(d) + gemm(d.m(), d.vocab, d.d_model),
        _ => 0,
    }
}

/// Frozen-weight bytes one block call streams through the GEMMs — the
/// "byte" half of the FLOP/byte inventory. f32 calls read every frozen
/// matrix at 4 B/param; `_q4` calls read the packed nibbles + group
/// scales instead (norm gains stay f32 on both paths). Arithmetic
/// intensity of the frozen GEMMs therefore rises ~7× under q4, which is
/// what makes the fused-dequant kernels pay off on memory-bound shapes.
pub fn artifact_weight_bytes(d: &ModelDims, name: &str) -> u64 {
    let q4 = name.ends_with("_q4");
    let base = name.strip_suffix("_q4").unwrap_or(name);
    let per_block: u64 = if q4 {
        crate::model::quant::packed_block_bytes(d)
    } else {
        d.frozen_params_per_block() as u64 * 4
    };
    match base {
        "block_fwd" | "block_fwd_saveh" | "block_fwd_residuals"
        | "block_bwd_mesp" | "block_bwd_storeh" | "block_bwd_residuals" => per_block,
        "lm_loss_fwd" | "lm_loss_grad" => {
            (d.vocab * d.d_model + d.d_model) as u64 * 4
        }
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn backward_costs_more_than_forward() {
        let d = presets::compiled("small").unwrap();
        let fwd = artifact(&d, "block_fwd");
        assert!(fwd > 0);
        assert!(artifact(&d, "block_bwd_mesp") > fwd);
        // storing h skips work relative to the fused recompute
        assert!(artifact(&d, "block_bwd_storeh") < artifact(&d, "block_bwd_mesp"));
        // the residual path does no forward at all
        assert!(artifact(&d, "block_bwd_residuals") < artifact(&d, "block_bwd_storeh"));
        assert_eq!(artifact(&d, "embed_fwd"), 0);
        assert_eq!(artifact(&d, "unknown"), 0);
    }

    #[test]
    fn q4_variants_count_the_same_flops() {
        let d = presets::compiled("toy").unwrap();
        for base in ["block_fwd", "block_fwd_saveh", "block_fwd_residuals",
                     "block_bwd_mesp", "block_bwd_storeh",
                     "block_bwd_residuals"] {
            let q4 = format!("{base}_q4");
            assert_eq!(artifact(&d, base), artifact(&d, &q4), "{base}");
            assert!(artifact(&d, &q4) > 0);
        }
    }

    #[test]
    fn q4_weight_bytes_shrink_frozen_traffic() {
        let d = presets::compiled("toy").unwrap();
        let f32b = artifact_weight_bytes(&d, "block_bwd_mesp");
        let q4b = artifact_weight_bytes(&d, "block_bwd_mesp_q4");
        assert!(q4b > 0 && q4b < f32b / 2, "q4 {q4b} !< f32 {f32b} / 2");
        assert_eq!(artifact_weight_bytes(&d, "embed_fwd"), 0);
    }

    #[test]
    fn scales_with_dims() {
        let toy = presets::compiled("toy").unwrap();
        let small = presets::compiled("small").unwrap();
        assert!(artifact(&small, "block_fwd") > artifact(&toy, "block_fwd"));
        assert!(artifact(&small, "lm_loss_grad") == 2 * artifact(&small, "lm_loss_fwd"));
    }
}
