//! Gradient-quality analysis (paper §5.6, Table 3): how well does an
//! estimated gradient match the exact one? Metrics per layer: cosine
//! similarity, sign agreement, relative error.

use crate::util::stats;

/// One layer's gradient-quality row (Table 3 format).
#[derive(Debug, Clone)]
pub struct GradQuality {
    pub layer: usize,
    pub cosine: f64,
    pub sign_agree: f64,
    pub rel_error: f64,
}

/// Compare estimated vs exact per-layer gradient vectors.
pub fn grad_quality(estimate: &[Vec<f32>], exact: &[Vec<f32>]) -> Vec<GradQuality> {
    assert_eq!(estimate.len(), exact.len(), "layer count mismatch");
    estimate
        .iter()
        .zip(exact)
        .enumerate()
        .map(|(layer, (e, t))| GradQuality {
            layer,
            cosine: stats::cosine(e, t),
            sign_agree: stats::sign_agreement(e, t),
            rel_error: stats::rel_error(e, t),
        })
        .collect()
}

/// Average row across layers (the paper's "Avg" line).
pub fn average(rows: &[GradQuality]) -> GradQuality {
    let n = rows.len().max(1) as f64;
    GradQuality {
        layer: usize::MAX,
        cosine: rows.iter().map(|r| r.cosine).sum::<f64>() / n,
        sign_agree: rows.iter().map(|r| r.sign_agree).sum::<f64>() / n,
        rel_error: rows.iter().map(|r| r.rel_error).sum::<f64>() / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_gradients_are_perfect() {
        let g = vec![vec![1.0f32, -2.0, 3.0]];
        let q = grad_quality(&g, &g);
        assert!((q[0].cosine - 1.0).abs() < 1e-9);
        assert_eq!(q[0].sign_agree, 1.0);
        assert_eq!(q[0].rel_error, 0.0);
    }

    #[test]
    fn random_gradients_near_zero_cosine() {
        use crate::util::Rng;
        let mut r = Rng::new(0);
        let a: Vec<f32> = r.normal_vec(10_000, 1.0);
        let b: Vec<f32> = r.normal_vec(10_000, 1.0);
        let q = grad_quality(&[a], &[b]);
        assert!(q[0].cosine.abs() < 0.05, "cos {}", q[0].cosine);
        assert!((q[0].sign_agree - 0.5).abs() < 0.05);
    }

    #[test]
    fn average_row() {
        let rows = vec![
            GradQuality { layer: 0, cosine: 0.0, sign_agree: 0.4, rel_error: 1.0 },
            GradQuality { layer: 1, cosine: 0.2, sign_agree: 0.6, rel_error: 3.0 },
        ];
        let avg = average(&rows);
        assert!((avg.cosine - 0.1).abs() < 1e-12);
        assert!((avg.sign_agree - 0.5).abs() < 1e-12);
        assert!((avg.rel_error - 2.0).abs() < 1e-12);
    }
}
