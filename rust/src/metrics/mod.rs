//! Metrics: step logging (JSONL + console), gradient-quality analysis
//! (paper Table 3), and markdown table rendering for the reproduce
//! drivers.

pub mod gradqual;
pub mod tables;

use std::io::Write;
use std::path::Path;

use crate::train::StepStats;
use crate::util::{stats, Json};

pub use gradqual::{grad_quality, GradQuality};
pub use tables::{exec_stats_table, TableBuilder};

/// Step-metrics sink: JSONL file and/or periodic console lines.
pub struct MetricsLogger {
    file: Option<std::fs::File>,
    log_every: usize,
    pub history: Vec<StepStats>,
}

impl MetricsLogger {
    pub fn new(path: Option<&Path>, log_every: usize) -> anyhow::Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(MetricsLogger { file, log_every: log_every.max(1), history: Vec::new() })
    }

    pub fn record(&mut self, method: &str, s: &StepStats) -> anyhow::Result<()> {
        if let Some(f) = self.file.as_mut() {
            let line = Json::obj(vec![
                ("step", Json::num(s.step as f64)),
                ("method", Json::str(method)),
                ("loss", Json::num(s.loss)),
                ("peak_bytes", Json::num(s.peak_bytes as f64)),
                ("secs", Json::num(s.secs)),
                ("live_after", Json::num(s.live_after as f64)),
            ]);
            writeln!(f, "{}", line.to_string())?;
        }
        if s.step % self.log_every == 0 || s.step == 1 {
            eprintln!(
                "[{method}] step {:>6}  loss {:.4}  peak {:>8} MB  {:.3}s",
                s.step, s.loss,
                stats::fmt_mb(s.peak_bytes),
                s.secs
            );
        }
        self.history.push(s.clone());
        Ok(())
    }

    /// Summary over the recorded history (excluding warmup step 1).
    pub fn summary(&self) -> RunSummary {
        let h: Vec<&StepStats> =
            self.history.iter().filter(|s| s.step > 1).collect();
        let losses: Vec<f64> = h.iter().map(|s| s.loss).collect();
        let times: Vec<f64> = h.iter().map(|s| s.secs).collect();
        RunSummary {
            steps: self.history.len(),
            final_loss: self.history.last().map(|s| s.loss).unwrap_or(f64::NAN),
            mean_step_secs: stats::mean(&times),
            p50_step_secs: stats::percentile(&times, 50.0),
            peak_bytes: self.history.iter().map(|s| s.peak_bytes).max()
                .unwrap_or(0),
            mean_loss_last_10: stats::mean(
                &losses[losses.len().saturating_sub(10)..]),
        }
    }
}

/// Aggregate result of a training run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub steps: usize,
    pub final_loss: f64,
    pub mean_step_secs: f64,
    pub p50_step_secs: f64,
    pub peak_bytes: u64,
    pub mean_loss_last_10: f64,
}

impl RunSummary {
    /// A run is healthy if it recorded steps and its losses stayed
    /// finite — the fleet report flags divergent jobs with this.
    pub fn healthy(&self) -> bool {
        self.steps > 0
            && self.final_loss.is_finite()
            && self.mean_loss_last_10.is_finite()
    }

    pub fn print(&self, method: &str) {
        println!(
            "{method}: {} steps, final loss {:.4} (last-10 mean {:.4}), \
             peak {} MB, {:.3}s/step (p50 {:.3}s)",
            self.steps, self.final_loss, self.mean_loss_last_10,
            stats::fmt_mb(self.peak_bytes), self.mean_step_secs,
            self.p50_step_secs
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(step: usize, loss: f64) -> StepStats {
        StepStats { step, loss, peak_bytes: 1000 * step as u64,
                    secs: 0.1, live_after: 10 }
    }

    #[test]
    fn records_and_summarizes() {
        let mut m = MetricsLogger::new(None, 100).unwrap();
        for i in 1..=5 {
            m.record("MeSP", &stat(i, 5.0 - i as f64 * 0.5)).unwrap();
        }
        let s = m.summary();
        assert_eq!(s.steps, 5);
        assert!((s.final_loss - 2.5).abs() < 1e-9);
        assert_eq!(s.peak_bytes, 5000);
        assert!(s.healthy());
    }

    #[test]
    fn divergent_run_is_unhealthy() {
        let mut m = MetricsLogger::new(None, 100).unwrap();
        m.record("MeZO", &stat(1, f64::NAN)).unwrap();
        assert!(!m.summary().healthy());
    }

    #[test]
    fn jsonl_file_output() {
        let dir = std::env::temp_dir().join("mesp-test-metrics");
        let path = dir.join("run.jsonl");
        let mut m = MetricsLogger::new(Some(&path), 100).unwrap();
        m.record("MeBP", &stat(1, 3.3)).unwrap();
        drop(m);
        let content = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(content.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("method").unwrap().as_str(), Some("MeBP"));
        assert_eq!(j.get("loss").unwrap().as_f64(), Some(3.3));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
