//! Markdown table rendering for the reproduce drivers — every paper table
//! is emitted in the same row/column layout the paper uses, with a
//! "paper" column next to our measured/modelled values where applicable —
//! plus the per-artifact execution-stats table (calls, time, FLOPs,
//! achieved GFLOP/s) `mesp train` and `mesp inspect` print.

use crate::runtime::ExecStats;

/// Simple aligned markdown table builder.
#[derive(Debug, Default)]
pub struct TableBuilder {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(header: &[&str]) -> Self {
        TableBuilder {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a percentage like the paper ("62%", "-4%").
pub fn pct(v: f64) -> String {
    format!("{}%", v.round() as i64)
}

/// Render per-artifact execution stats (slowest first): call count, total
/// seconds, mean ms/call, total GFLOP and achieved GFLOP/s. A view over
/// the metrics registry (`obs::views`): the stats are loaded under
/// `artifact/<name>/*` and rendered from there, so this table and the
/// `--metrics-out` JSONL export can never drift apart.
pub fn exec_stats_table(stats: &[(String, ExecStats)]) -> String {
    let reg = crate::obs::MetricsRegistry::new();
    crate::obs::views::exec_stats_into(&reg, stats);
    crate::obs::views::render_exec_stats(&reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableBuilder::new(&["Method", "Mem (MB)"]);
        t.row(vec!["MeBP".into(), "360.8".into()]);
        t.row(vec!["MeSP".into(), "136.2".into()]);
        let s = t.render();
        assert!(s.contains("| MeBP   | 360.8    |"));
        assert!(s.lines().count() == 4);
        // all lines same width
        let widths: Vec<usize> =
            s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        TableBuilder::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn pct_rounds() {
        assert_eq!(pct(61.7), "62%");
        assert_eq!(pct(-4.2), "-4%");
    }

    #[test]
    fn exec_stats_table_has_gflops_column() {
        let stats = vec![(
            "block_bwd_mesp".to_string(),
            ExecStats { calls: 4, total_secs: 2.0, flops: 8_000_000_000 },
        )];
        let s = exec_stats_table(&stats);
        assert!(s.contains("GFLOP/s"), "{s}");
        assert!(s.contains("block_bwd_mesp"), "{s}");
        assert!(s.contains("4.00"), "8 GFLOP / 2 s = 4 GFLOP/s\n{s}");
        assert!(s.contains("500.000"), "ms/call\n{s}");
    }
}
