//! The paper's published numbers, transcribed from the tables — printed
//! next to our modelled/measured values so every reproduce driver shows
//! paper-vs-ours in one view (EXPERIMENTS.md is generated from these).

/// Table 1: (model, method, mem_mb, time_s) at seq 256, r8.
pub const TABLE1: &[(&str, &str, f64, f64)] = &[
    ("0.5B", "MeBP", 360.8, 0.68),
    ("0.5B", "MeZO", 243.0, 0.51),
    ("0.5B", "MeSP", 136.2, 0.86),
    ("1.5B", "MeBP", 516.2, 1.66),
    ("1.5B", "MeZO", 376.0, 1.21),
    ("1.5B", "MeSP", 262.6, 2.17),
    ("3B", "MeBP", 637.6, 3.21),
    ("3B", "MeZO", 479.2, 2.24),
    ("3B", "MeSP", 368.4, 4.09),
];

/// Table 2: peak MB vs seq on 0.5B: (method, [128, 256, 512, 1024]).
pub const TABLE2: &[(&str, [f64; 4])] = &[
    ("MeBP", [252.7, 360.8, 582.4, 1050.3]),
    ("MeZO", [199.0, 243.0, 336.0, 524.0]),
    ("MeSP", [110.7, 136.2, 245.8, 513.6]),
];

/// Table 3: MeZO gradient quality on 0.5B: (layer, cosine, sign%, rel err).
pub const TABLE3: &[(usize, f64, f64, f64)] = &[
    (0, 0.003, 48.4, 171.0),
    (5, 0.000, 48.4, 2155.0),
    (10, -0.000, 48.4, 1906.0),
    (15, -0.001, 48.4, 2351.0),
    (20, -0.000, 48.4, 3590.0),
    (23, 0.001, 48.5, 1692.0),
];

/// Table 4: peak MB vs rank on 0.5B seq 256: (method, [r4, r8, r16, r32]).
pub const TABLE4: &[(&str, [f64; 4])] = &[
    ("MeBP", [355.2, 360.8, 372.4, 395.8]),
    ("MeZO", [215.0, 243.0, 299.0, 411.0]),
    ("MeSP", [132.8, 136.2, 143.5, 158.2]),
];

/// Table 5: h-strategy ablation on 3B seq 256: (strategy, mem MB, time s).
pub const TABLE5: &[(&str, f64, f64)] = &[
    ("MeBP (baseline)", 637.6, 3.21),
    ("Store h", 398.5, 3.85),
    ("Recompute h (ours)", 368.4, 4.09),
];

/// Table 6: seq ablation 1.5B.
pub const TABLE6: &[(&str, [f64; 4])] = &[
    ("MeBP", [325.4, 516.2, 845.6, 1538.2]),
    ("MeZO", [268.5, 376.0, 548.4, 878.6]),
    ("MeSP", [165.2, 262.6, 432.8, 798.5]),
];

/// Table 7: seq ablation 3B.
pub const TABLE7: &[(&str, [f64; 4])] = &[
    ("MeBP", [425.8, 637.6, 930.7, 1685.2]),
    ("MeZO", [362.4, 479.2, 590.4, 925.8]),
    ("MeSP", [245.6, 368.4, 505.3, 925.8]),
];

/// Table 9: rank ablation 1.5B.
pub const TABLE9: &[(&str, [f64; 4])] = &[
    ("MeBP", [508.5, 516.2, 532.4, 564.8]),
    ("MeZO", [365.2, 376.0, 398.5, 445.2]),
    ("MeSP", [255.8, 262.6, 275.8, 302.5]),
];

/// Table 10: rank ablation 3B.
pub const TABLE10: &[(&str, [f64; 4])] = &[
    ("MeBP", [628.4, 637.6, 658.2, 698.5]),
    ("MeZO", [475.5, 479.2, 492.8, 525.6]),
    ("MeSP", [358.2, 368.4, 385.6, 420.8]),
];

/// Table 11 / Fig 2: loss at 100-step intervals (step, mebp, mesp, mezo).
pub const TABLE11: &[(usize, f64, f64, f64)] = &[
    (0, 3.348, 3.348, 3.384),
    (100, 3.345, 3.345, 3.392),
    (200, 4.312, 4.312, 3.394),
    (300, 3.911, 3.911, 3.394),
    (400, 3.717, 3.717, 3.400),
    (500, 3.495, 3.495, 3.403),
    (600, 3.506, 3.506, 3.414),
    (700, 3.498, 3.498, 3.423),
    (800, 3.380, 3.380, 3.431),
    (900, 3.352, 3.352, 3.442),
    (1000, 3.332, 3.332, 3.451),
];

pub const SEQ_SWEEP: [usize; 4] = [128, 256, 512, 1024];
pub const RANK_SWEEP: [usize; 4] = [4, 8, 16, 32];
