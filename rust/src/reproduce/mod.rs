//! Reproduction drivers: one function per paper table/figure (DESIGN.md
//! §6). Memory tables evaluate the analytical model at the paper's Qwen2.5
//! dims; behavioural tables (3, 5-timing, Fig 2) run the real engines on
//! compiled configs. Every driver prints paper-vs-ours side by side.
//!
//! Method grids run through `coordinator::sweep_methods`, which since the
//! fleet subsystem landed routes them over `fleet::Scheduler` (one
//! worker, unlimited budget): serial and deterministic, but on the same
//! queue/admission path the `mesp fleet` serving command exercises.

pub mod paper_data;

use crate::config::{presets, Method, TrainConfig};
use crate::coordinator::{sweep_methods, TrainSession};
use crate::memory::model as memmodel;
use crate::metrics::tables::{pct, TableBuilder};
use crate::metrics::{gradqual, grad_quality};
use crate::util::stats::fmt_mb;

use paper_data::{RANK_SWEEP, SEQ_SWEEP};

fn model_mb(method: Method, dims: &crate::config::ModelDims) -> f64 {
    memmodel::peak_bytes(method, dims) as f64 / (1024.0 * 1024.0)
}

const METHODS: [Method; 3] = [Method::Mebp, Method::Mezo, Method::Mesp];

/// Table 1: memory and (measured) time per method × model size, seq 256.
/// Memory comes from the analytical model at Qwen dims; step-time ratios
/// are measured on the `small` compiled config (`steps` real steps each)
/// and reported next to the paper's on-device seconds.
pub fn table1(steps: usize) -> anyhow::Result<String> {
    let mut out = String::from("## Table 1 — memory & time, seq 256, r8\n\n");
    // measured step-time ratios on the real engines
    let base = TrainConfig { config: "small".into(), log_every: usize::MAX,
                             ..Default::default() };
    let runs = sweep_methods(&base, &METHODS, steps)?;
    let mebp_t = runs.iter().find(|(m, ..)| *m == Method::Mebp)
        .map(|(_, s, _)| s.mean_step_secs).unwrap_or(1.0);

    let mut t = TableBuilder::new(&[
        "Model", "Method", "Mem MB (paper)", "Mem MB (model)",
        "Red. (paper)", "Red. (model)", "time ratio vs MeBP (paper)",
        "time ratio (measured@small)",
    ]);
    for (name, seq) in [("0.5B", 256), ("1.5B", 256), ("3B", 256)] {
        let dims = presets::by_name(name, seq, 8)?;
        let mebp_model = model_mb(Method::Mebp, &dims);
        for m in METHODS {
            let paper = paper_data::TABLE1
                .iter()
                .find(|(n, meth, ..)| *n == name && *meth == m.name())
                .unwrap();
            let ours = model_mb(m, &dims);
            let paper_mebp = paper_data::TABLE1
                .iter()
                .find(|(n, meth, ..)| *n == name && *meth == "MeBP")
                .unwrap();
            let run = runs.iter().find(|(mm, ..)| *mm == m).unwrap();
            t.row(vec![
                name.into(),
                m.name().into(),
                format!("{:.1}", paper.2),
                format!("{ours:.1}"),
                pct(100.0 * (1.0 - paper.2 / paper_mebp.2)),
                pct(100.0 * (1.0 - ours / mebp_model)),
                format!("{:.2}", paper.3 / paper_mebp.3),
                format!("{:.2}", run.1.mean_step_secs / mebp_t),
            ]);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Seq-sweep tables (2 on 0.5B, 6 on 1.5B, 7 on 3B).
pub fn seq_sweep_table(
    n: usize,
    model: &str,
    paper: &[(&str, [f64; 4])],
) -> anyhow::Result<String> {
    let mut out = format!(
        "## Table {n} — peak memory (MB) vs sequence length, {model}, r8\n\n");
    let mut t = TableBuilder::new(&[
        "Method", "src", "128", "256", "512", "1024",
    ]);
    for m in METHODS {
        let prow = paper.iter().find(|(pm, _)| *pm == m.name()).unwrap();
        t.row(vec![
            m.name().into(), "paper".into(),
            format!("{:.1}", prow.1[0]), format!("{:.1}", prow.1[1]),
            format!("{:.1}", prow.1[2]), format!("{:.1}", prow.1[3]),
        ]);
        let mut cells = vec![m.name().to_string(), "model".into()];
        for seq in SEQ_SWEEP {
            let dims = presets::by_name(model, seq, 8)?;
            cells.push(format!("{:.1}", model_mb(m, &dims)));
        }
        t.row(cells);
    }
    // reduction rows
    for m in [Method::Mezo, Method::Mesp] {
        let mut cells = vec![format!("{} red.", m.name()), "model".into()];
        for seq in SEQ_SWEEP {
            let dims = presets::by_name(model, seq, 8)?;
            cells.push(pct(memmodel::reduction_vs_mebp(m, &dims)));
        }
        t.row(cells);
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Table 3: MeZO gradient quality vs exact gradients — real run on a
/// compiled config (`small` by default).
pub fn table3(config: &str) -> anyhow::Result<String> {
    let mut out = format!(
        "## Table 3 — MeZO gradient quality vs exact (config {config})\n\n");
    let base = TrainConfig { config: config.into(), log_every: usize::MAX,
                             ..Default::default() };
    // exact gradients from MeSP (== MeBP, see gradcheck test)
    let mut cfg_e = base.clone();
    cfg_e.method = Method::Mesp;
    let mut exact_s = TrainSession::builder(cfg_e).build()?;
    let (batch, _g) = exact_s.loader.next();
    let exact = exact_s.engine.gradients(&batch)?;

    let mut cfg_z = base.clone();
    cfg_z.method = Method::Mezo;
    let mut mezo_s = TrainSession::builder(cfg_z).build()?;
    let estimate = mezo_s.engine.gradients(&batch)?;

    let rows = grad_quality(&estimate, &exact);
    let mut t = TableBuilder::new(&[
        "Layer", "Cosine", "Sign agree", "Rel. error",
        "paper cosine≈", "paper sign≈",
    ]);
    for r in &rows {
        t.row(vec![
            r.layer.to_string(),
            format!("{:.4}", r.cosine),
            format!("{:.1}%", 100.0 * r.sign_agree),
            format!("{:.1}", r.rel_error),
            "0.001".into(),
            "48.4%".into(),
        ]);
    }
    let avg = gradqual::average(&rows);
    t.row(vec![
        "Avg".into(),
        format!("{:.4}", avg.cosine),
        format!("{:.1}%", 100.0 * avg.sign_agree),
        format!("{:.1}", avg.rel_error),
        "0.001".into(),
        "48.4%".into(),
    ]);
    out.push_str(&t.render());
    Ok(out)
}

/// Rank-sweep tables (4 on 0.5B, 9 on 1.5B, 10 on 3B).
pub fn rank_sweep_table(
    n: usize,
    model: &str,
    paper: &[(&str, [f64; 4])],
) -> anyhow::Result<String> {
    let mut out = format!(
        "## Table {n} — peak memory (MB) vs LoRA rank, {model}, seq 256\n\n");
    let mut t = TableBuilder::new(&[
        "Method", "src", "r=4", "r=8", "r=16", "r=32",
    ]);
    for m in METHODS {
        let prow = paper.iter().find(|(pm, _)| *pm == m.name()).unwrap();
        t.row(vec![
            m.name().into(), "paper".into(),
            format!("{:.1}", prow.1[0]), format!("{:.1}", prow.1[1]),
            format!("{:.1}", prow.1[2]), format!("{:.1}", prow.1[3]),
        ]);
        let mut cells = vec![m.name().to_string(), "model".into()];
        for r in RANK_SWEEP {
            let dims = presets::by_name(model, 256, r)?;
            cells.push(format!("{:.1}", model_mb(m, &dims)));
        }
        t.row(cells);
    }
    for m in [Method::Mezo, Method::Mesp] {
        let mut cells = vec![format!("{} red.", m.name()), "model".into()];
        for r in RANK_SWEEP {
            let dims = presets::by_name(model, 256, r)?;
            cells.push(pct(memmodel::reduction_vs_mebp(m, &dims)));
        }
        t.row(cells);
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Table 5: store-h vs recompute-h — model memory at 3B dims + measured
/// time/memory of the real engines on `small`.
pub fn table5(steps: usize) -> anyhow::Result<String> {
    let mut out = String::from("## Table 5 — h strategy ablation\n\n");
    let dims = presets::qwen25_3b(256, 8);
    let base = TrainConfig { config: "small".into(), log_every: usize::MAX,
                             ..Default::default() };
    let runs = sweep_methods(
        &base, &[Method::Mebp, Method::StoreH, Method::Mesp], steps)?;
    let mebp_t = runs[0].1.mean_step_secs;
    let mebp_mem = runs[0].1.peak_bytes as f64;

    let mut t = TableBuilder::new(&[
        "Strategy", "Mem MB (paper@3B)", "Mem MB (model@3B)",
        "mem vs MeBP (measured@small)", "time vs MeBP (paper)",
        "time vs MeBP (measured@small)",
    ]);
    for ((method, summary, _), paper) in
        runs.iter().zip(paper_data::TABLE5)
    {
        let model_mem = model_mb(*method, &dims);
        t.row(vec![
            paper.0.into(),
            format!("{:.1}", paper.1),
            format!("{model_mem:.1}"),
            format!("{:.2}x", summary.peak_bytes as f64 / mebp_mem),
            format!("{:.2}x", paper.2 / paper_data::TABLE5[0].2),
            format!("{:.2}x", summary.mean_step_secs / mebp_t),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("\npaper: recompute-h saves memory vs store-h at a small \
                  time cost; same ordering must hold in the measured column.\n");
    Ok(out)
}

/// Figure 2 / Table 11: loss curves for the three methods with identical
/// seeds. `steps` real steps on `config`; MeSP and MeBP must match
/// step-for-step (exact-gradient equivalence).
pub fn fig2(config: &str, steps: usize) -> anyhow::Result<String> {
    let mut out = format!(
        "## Figure 2 / Table 11 — training loss, config {config}, \
         {steps} steps, identical seeds\n\n");
    // lr scaled up so the small config shows the convergence separation
    // within a few hundred steps (the paper runs 100K steps at 1e-4; the
    // relative behaviour — MeSP ≡ MeBP exactly, MeZO worse — is lr-
    // invariant for exact methods and only *helped* for MeZO by more
    // steps, so a faster schedule is the conservative choice).
    let base = TrainConfig { config: config.into(),
                             lr: 3e-3,
                             log_every: (steps / 10).max(1),
                             ..Default::default() };
    let runs = sweep_methods(&base, &METHODS, steps)?;
    let interval = (steps / 10).max(1);
    let mut t = TableBuilder::new(&["Step", "MeBP", "MeSP", "MeZO"]);
    let get = |m: Method| -> &Vec<f64> {
        &runs.iter().find(|(mm, ..)| *mm == m).unwrap().2
    };
    let (mebp, mesp, mezo) = (get(Method::Mebp), get(Method::Mesp),
                              get(Method::Mezo));
    for i in (0..steps).step_by(interval) {
        t.row(vec![
            (i + 1).to_string(),
            format!("{:.4}", mebp[i]),
            format!("{:.4}", mesp[i]),
            format!("{:.4}", mezo[i]),
        ]);
    }
    out.push_str(&t.render());
    let max_diff = mebp
        .iter()
        .zip(mesp)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    out.push_str(&format!(
        "\nmax |MeBP − MeSP| loss difference: {max_diff:.2e} \
         (paper: identical values — mathematical equivalence)\n"));
    out.push_str(&format!(
        "final losses: MeBP {:.4}, MeSP {:.4}, MeZO {:.4} \
         (paper: MeZO converges ~22% higher)\n",
        mebp.last().unwrap(), mesp.last().unwrap(), mezo.last().unwrap()));
    Ok(out)
}

/// Table 8: the reduction summary grid (model sizes × seq lens).
pub fn table8() -> anyhow::Result<String> {
    let mut out = String::from(
        "## Table 8 — memory reduction vs MeBP, all configurations\n\n");
    let mut t = TableBuilder::new(&[
        "Model", "Seq", "MeZO red. (model)", "MeSP red. (model)",
    ]);
    let (mut sum_z, mut sum_s, mut n) = (0.0, 0.0, 0);
    for model in ["0.5b", "1.5b", "3b"] {
        for seq in SEQ_SWEEP {
            let dims = presets::by_name(model, seq, 8)?;
            let rz = memmodel::reduction_vs_mebp(Method::Mezo, &dims);
            let rs = memmodel::reduction_vs_mebp(Method::Mesp, &dims);
            sum_z += rz;
            sum_s += rs;
            n += 1;
            t.row(vec![
                model.to_uppercase(), seq.to_string(), pct(rz), pct(rs),
            ]);
        }
    }
    t.row(vec![
        "Average".into(), "".into(),
        pct(sum_z / n as f64), pct(sum_s / n as f64),
    ]);
    out.push_str(&t.render());
    out.push_str("\npaper averages: MeZO 32%, MeSP 50%\n");
    Ok(out)
}

/// Run one table by number (2/4/6/7/9/10 take no runtime work).
pub fn run_table(n: usize, steps: usize) -> anyhow::Result<String> {
    match n {
        1 => table1(steps),
        2 => seq_sweep_table(2, "0.5b", paper_data::TABLE2),
        3 => table3("small"),
        4 => rank_sweep_table(4, "0.5b", paper_data::TABLE4),
        5 => table5(steps),
        6 => seq_sweep_table(6, "1.5b", paper_data::TABLE6),
        7 => seq_sweep_table(7, "3b", paper_data::TABLE7),
        8 => table8(),
        9 => rank_sweep_table(9, "1.5b", paper_data::TABLE9),
        10 => rank_sweep_table(10, "3b", paper_data::TABLE10),
        11 => fig2("small", steps.max(100)),
        _ => anyhow::bail!("no table {n} in the paper (1-11; 11 = Fig 2)"),
    }
}

/// Memory breakdown report for one method at Qwen dims (debugging aid +
/// DESIGN.md §7 documentation).
pub fn breakdown(model: &str, seq: usize, rank: usize) -> anyhow::Result<String> {
    let dims = presets::by_name(model, seq, rank)?;
    let mut out = format!("## Peak-memory breakdown, {} (paper widths)\n\n",
                          dims.name);
    let mut t = TableBuilder::new(&[
        "Component", "MeBP", "MeZO", "MeSP", "Store-h",
    ]);
    let bds: Vec<_> = [Method::Mebp, Method::Mezo, Method::Mesp, Method::StoreH]
        .iter()
        .map(|m| memmodel::peak(*m, &dims, crate::config::OptimizerKind::Sgd,
                                memmodel::Widths::paper()))
        .collect();
    for i in 0..bds[0].rows().len() {
        let name = bds[0].rows()[i].0;
        t.row(vec![
            name.into(),
            fmt_mb(bds[0].rows()[i].1),
            fmt_mb(bds[1].rows()[i].1),
            fmt_mb(bds[2].rows()[i].1),
            fmt_mb(bds[3].rows()[i].1),
        ]);
    }
    t.row(vec![
        "TOTAL".into(),
        fmt_mb(bds[0].total()), fmt_mb(bds[1].total()),
        fmt_mb(bds[2].total()), fmt_mb(bds[3].total()),
    ]);
    out.push_str(&t.render());
    Ok(out)
}
