//! Self-contained infrastructure the offline build cannot pull from
//! crates.io: JSON, PRNG, statistics. Kept dependency-free on purpose —
//! determinism and parseability are load-bearing for reproduction runs.

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
