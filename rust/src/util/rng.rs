//! Deterministic PRNG: xoshiro256** seeded via SplitMix64, plus normal
//! sampling (Box–Muller). No external `rand` crate in the offline build —
//! and determinism across the whole system (weight init, data generation,
//! MeZO perturbations) hangs off this one implementation, so keeping it
//! in-tree is a feature: the same seed reproduces the same run bit-for-bit.

/// Well-known stream ids for [`derive`], so every subsystem draws from a
/// documented, collision-free slice of the seed space.
pub mod stream {
    /// Model weight initialisation.
    pub const MODEL: u64 = 1;
    /// Data loader / corpus generation.
    pub const LOADER: u64 = 2;
    /// Fleet job seeds (combined with the job index).
    pub const JOB: u64 = 3;
}

/// Derive an independent sub-seed from `(seed, stream_id)` with the
/// SplitMix64 finalizer. Distinct stream ids map to distinct (and
/// statistically independent) seeds, so components sharing one base seed
/// — the model init, the data loader, each fleet job — never consume the
/// same underlying random stream. Pure function: same inputs, same seed.
pub fn derive(seed: u64, stream_id: u64) -> u64 {
    let mut z = seed
        .wrapping_add(stream_id.wrapping_add(1).wrapping_mul(0x9e3779b97f4a7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. per-layer, per-step).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.s[0] ^ stream.wrapping_mul(0x9e3779b97f4a7c15));
        r.s[1] ^= self.s[1];
        r.s[2] ^= self.s[2].rotate_left(17);
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // top 24 bits → f32 mantissa precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a fresh Vec with N(0, std²) samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let v = r.normal_vec(40_000, 1.0);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 =
            v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn derive_is_deterministic_and_stream_separated() {
        assert_eq!(derive(42, stream::MODEL), derive(42, stream::MODEL));
        assert_ne!(derive(42, stream::MODEL), derive(42, stream::LOADER));
        assert_ne!(derive(42, stream::MODEL), derive(43, stream::MODEL));
        // stream 0 is usable too (plain SplitMix64 step)
        assert_ne!(derive(42, 0), 42);
    }

    #[test]
    fn derived_job_seeds_are_distinct() {
        let base = derive(42, stream::JOB);
        let seeds: Vec<u64> = (0..64).map(|i| derive(base, i)).collect();
        for (i, a) in seeds.iter().enumerate() {
            for b in &seeds[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
