//! Small statistics helpers shared by metrics, benches and the reproduce
//! drivers (mean/std/percentiles over timing or loss series).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile via nearest-rank on a sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Cosine similarity between two vectors (paper Table 3 metric).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Fraction of elements whose signs agree (paper Table 3 metric).
pub fn sign_agreement(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let agree = a
        .iter()
        .zip(b)
        .filter(|(x, y)| (**x >= 0.0) == (**y >= 0.0))
        .count();
    agree as f64 / a.len() as f64
}

/// Relative error ‖a − b‖ / ‖b‖ (paper Table 3 metric; b is truth).
pub fn rel_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum::<f64>().sqrt();
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    num / den
}

/// Human-readable byte count (MB with one decimal, like the paper tables).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn cosine_identity_and_orthogonal() {
        let a = [1.0f32, 2.0, 3.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        let b = [0.0f32, 0.0, 0.0];
        assert_eq!(cosine(&a, &b), 0.0);
        let c = [2.0f32, -1.0, 0.0];
        assert!(cosine(&a, &c).abs() < 1e-9);
    }

    #[test]
    fn sign_agree() {
        let a = [1.0f32, -1.0, 1.0, -1.0];
        let b = [1.0f32, 1.0, -1.0, -1.0];
        assert_eq!(sign_agreement(&a, &b), 0.5);
    }

    #[test]
    fn rel_err() {
        let a = [2.0f32, 0.0];
        let b = [1.0f32, 0.0];
        assert!((rel_error(&a, &b) - 1.0).abs() < 1e-9);
        assert_eq!(rel_error(&b, &b), 0.0);
    }

    #[test]
    fn mb_format() {
        assert_eq!(fmt_mb(361 * 1024 * 1024), "361.0");
    }
}
