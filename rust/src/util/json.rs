//! Minimal JSON parser/serializer.
//!
//! The offline build has no `serde`/`serde_json`, so the runtime parses
//! `artifacts/*/manifest.json` (and writes metrics JSONL) with this
//! self-contained implementation. It supports the full JSON grammar minus
//! exotic number forms; everything the AOT manifests and metrics need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so output
/// is deterministic — handy for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers ----------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact single-line serialization.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                anyhow::bail!(
                                    "truncated \\u escape at byte {}",
                                    self.i
                                );
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        anyhow::bail!("truncated UTF-8 at byte {start}");
                    }
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"artifacts":{"f":{"args":[{"name":"x","shape":[1,32,64],"dtype":"f32"}],"outputs":15}}}"#;
        let v = Json::parse(src).unwrap();
        let f = v.get("artifacts").unwrap().get("f").unwrap();
        assert_eq!(f.get("outputs").unwrap().as_usize(), Some(15));
        let arg0 = &f.get("args").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = arg0
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|j| j.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![1, 32, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo → é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → é"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5").unwrap().as_f64(), Some(-2.5));
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
