//! Admission control: keep the sum of predicted per-session peak memory
//! under the device budget — and, since the budget can now SHRINK
//! mid-run (`--budget-schedule`) or be contended by higher-priority
//! arrivals, decide which running job must yield.
//!
//! Each job is costed BEFORE it starts with the analytical peak-memory
//! model (`memory::model`) at tracked widths, plus the reference
//! backend's always-resident weight copies and the prefetch queue — i.e.
//! the worst tracked moment one `TrainSession` of that spec can reach.
//! Workers block in [`Admission::admit_job`] until the budget has room
//! (backpressure); the permit is RAII, so a finished (or crashed) session
//! always returns its reservation. Because the per-job cost is an upper
//! bound on the session's tracked peak, `sum(admitted costs) <= budget`
//! implies the fleet-wide aggregate tracked peak stays under the budget.
//!
//! # Arrival order and preemption
//!
//! Initial admissions are granted strictly in job-id (submission) order
//! via an arrival ticket, so "which job was already running when
//! pressure arrived" is deterministic — priorities decide who YIELDS,
//! not who goes first. With preemption enabled, a blocked arrival whose
//! priority exceeds a running job's — or a budget shrink that leaves the
//! running set over-committed — flags the lowest-priority running job
//! (ties: the most recently admitted yields first). The flag is a
//! cooperative request: the scheduler's step loop observes it via
//! [`Permit::preempt_requested`], snapshots the session to disk, drops
//! the permit (returning the reservation) and re-queues the job to
//! resume later. Resumed admissions carry no ticket — they re-enter
//! whenever the budget next has room.
//!
//! # Tenants and quotas
//!
//! `mesp serve` admits on behalf of named tenants
//! ([`Admission::admit_job_tenant`]). A tenant with a quota
//! ([`Admission::set_tenant_quota`]) may never have more than that many
//! bytes of per-job cost committed at once: a waiter whose tenant is at
//! its quota is SKIPPED by the grant selection (other tenants' waiters
//! proceed — a capped tenant cannot starve the fleet), and a single job
//! whose cost alone exceeds its tenant's quota is refused outright.
//! Shared frozen-base weight bytes are fleet-wide and are NOT charged
//! against any tenant's quota — only the per-job activation/queue cost
//! is. Weighted-fair queuing across tenants happens one level up, in
//! the serve daemon's dispatch queue; the gate only enforces hard caps.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::{presets, Method};
use crate::coordinator::PREFETCH_DEPTH;
use crate::memory::{model as memmodel, Widths};
use crate::util::rng::{derive, stream};
use crate::util::stats::fmt_mb;

use super::job::JobSpec;

/// Predicted peak tracked bytes for one session running `spec`,
/// EXCLUDING the frozen base weights:
/// the analytical per-method activation/gradient peak (tracked widths,
///   quant-aware: q4 adds the naive-oracle dequant-buffer scratch)
/// + the prefetch queue's batch buffers.
///
/// The frozen base is costed separately by [`job_weight_class`]: since
/// PR 6 the weights are interned in a fleet-wide
/// [`crate::model::WeightCache`], so the gate charges them ONCE per
/// distinct `(config, model seed, quant)` class — the first admit of a
/// class reserves them, the last release returns them — instead of once
/// per job.
pub fn job_cost_bytes(spec: &JobSpec) -> anyhow::Result<u64> {
    let dims = presets::compiled(&spec.config)?;
    let activations = memmodel::peak_opts(
        spec.method,
        &dims,
        spec.optimizer,
        Widths::tracked(),
        spec.quant,
        memmodel::MemOptions {
            loss_chunk: spec.loss_chunk,
            act_compress: spec.act_compress,
        },
    )
    .total();
    let batch_bytes = 2 * (dims.batch * dims.seq * 4) as u64; // tokens+targets i32
    let queue = (PREFETCH_DEPTH as u64 + 2) * batch_bytes;
    Ok(activations + queue)
}

/// The shared-weight cost of a job: which frozen base it attaches to
/// (`key`) and what that base costs resident (`bytes`). Jobs whose keys
/// agree share one `FrozenModel` through the fleet's weight cache, so
/// the admission gate charges `bytes` only while at least one holder of
/// the key is admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WeightClass {
    /// Identity of the frozen base: hash of (config name, resolved
    /// model seed, quant mode) — the same identity the weight cache
    /// interns on.
    pub key: u64,
    /// Resident bytes of one copy of that base at the job's quant mode.
    pub bytes: u64,
}

/// Compute the [`WeightClass`] of `spec`. The model seed resolves like
/// [`crate::config::TrainConfig::model_seed`]: an explicit pin wins,
/// otherwise it derives from the job's own seed (private weights).
pub fn job_weight_class(spec: &JobSpec) -> anyhow::Result<WeightClass> {
    let dims = presets::compiled(&spec.config)?;
    let model_seed = spec
        .model_seed
        .unwrap_or_else(|| derive(spec.seed, stream::MODEL));
    let mut key: u64 = 0xcbf29ce484222325;
    let mut eat = |bytes: &[u8]| {
        for b in bytes {
            key ^= *b as u64;
            key = key.wrapping_mul(0x100000001b3);
        }
    };
    eat(spec.config.as_bytes());
    eat(&model_seed.to_le_bytes());
    eat(spec.quant.name().as_bytes());
    Ok(WeightClass {
        key,
        bytes: memmodel::resident_weight_bytes(&dims, spec.quant),
    })
}

/// Refcount of one weight class the gate currently covers.
#[derive(Debug)]
struct WeightEntry {
    holders: usize,
    bytes: u64,
}

/// One admitted job the gate is currently covering.
#[derive(Debug)]
struct RunningEntry {
    /// Unique registration id (monotonic admission order).
    reg: u64,
    priority: u8,
    cost: u64,
    flag: Arc<AtomicBool>,
}

/// A thread blocked in the budget phase of [`Admission::admit_job`].
/// Grants go to the highest-priority waiter first (ties: earliest), so a
/// just-parked low-priority job cannot race the reservation away from
/// the high-priority arrival it was parked FOR.
#[derive(Debug)]
struct Waiter {
    wid: u64,
    priority: u8,
    /// Per-job cost the waiter will commit — what its tenant's quota is
    /// checked against during grant selection.
    cost: u64,
    tenant: Option<String>,
}

#[derive(Debug, Default)]
struct AdmState {
    /// Current budget (mutable: `--budget-schedule` shrinks it mid-run).
    budget: u64,
    /// Highest budget the gate can still reach: max of the current
    /// budget and every not-yet-applied schedule point. A job is
    /// refused as "can never be admitted" only against THIS — a
    /// transient shrink must park work, not kill it, when the schedule
    /// grows the budget back later.
    ceiling: u64,
    /// Sum of admitted job costs currently outstanding.
    committed: u64,
    /// Number of admitted jobs currently outstanding.
    active: usize,
    /// Next initial job id to be granted (arrival-ticket gate).
    next_ticket: usize,
    /// Closed gates refuse every admit (serve shutdown unblocks its
    /// workers through this).
    closed: bool,
    preempt_enabled: bool,
    running: Vec<RunningEntry>,
    waiters: Vec<Waiter>,
    wait_seq: u64,
    reg_seq: u64,
    preempts_requested: usize,
    active_by_method: BTreeMap<&'static str, usize>,
    peak_concurrent: usize,
    peak_committed: u64,
    peak_by_method: BTreeMap<&'static str, usize>,
    admitted_total: usize,
    /// Weight classes currently held by at least one admitted job,
    /// keyed by [`WeightClass::key`]. Their bytes are part of
    /// `committed` exactly while an entry exists.
    weights: HashMap<u64, WeightEntry>,
    /// Admissions that attached to an already-charged weight class
    /// (paid 0 weight bytes).
    weight_shared_admissions: usize,
    /// High-water of weight bytes simultaneously committed.
    peak_weight_bytes: u64,
    /// Hard per-tenant caps on committed per-job cost bytes (weights
    /// excluded — they are fleet-wide).
    tenant_quota: HashMap<String, u64>,
    /// Per-tenant committed per-job cost bytes currently outstanding.
    tenant_committed: HashMap<String, u64>,
}

impl AdmState {
    /// Weight bytes a job of class `w` would newly commit: zero when
    /// some admitted job already holds the class (shared attach), the
    /// full resident bytes when it would be the first holder.
    fn weight_need(&self, w: &Option<WeightClass>) -> u64 {
        match w {
            Some(c) if !self.weights.contains_key(&c.key) => c.bytes,
            _ => 0,
        }
    }

    /// Whether `cost` more bytes for `tenant` would stay within the
    /// tenant's quota (no tenant / no quota: always). Weight-class
    /// bytes are deliberately excluded — shared bases are fleet-wide.
    fn tenant_fits(&self, tenant: &Option<String>, cost: u64) -> bool {
        let Some(t) = tenant else { return true };
        let Some(q) = self.tenant_quota.get(t) else { return true };
        let used = self.tenant_committed.get(t).copied().unwrap_or(0);
        used.saturating_add(cost) <= *q
    }

    /// Sum of costs of running jobs already flagged for preemption —
    /// budget that is committed but on its way back.
    fn flagged(&self) -> u64 {
        self.running
            .iter()
            .filter(|e| e.flag.load(Ordering::SeqCst))
            .map(|e| e.cost)
            .sum()
    }

    /// Flag lowest-priority running jobs (ties: most recently admitted
    /// first) until `need` bytes fit under the budget, or no eligible
    /// victim remains. `below` restricts victims to priorities strictly
    /// below an arriving job's; `None` (budget shrink) may flag anyone.
    fn flag_victims(&mut self, need: u64, below: Option<u8>) {
        let eligible = |e: &&RunningEntry| {
            !e.flag.load(Ordering::SeqCst)
                && match below {
                    Some(p) => e.priority < p,
                    None => true,
                }
        };
        // Feasibility first: if parking EVERY eligible victim still
        // would not fit `need`, flag nobody — a pointless park/resume
        // round trip costs snapshot I/O and admits nothing.
        let reclaimable: u64 =
            self.running.iter().filter(eligible).map(|e| e.cost).sum();
        let keep_floor = self.committed - self.flagged() - reclaimable;
        if keep_floor.saturating_add(need) > self.budget {
            return;
        }
        loop {
            // Stop once the unflagged running set plus the `need` bytes
            // fit: (committed - flagged) + need <= budget. With need = 0
            // (budget shrink) this flags exactly until the survivors fit.
            let keep = self.committed - self.flagged();
            if keep.saturating_add(need) <= self.budget {
                return;
            }
            let victim = self
                .running
                .iter()
                .filter(eligible)
                .min_by_key(|e| (e.priority, u64::MAX - e.reg));
            let Some(v) = victim else { return };
            v.flag.store(true, Ordering::SeqCst);
            self.preempts_requested += 1;
        }
    }
}

/// Snapshot of the admission high-water marks for the fleet report.
#[derive(Debug, Clone, Default)]
pub struct AdmissionStats {
    /// Most jobs ever admitted at once.
    pub peak_concurrent: usize,
    /// Highest sum of admitted costs (predicted occupancy high-water).
    pub peak_committed: u64,
    /// Most concurrently-admitted jobs per method name.
    pub peak_by_method: BTreeMap<String, usize>,
    /// Total jobs admitted over the fleet's lifetime (resumes included).
    pub admitted_total: usize,
    /// Preemption requests issued (arrival pressure + budget shrinks).
    pub preempts_requested: usize,
    /// Admissions that attached to an already-charged weight class —
    /// jobs whose frozen base was already resident, charged 0 weight
    /// bytes by the gate.
    pub weight_shared_admissions: usize,
    /// High-water mark of shared-weight bytes committed at once.
    pub peak_weight_bytes: u64,
}

/// The budget gate. Shared by all workers of one fleet run.
#[derive(Debug)]
pub struct Admission {
    state: Mutex<AdmState>,
    cv: Condvar,
}

impl Admission {
    pub fn new(budget: u64) -> Admission {
        Admission {
            state: Mutex::new(AdmState {
                budget,
                ceiling: budget,
                ..AdmState::default()
            }),
            cv: Condvar::new(),
        }
    }

    /// Allow this gate to request preemption of running jobs (off by
    /// default: a plain fleet run never parks anyone).
    pub fn enable_preemption(&self) {
        self.state.lock().unwrap().preempt_enabled = true;
    }

    pub fn budget(&self) -> u64 {
        self.state.lock().unwrap().budget
    }

    /// The current refusal ceiling (highest still-reachable budget).
    pub fn ceiling(&self) -> u64 {
        self.state.lock().unwrap().ceiling
    }

    /// Close the gate: every blocked admit fails immediately and all
    /// future admits are refused. The serve daemon's shutdown path —
    /// parked work persists on disk, so refusing late arrivals loses
    /// nothing.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Change the budget mid-run. If the new budget no longer covers
    /// the running set and preemption is enabled, lowest-priority
    /// running jobs are flagged until the survivors fit. The refusal
    /// ceiling follows the new budget (static-world semantics); a
    /// scheduler applying a budget SCHEDULE uses
    /// [`Self::set_budget_with_ceiling`] so a transient dip parks jobs
    /// instead of permanently refusing them.
    pub fn set_budget(&self, new: u64) {
        self.set_budget_with_ceiling(new, new);
    }

    /// [`Self::set_budget`] with an explicit refusal ceiling: the max
    /// of `new` and every budget the schedule can still reach. Jobs
    /// whose cost fits the ceiling but not the current budget WAIT
    /// (the budget may grow back); only cost > ceiling is a permanent
    /// "can never be admitted" refusal.
    pub fn set_budget_with_ceiling(&self, new: u64, ceiling: u64) {
        let mut st = self.state.lock().unwrap();
        st.budget = new;
        st.ceiling = ceiling.max(new);
        if st.preempt_enabled {
            st.flag_victims(0, None);
        }
        self.cv.notify_all();
    }

    /// Reserve `cost` bytes for a job of `method`, blocking while the
    /// budget is full. Errors if the job could never fit ANY reachable
    /// budget. `ticket` carries the job id for initial admissions —
    /// granted strictly in id order; resumed jobs pass `None` and
    /// re-enter whenever there is room. A blocked arrival with
    /// preemption enabled flags running jobs of strictly lower
    /// `priority` to make room.
    ///
    /// `weights` is the job's shared-weight class: its bytes are charged
    /// only when no admitted job already holds the class (the weight
    /// cache keeps one resident copy per class), and returned when the
    /// LAST holder releases. `None` means the job's weights are inside
    /// `cost` (legacy accounting) or it has none.
    pub fn admit_job_shared(
        &self,
        method: Method,
        cost: u64,
        priority: u8,
        ticket: Option<usize>,
        weights: Option<WeightClass>,
    ) -> anyhow::Result<Permit<'_>> {
        self.admit_job_tenant(method, cost, priority, ticket, weights, None)
    }

    /// [`Self::admit_job_shared`] on behalf of a named tenant: `cost`
    /// is additionally charged against the tenant's quota (if one is
    /// set) for as long as the permit lives. A waiter whose tenant is
    /// at quota is skipped by grant selection so other tenants keep
    /// flowing; a job whose cost alone exceeds the quota is refused
    /// outright ("can never be admitted", like a cost over the budget
    /// ceiling).
    pub fn admit_job_tenant(
        &self,
        method: Method,
        cost: u64,
        priority: u8,
        ticket: Option<usize>,
        weights: Option<WeightClass>,
        tenant: Option<&str>,
    ) -> anyhow::Result<Permit<'_>> {
        let name = method.name();
        let tenant: Option<String> = tenant.map(String::from);
        // A job alone on an empty gate pays cost + its full weight
        // class; only that exceeding the ceiling is a permanent refusal
        // (sharing can only lower the real charge).
        let solo = cost + weights.map_or(0, |w| w.bytes);
        let mut st = self.state.lock().unwrap();
        if let Some(id) = ticket {
            while st.next_ticket < id {
                st = self.cv.wait(st).unwrap();
            }
        }
        // Budget phase: register as a waiter; only the grantable waiter
        // (highest priority, earliest arrival within a priority, tenant
        // under quota) may claim freed budget or request preemption.
        st.wait_seq += 1;
        let wid = st.wait_seq;
        st.waiters.push(Waiter {
            wid,
            priority,
            cost,
            tenant: tenant.clone(),
        });
        let mut refusal = String::new();
        let granted = loop {
            if st.closed {
                refusal =
                    "admission gate closed (daemon shutting down)".to_string();
                break false;
            }
            // Refuse only against the ceiling: under a budget schedule
            // the current budget may be a transient dip the job should
            // wait (or stay parked) through, not die on.
            if solo > st.ceiling {
                refusal = format!(
                    "job cost {} MB exceeds the fleet budget ceiling {} MB \
                     — it can never be admitted",
                    fmt_mb(solo),
                    fmt_mb(st.ceiling)
                );
                break false;
            }
            if let Some(t) = &tenant {
                if let Some(q) = st.tenant_quota.get(t) {
                    if cost > *q {
                        refusal = format!(
                            "job cost {} MB exceeds tenant '{t}' quota {} MB \
                             — it can never be admitted",
                            fmt_mb(cost),
                            fmt_mb(*q)
                        );
                        break false;
                    }
                }
            }
            let grantable = st
                .waiters
                .iter()
                .filter(|w| st.tenant_fits(&w.tenant, w.cost))
                .max_by_key(|w| (w.priority, std::cmp::Reverse(w.wid)))
                .map(|w| w.wid);
            if grantable == Some(wid) {
                // The weight term depends on who is admitted RIGHT NOW:
                // re-evaluate per wakeup (a holder may have arrived or
                // left while we slept).
                let need = cost + st.weight_need(&weights);
                if st.committed <= st.budget && need <= st.budget - st.committed
                {
                    break true;
                }
                if st.preempt_enabled {
                    st.flag_victims(need, Some(priority));
                }
            }
            st = self.cv.wait(st).unwrap();
        };
        st.waiters.retain(|w| w.wid != wid);
        if ticket.is_some() {
            // Grant or refuse, the arrival ticket advances — a refused
            // job must not wedge every arrival behind it.
            st.next_ticket += 1;
        }
        if !granted {
            drop(st);
            self.cv.notify_all();
            anyhow::bail!("{refusal}");
        }
        if let Some(t) = &tenant {
            *st.tenant_committed.entry(t.clone()).or_insert(0) += cost;
        }
        let wneed = st.weight_need(&weights);
        if let Some(w) = &weights {
            let e = st
                .weights
                .entry(w.key)
                .or_insert(WeightEntry { holders: 0, bytes: w.bytes });
            e.holders += 1;
            if wneed == 0 {
                st.weight_shared_admissions += 1;
            }
        }
        st.committed += cost + wneed;
        st.active += 1;
        st.admitted_total += 1;
        st.peak_committed = st.peak_committed.max(st.committed);
        st.peak_concurrent = st.peak_concurrent.max(st.active);
        let wtotal: u64 = st.weights.values().map(|e| e.bytes).sum();
        st.peak_weight_bytes = st.peak_weight_bytes.max(wtotal);
        let per = st.active_by_method.entry(name).or_insert(0);
        *per += 1;
        let per = *per;
        let peak = st.peak_by_method.entry(name).or_insert(0);
        *peak = (*peak).max(per);
        st.reg_seq += 1;
        let reg = st.reg_seq;
        let flag = Arc::new(AtomicBool::new(false));
        st.running.push(RunningEntry {
            reg,
            priority,
            cost,
            flag: Arc::clone(&flag),
        });
        drop(st);
        self.cv.notify_all();
        Ok(Permit { adm: self, reg, method: name, cost, weights, flag, tenant })
    }

    /// Cap `tenant`'s simultaneously-committed per-job cost bytes.
    pub fn set_tenant_quota(&self, tenant: &str, bytes: u64) {
        self.state
            .lock()
            .unwrap()
            .tenant_quota
            .insert(tenant.to_string(), bytes);
        self.cv.notify_all();
    }

    /// Per-job cost bytes currently committed on behalf of `tenant`.
    pub fn tenant_committed(&self, tenant: &str) -> u64 {
        self.state
            .lock()
            .unwrap()
            .tenant_committed
            .get(tenant)
            .copied()
            .unwrap_or(0)
    }

    /// [`Self::admit_job_shared`] without a weight class — jobs whose
    /// weights are folded into `cost` (or that have none).
    pub fn admit_job(
        &self,
        method: Method,
        cost: u64,
        priority: u8,
        ticket: Option<usize>,
    ) -> anyhow::Result<Permit<'_>> {
        self.admit_job_shared(method, cost, priority, ticket, None)
    }

    /// [`Self::admit_job`] without priority or arrival ticket — the
    /// plain gate the non-preempting paths use.
    pub fn admit(&self, method: Method, cost: u64) -> anyhow::Result<Permit<'_>> {
        self.admit_job(method, cost, 0, None)
    }

    pub fn stats(&self) -> AdmissionStats {
        let st = self.state.lock().unwrap();
        AdmissionStats {
            peak_concurrent: st.peak_concurrent,
            peak_committed: st.peak_committed,
            peak_by_method: st
                .peak_by_method
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            admitted_total: st.admitted_total,
            preempts_requested: st.preempts_requested,
            weight_shared_admissions: st.weight_shared_admissions,
            peak_weight_bytes: st.peak_weight_bytes,
        }
    }

    fn release(
        &self,
        reg: u64,
        method: &'static str,
        cost: u64,
        weights: Option<WeightClass>,
        tenant: Option<&str>,
    ) {
        {
            let mut st = self.state.lock().unwrap();
            st.committed = st.committed.saturating_sub(cost);
            if let Some(t) = tenant {
                if let Some(c) = st.tenant_committed.get_mut(t) {
                    *c = c.saturating_sub(cost);
                    if *c == 0 {
                        st.tenant_committed.remove(t);
                    }
                }
            }
            if let Some(w) = weights {
                if let Some(e) = st.weights.get_mut(&w.key) {
                    e.holders -= 1;
                    if e.holders == 0 {
                        // Last holder out: the cache entry dies with it,
                        // so the resident bytes come back too.
                        st.committed = st.committed.saturating_sub(e.bytes);
                        st.weights.remove(&w.key);
                    }
                }
            }
            st.active = st.active.saturating_sub(1);
            st.running.retain(|e| e.reg != reg);
            if let Some(n) = st.active_by_method.get_mut(method) {
                *n = n.saturating_sub(1);
            }
        }
        self.cv.notify_all();
    }
}

/// RAII budget reservation: returns its bytes on drop and wakes waiters.
/// While held, [`Self::preempt_requested`] reports whether the gate has
/// asked this job to park itself.
#[derive(Debug)]
pub struct Permit<'a> {
    adm: &'a Admission,
    reg: u64,
    method: &'static str,
    cost: u64,
    weights: Option<WeightClass>,
    flag: Arc<AtomicBool>,
    tenant: Option<String>,
}

impl Permit<'_> {
    pub fn cost(&self) -> u64 {
        self.cost
    }

    /// True once the gate wants this job's reservation back (arrival
    /// pressure from a higher-priority job, or a budget shrink). The
    /// holder should snapshot its session and drop the permit.
    pub fn preempt_requested(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.adm.release(
            self.reg,
            self.method,
            self.cost,
            self.weights,
            self.tenant.as_deref(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::fleet::job::JobSpec;

    fn spec(method: Method) -> JobSpec {
        let mut s = JobSpec::from_base(&TrainConfig::default());
        s.method = method;
        s
    }

    #[test]
    fn mesp_costs_less_than_mebp() {
        // The fleet's raison d'être: the same budget fits more MeSP jobs.
        let mesp = job_cost_bytes(&spec(Method::Mesp)).unwrap();
        let mebp = job_cost_bytes(&spec(Method::Mebp)).unwrap();
        assert!(mesp < mebp, "MeSP {mesp} !< MeBP {mebp}");
    }

    #[test]
    fn q4_jobs_cost_less_than_f32_twins_all_in() {
        // The packed resident-weight term shrinks the FULL per-class
        // footprint (cost + weight class), even after the q4
        // oracle-dequant scratch term inflates the activation cost.
        for method in Method::ALL {
            let f32_spec = spec(method);
            let mut q4_spec = spec(method);
            q4_spec.quant = crate::config::QuantMode::Q4;
            let f = job_cost_bytes(&f32_spec).unwrap()
                + job_weight_class(&f32_spec).unwrap().bytes;
            let q = job_cost_bytes(&q4_spec).unwrap()
                + job_weight_class(&q4_spec).unwrap().bytes;
            assert!(q < f, "{}: q4 total {q} !< f32 total {f}", method.name());
        }
    }

    #[test]
    fn weight_class_keys_track_base_identity() {
        let a = job_weight_class(&spec(Method::Mesp)).unwrap();
        // Method does not change the frozen base.
        let b = job_weight_class(&spec(Method::Mebp)).unwrap();
        assert_eq!(a, b, "same base, same class");
        // Pinning model_seed to the same stream two different data
        // seeds would derive privately → still one class.
        let mut p1 = spec(Method::Mesp);
        let mut p2 = spec(Method::Mesp);
        p1.seed = 1;
        p2.seed = 2;
        p1.model_seed = Some(7);
        p2.model_seed = Some(7);
        assert_eq!(
            job_weight_class(&p1).unwrap().key,
            job_weight_class(&p2).unwrap().key,
            "pinned model seed shares the class across data seeds"
        );
        p2.model_seed = None; // derives from seed 2 → private weights
        assert_ne!(
            job_weight_class(&p1).unwrap().key,
            job_weight_class(&p2).unwrap().key
        );
        let mut q4 = spec(Method::Mesp);
        q4.quant = crate::config::QuantMode::Q4;
        let q = job_weight_class(&q4).unwrap();
        assert_ne!(a.key, q.key, "quant packing is part of the identity");
        assert!(q.bytes < a.bytes, "q4 class is cheaper resident");
    }

    #[test]
    fn shared_weight_class_charged_once_overlaps_many() {
        // Budget sized for exactly TWO private-weight jobs (cost 100 +
        // weights 1000 each). Jobs sharing one weight class pay the
        // 1000 once, so 12 of them fit the same budget.
        let w = WeightClass { key: 42, bytes: 1000 };
        let adm = Admission::new(2 * (100 + 1000));
        let mut permits = Vec::new();
        for _ in 0..12 {
            permits.push(
                adm.admit_job_shared(Method::Mesp, 100, 0, None, Some(w))
                    .unwrap(),
            );
        }
        let st = adm.stats();
        assert_eq!(st.peak_concurrent, 12);
        assert_eq!(st.peak_committed, 1000 + 12 * 100);
        assert_eq!(st.weight_shared_admissions, 11, "first pays, 11 attach");
        assert_eq!(st.peak_weight_bytes, 1000, "one resident copy");
        // A 13th shared job would still fit (2200 - 2200 = 0 < 100? no:
        // committed 2200 == budget) — the gate is full, so a private-
        // class job of the same shape must NOT be admittable now.
        drop(permits);
        // Two distinct classes: each pays its own weights — only two fit.
        let a = adm
            .admit_job_shared(Method::Mesp, 100, 0, None,
                              Some(WeightClass { key: 1, bytes: 1000 }))
            .unwrap();
        let b = adm
            .admit_job_shared(Method::Mesp, 100, 0, None,
                              Some(WeightClass { key: 2, bytes: 1000 }))
            .unwrap();
        assert_eq!(adm.stats().peak_weight_bytes, 2000);
        drop(a);
        drop(b);
    }

    #[test]
    fn last_holder_release_returns_weight_bytes() {
        let w = WeightClass { key: 7, bytes: 500 };
        let adm = Admission::new(1000);
        let p1 = adm
            .admit_job_shared(Method::Mesp, 100, 0, None, Some(w))
            .unwrap();
        let p2 = adm
            .admit_job_shared(Method::Mesp, 100, 0, None, Some(w))
            .unwrap();
        // 500 + 100 + 100 committed; a 350-cost job fits only if the
        // weight bytes are NOT double-charged.
        let p3 = adm.admit(Method::Mebp, 300).unwrap();
        drop(p3);
        drop(p1); // first holder leaves: bytes stay (p2 still holds)
        let p4 = adm.admit(Method::Mebp, 400).unwrap();
        drop(p4);
        drop(p2); // LAST holder leaves: the 500 come back
        let p5 = adm.admit(Method::Mebp, 1000).unwrap();
        drop(p5);
    }

    #[test]
    fn oversized_weight_class_rejected_against_solo_footprint() {
        let adm = Admission::new(100);
        let w = WeightClass { key: 1, bytes: 60 };
        // 50 + 60 > 100: can never fit even though cost alone would.
        let err = adm
            .admit_job_shared(Method::Mesp, 50, 0, None, Some(w))
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds the fleet budget ceiling"), "{err}");
        // 40 + 60 fits exactly.
        let p = adm
            .admit_job_shared(Method::Mesp, 40, 0, None, Some(w))
            .unwrap();
        drop(p);
    }

    #[test]
    fn cost_errors_on_unknown_config() {
        let mut s = spec(Method::Mesp);
        s.config = "nonexistent".into();
        assert!(job_cost_bytes(&s).is_err());
    }

    #[test]
    fn admit_and_release_cycle() {
        let adm = Admission::new(1000);
        let p1 = adm.admit(Method::Mesp, 400).unwrap();
        let p2 = adm.admit(Method::Mesp, 400).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2);
        assert_eq!(adm.stats().peak_committed, 800);
        drop(p1);
        drop(p2);
        let p3 = adm.admit(Method::Mebp, 1000).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2, "peaks are sticky");
        assert_eq!(adm.stats().admitted_total, 3);
        drop(p3);
    }

    #[test]
    fn oversized_job_rejected_immediately() {
        let adm = Admission::new(100);
        assert!(adm.admit(Method::Mesp, 101).is_err());
    }

    #[test]
    fn admit_blocks_until_budget_frees() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        let p = adm.admit(Method::Mesp, 80).unwrap();
        let admitted = Arc::new(AtomicBool::new(false));
        let (adm2, flag) = (Arc::clone(&adm), Arc::clone(&admitted));
        let h = std::thread::spawn(move || {
            let _p = adm2.admit(Method::Mebp, 80).unwrap();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!admitted.load(Ordering::SeqCst), "must wait for the budget");
        drop(p);
        h.join().unwrap();
        assert!(admitted.load(Ordering::SeqCst));
        assert_eq!(adm.stats().peak_concurrent, 1, "never overlapped");
    }

    #[test]
    fn unlimited_budget_never_blocks() {
        let adm = Admission::new(u64::MAX);
        let _a = adm.admit(Method::Mesp, u64::MAX / 4).unwrap();
        let _b = adm.admit(Method::Mesp, u64::MAX / 4).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2);
    }

    #[test]
    fn arrival_tickets_grant_in_id_order() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        // Job 1's admit arrives FIRST but must wait for job 0's grant.
        let adm = Arc::new(Admission::new(1000));
        let order = Arc::new(AtomicUsize::new(0));
        let (adm2, order2) = (Arc::clone(&adm), Arc::clone(&order));
        let h = std::thread::spawn(move || {
            let _p = adm2.admit_job(Method::Mesp, 10, 9, Some(1)).unwrap();
            order2.fetch_max(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(order.load(Ordering::SeqCst), 0, "ticket 1 must wait");
        let _p0 = adm.admit_job(Method::Mesp, 10, 0, Some(0)).unwrap();
        h.join().unwrap();
        assert_eq!(order.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn blocked_higher_priority_arrival_flags_lower_priority_runner() {
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        adm.enable_preemption();
        let low = adm.admit_job(Method::Mesp, 80, 1, Some(0)).unwrap();
        assert!(!low.preempt_requested());
        let adm2 = Arc::clone(&adm);
        let h = std::thread::spawn(move || {
            // blocks: 80 + 80 > 100; flags the priority-1 runner
            let _hi = adm2.admit_job(Method::Mebp, 80, 9, Some(1)).unwrap();
        });
        // wait for the flag to land
        for _ in 0..200 {
            if low.preempt_requested() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(low.preempt_requested(), "runner must be asked to yield");
        assert_eq!(adm.stats().preempts_requested, 1);
        drop(low); // the park: reservation returns, the arrival admits
        h.join().unwrap();
    }

    #[test]
    fn equal_or_higher_priority_runner_is_never_flagged() {
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        adm.enable_preemption();
        let runner = adm.admit_job(Method::Mesp, 80, 5, Some(0)).unwrap();
        let adm2 = Arc::clone(&adm);
        let h = std::thread::spawn(move || {
            let _p = adm2.admit_job(Method::Mesp, 80, 5, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(
            !runner.preempt_requested(),
            "equal priority must not preempt"
        );
        drop(runner);
        h.join().unwrap();
    }

    #[test]
    fn budget_shrink_flags_lowest_priority_runner() {
        let adm = Admission::new(200);
        adm.enable_preemption();
        let a = adm.admit_job(Method::Mesp, 90, 3, Some(0)).unwrap();
        let b = adm.admit_job(Method::Mesp, 90, 1, Some(1)).unwrap();
        adm.set_budget(100);
        assert!(!a.preempt_requested(), "higher-priority runner survives");
        assert!(b.preempt_requested(), "lowest priority parks");
        assert_eq!(adm.budget(), 100);
        drop(b);
        drop(a);
    }

    #[test]
    fn budget_shrink_without_preemption_flags_nobody() {
        let adm = Admission::new(200);
        let a = adm.admit_job(Method::Mesp, 90, 1, Some(0)).unwrap();
        adm.set_budget(50);
        assert!(!a.preempt_requested());
        drop(a);
    }

    #[test]
    fn transient_shrink_parks_instead_of_refusing_when_budget_grows_back() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        // Schedule semantics: budget dips to 40 now, but 100 is still
        // reachable — an 80-cost job must WAIT, not die.
        adm.set_budget_with_ceiling(40, 100);
        let admitted = Arc::new(AtomicBool::new(false));
        let (adm2, flag) = (Arc::clone(&adm), Arc::clone(&admitted));
        let h = std::thread::spawn(move || {
            let _p = adm2.admit(Method::Mesp, 80).unwrap();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!admitted.load(Ordering::SeqCst), "must wait through the dip");
        adm.set_budget_with_ceiling(100, 100); // the promised growth lands
        h.join().unwrap();
        assert!(admitted.load(Ordering::SeqCst));

        // Once the ceiling itself drops below the cost, refusal is
        // permanent and immediate.
        adm.set_budget_with_ceiling(40, 40);
        let err = adm.admit(Method::Mesp, 80).unwrap_err().to_string();
        assert!(err.contains("exceeds the fleet budget ceiling"), "{err}");
    }

    #[test]
    fn infeasible_preemption_flags_nobody() {
        // Budget 100; p9 runs 60, p1 runs 40. A p5 arrival of cost 50
        // could only evict the p1 job (40), leaving 60+50 > 100 — so
        // nobody should be parked for a request that cannot succeed.
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        adm.enable_preemption();
        let hi = adm.admit_job(Method::Mesp, 60, 9, Some(0)).unwrap();
        let lo = adm.admit_job(Method::Mesp, 40, 1, Some(1)).unwrap();
        let adm2 = Arc::clone(&adm);
        let h = std::thread::spawn(move || {
            let _p = adm2.admit_job(Method::Mebp, 50, 5, Some(2)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(!lo.preempt_requested(), "pointless park must not be asked");
        assert!(!hi.preempt_requested());
        assert_eq!(adm.stats().preempts_requested, 0);
        drop(hi); // now 40 + 50 fits after evicting nobody
        h.join().unwrap();
        drop(lo);
    }

    #[test]
    fn refused_ticket_does_not_wedge_later_arrivals() {
        let adm = Admission::new(100);
        assert!(adm.admit_job(Method::Mebp, 101, 0, Some(0)).is_err());
        // ticket 1 must still be grantable
        let p = adm.admit_job(Method::Mesp, 50, 0, Some(1)).unwrap();
        drop(p);
    }

    #[test]
    fn tenant_over_quota_waits_and_does_not_block_other_tenants() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(1000));
        adm.set_tenant_quota("a", 100);
        let p1 = adm
            .admit_job_tenant(Method::Mesp, 80, 0, None, None, Some("a"))
            .unwrap();
        assert_eq!(adm.tenant_committed("a"), 80);
        // Second "a" job would push the tenant to 160 > 100: must wait.
        let admitted = Arc::new(AtomicBool::new(false));
        let (adm2, flag) = (Arc::clone(&adm), Arc::clone(&admitted));
        let h = std::thread::spawn(move || {
            let _p = adm2
                .admit_job_tenant(Method::Mesp, 80, 0, None, None, Some("a"))
                .unwrap();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!admitted.load(Ordering::SeqCst), "quota must gate tenant a");
        // A DIFFERENT tenant must flow past the quota-blocked waiter
        // even though that waiter arrived first.
        let pb = adm
            .admit_job_tenant(Method::Mebp, 80, 0, None, None, Some("b"))
            .unwrap();
        drop(pb);
        assert!(!admitted.load(Ordering::SeqCst));
        drop(p1); // tenant a frees its quota: the waiter admits
        h.join().unwrap();
        assert!(admitted.load(Ordering::SeqCst));
        assert_eq!(adm.tenant_committed("a"), 0, "all permits released");
        assert_eq!(adm.tenant_committed("b"), 0);
    }

    #[test]
    fn job_over_its_tenant_quota_refused_by_name() {
        let adm = Admission::new(1000);
        adm.set_tenant_quota("a", 50);
        let err = adm
            .admit_job_tenant(Method::Mesp, 80, 0, None, None, Some("a"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("tenant 'a' quota"), "{err}");
        // The same job under no tenant (or an unquota'd one) is fine.
        let p = adm
            .admit_job_tenant(Method::Mesp, 80, 0, None, None, Some("b"))
            .unwrap();
        drop(p);
        let p = adm.admit(Method::Mesp, 80).unwrap();
        drop(p);
    }

    #[test]
    fn tenant_committed_tracks_permit_lifetimes() {
        let adm = Admission::new(1000);
        let p1 = adm
            .admit_job_tenant(Method::Mesp, 100, 0, None, None, Some("t"))
            .unwrap();
        let p2 = adm
            .admit_job_tenant(Method::Mebp, 50, 0, None, None, Some("t"))
            .unwrap();
        assert_eq!(adm.tenant_committed("t"), 150);
        drop(p1);
        assert_eq!(adm.tenant_committed("t"), 50);
        drop(p2);
        assert_eq!(adm.tenant_committed("t"), 0);
        assert_eq!(adm.tenant_committed("nobody"), 0);
    }
}
