//! Admission control: keep the sum of predicted per-session peak memory
//! under the device budget.
//!
//! Each job is costed BEFORE it starts with the analytical peak-memory
//! model (`memory::model`) at tracked widths, plus the reference
//! backend's always-resident weight copies and the prefetch queue — i.e.
//! the worst tracked moment one `TrainSession` of that spec can reach.
//! Workers block in [`Admission::admit`] until the budget has room
//! (backpressure); the permit is RAII, so a finished (or crashed) session
//! always returns its reservation. Because the per-job cost is an upper
//! bound on the session's tracked peak, `sum(admitted costs) <= budget`
//! implies the fleet-wide aggregate tracked peak stays under the budget.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use crate::config::{presets, Method};
use crate::coordinator::PREFETCH_DEPTH;
use crate::memory::{model as memmodel, Widths};
use crate::util::stats::fmt_mb;

use super::job::JobSpec;

/// Predicted peak tracked bytes for one session running `spec`:
/// the analytical per-method activation/gradient peak (tracked widths,
///   quant-aware: q4 adds the naive-oracle dequant-buffer scratch)
/// + the resident weight uploads at the job's quant mode (the reference
///   backend keeps the frozen model on-device; under q4 the projections
///   stay int4-packed, which is the term that lets one budget overlap
///   more quantized jobs)
/// + the prefetch queue's batch buffers.
pub fn job_cost_bytes(spec: &JobSpec) -> anyhow::Result<u64> {
    let dims = presets::compiled(&spec.config)?;
    let activations = memmodel::peak_q(
        spec.method, &dims, spec.optimizer, Widths::tracked(), spec.quant,
    )
    .total();
    let weights = memmodel::resident_weight_bytes(&dims, spec.quant);
    let batch_bytes = 2 * (dims.batch * dims.seq * 4) as u64; // tokens+targets i32
    let queue = (PREFETCH_DEPTH as u64 + 2) * batch_bytes;
    Ok(activations + weights + queue)
}

#[derive(Debug, Default)]
struct AdmState {
    /// Sum of admitted job costs currently outstanding.
    committed: u64,
    /// Number of admitted jobs currently outstanding.
    active: usize,
    active_by_method: BTreeMap<&'static str, usize>,
    peak_concurrent: usize,
    peak_committed: u64,
    peak_by_method: BTreeMap<&'static str, usize>,
    admitted_total: usize,
}

/// Snapshot of the admission high-water marks for the fleet report.
#[derive(Debug, Clone, Default)]
pub struct AdmissionStats {
    /// Most jobs ever admitted at once.
    pub peak_concurrent: usize,
    /// Highest sum of admitted costs (predicted occupancy high-water).
    pub peak_committed: u64,
    /// Most concurrently-admitted jobs per method name.
    pub peak_by_method: BTreeMap<String, usize>,
    /// Total jobs admitted over the fleet's lifetime.
    pub admitted_total: usize,
}

/// The budget gate. Shared by all workers of one fleet run.
#[derive(Debug)]
pub struct Admission {
    budget: u64,
    state: Mutex<AdmState>,
    cv: Condvar,
}

impl Admission {
    pub fn new(budget: u64) -> Admission {
        Admission {
            budget,
            state: Mutex::new(AdmState::default()),
            cv: Condvar::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Reserve `cost` bytes for a job of `method`, blocking while the
    /// budget is full. Errors immediately if the job could never fit.
    pub fn admit(&self, method: Method, cost: u64) -> anyhow::Result<Permit<'_>> {
        anyhow::ensure!(
            cost <= self.budget,
            "job cost {} MB exceeds the fleet budget {} MB — it can never \
             be admitted",
            fmt_mb(cost),
            fmt_mb(self.budget)
        );
        let name = method.name();
        let mut st = self.state.lock().unwrap();
        while cost > self.budget - st.committed {
            st = self.cv.wait(st).unwrap();
        }
        st.committed += cost;
        st.active += 1;
        st.admitted_total += 1;
        st.peak_committed = st.peak_committed.max(st.committed);
        st.peak_concurrent = st.peak_concurrent.max(st.active);
        let per = st.active_by_method.entry(name).or_insert(0);
        *per += 1;
        let per = *per;
        let peak = st.peak_by_method.entry(name).or_insert(0);
        *peak = (*peak).max(per);
        Ok(Permit { adm: self, method: name, cost })
    }

    pub fn stats(&self) -> AdmissionStats {
        let st = self.state.lock().unwrap();
        AdmissionStats {
            peak_concurrent: st.peak_concurrent,
            peak_committed: st.peak_committed,
            peak_by_method: st
                .peak_by_method
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            admitted_total: st.admitted_total,
        }
    }

    fn release(&self, method: &'static str, cost: u64) {
        {
            let mut st = self.state.lock().unwrap();
            st.committed = st.committed.saturating_sub(cost);
            st.active = st.active.saturating_sub(1);
            if let Some(n) = st.active_by_method.get_mut(method) {
                *n = n.saturating_sub(1);
            }
        }
        self.cv.notify_all();
    }
}

/// RAII budget reservation: returns its bytes on drop and wakes waiters.
#[derive(Debug)]
pub struct Permit<'a> {
    adm: &'a Admission,
    method: &'static str,
    cost: u64,
}

impl Permit<'_> {
    pub fn cost(&self) -> u64 {
        self.cost
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.adm.release(self.method, self.cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::fleet::job::JobSpec;

    fn spec(method: Method) -> JobSpec {
        let mut s = JobSpec::from_base(&TrainConfig::default());
        s.method = method;
        s
    }

    #[test]
    fn mesp_costs_less_than_mebp() {
        // The fleet's raison d'être: the same budget fits more MeSP jobs.
        let mesp = job_cost_bytes(&spec(Method::Mesp)).unwrap();
        let mebp = job_cost_bytes(&spec(Method::Mebp)).unwrap();
        assert!(mesp < mebp, "MeSP {mesp} !< MeBP {mebp}");
    }

    #[test]
    fn q4_jobs_cost_less_than_f32_twins() {
        // The packed resident-weight term shrinks the charge, even after
        // the q4 oracle-dequant scratch term is added.
        for method in Method::ALL {
            let f32_spec = spec(method);
            let mut q4_spec = spec(method);
            q4_spec.quant = crate::config::QuantMode::Q4;
            let f = job_cost_bytes(&f32_spec).unwrap();
            let q = job_cost_bytes(&q4_spec).unwrap();
            assert!(q < f, "{}: q4 cost {q} !< f32 cost {f}", method.name());
        }
    }

    #[test]
    fn cost_errors_on_unknown_config() {
        let mut s = spec(Method::Mesp);
        s.config = "nonexistent".into();
        assert!(job_cost_bytes(&s).is_err());
    }

    #[test]
    fn admit_and_release_cycle() {
        let adm = Admission::new(1000);
        let p1 = adm.admit(Method::Mesp, 400).unwrap();
        let p2 = adm.admit(Method::Mesp, 400).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2);
        assert_eq!(adm.stats().peak_committed, 800);
        drop(p1);
        drop(p2);
        let p3 = adm.admit(Method::Mebp, 1000).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2, "peaks are sticky");
        assert_eq!(adm.stats().admitted_total, 3);
        drop(p3);
    }

    #[test]
    fn oversized_job_rejected_immediately() {
        let adm = Admission::new(100);
        assert!(adm.admit(Method::Mesp, 101).is_err());
    }

    #[test]
    fn admit_blocks_until_budget_frees() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let adm = Arc::new(Admission::new(100));
        let p = adm.admit(Method::Mesp, 80).unwrap();
        let admitted = Arc::new(AtomicBool::new(false));
        let (adm2, flag) = (Arc::clone(&adm), Arc::clone(&admitted));
        let h = std::thread::spawn(move || {
            let _p = adm2.admit(Method::Mebp, 80).unwrap();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!admitted.load(Ordering::SeqCst), "must wait for the budget");
        drop(p);
        h.join().unwrap();
        assert!(admitted.load(Ordering::SeqCst));
        assert_eq!(adm.stats().peak_concurrent, 1, "never overlapped");
    }

    #[test]
    fn unlimited_budget_never_blocks() {
        let adm = Admission::new(u64::MAX);
        let _a = adm.admit(Method::Mesp, u64::MAX / 4).unwrap();
        let _b = adm.admit(Method::Mesp, u64::MAX / 4).unwrap();
        assert_eq!(adm.stats().peak_concurrent, 2);
    }
}
