//! `mesp serve`: a long-lived fleet daemon behind a Unix socket.
//!
//! Where `mesp fleet` runs a fixed job list to completion, `serve`
//! accepts jobs over the [`super::protocol`] JSONL protocol for as long
//! as it lives, schedules them through the SAME admission/preemption
//! engine ([`super::admission`]), and survives being SIGKILLed: every
//! accepted job is journaled to a JSON sidecar in `--snapshot-dir`, and
//! running jobs checkpoint to bitwise-resumable snapshots
//! ([`crate::persist`]), so a restarted daemon rescans the directory and
//! re-admits every interrupted job exactly where it stopped.
//!
//! # Scheduling: weighted-fair queuing over tenants
//!
//! Every submit names a tenant (default [`super::protocol::DEFAULT_TENANT`]).
//! Dispatch is stride scheduling: each tenant holds a FIFO queue and a
//! `pass` counter; a free worker serves the tenant with the lowest pass,
//! which then advances by `STRIDE / weight`. A tenant with weight 2 gets
//! twice the dispatch share of a weight-1 tenant under contention, and an
//! idle tenant's unused share flows to the others. Below dispatch, the
//! admission gate enforces the byte budget and optional per-tenant
//! quotas ([`Admission::set_tenant_quota`]) — WFQ decides *order*,
//! admission decides *fit*.
//!
//! # Crash recovery contract
//!
//! - On submit, a sidecar `job-<id>.json` (tenant + full resolved spec,
//!   seeds encoded exactly) is written atomically BEFORE the ack frame.
//! - Running real jobs checkpoint every `--checkpoint-every` steps and
//!   park to a snapshot on preemption or shutdown.
//! - On startup, the daemon acquires `serve.lock`
//!   ([`crate::persist::LockFile`]), rescans the dir, and re-admits each
//!   sidecar-journaled job — resuming from its newest `job-<id>-step-N.snap`
//!   when one exists, from scratch otherwise. Either way the final
//!   adapter bits match an uninterrupted run (the persist contract).
//! - Terminal jobs remove their sidecar; completed real jobs leave a
//!   `job-<id>-final.snap` so tests (and operators) can compare runs
//!   bitwise.
//!
//! # Exit codes
//!
//! The `mesp serve` process distinguishes how it died (CI scripts branch
//! on this): [`EXIT_OK`] clean drain/shutdown, [`EXIT_RUNTIME`] runtime
//! failure, [`EXIT_JOB_FAILURES`] clean exit but some jobs failed,
//! [`EXIT_STARTUP`] could not start (bad socket, live lock holder,
//! corrupt sidecar). `mesp fleet` uses the same scheme.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{
    ActCompress, Method, OptimizerKind, QuantMode, TrainConfig,
};
use crate::coordinator::TrainSession;
use crate::memory::MemoryTracker;
use crate::model::WeightCache;
use crate::obs::MetricsRegistry;
use crate::persist::LockFile;
use crate::util::json::Json;
use crate::util::rng::{derive, stream};
use crate::util::stats::fmt_mb;

use super::admission::{job_cost_bytes, job_weight_class, Admission, Permit};
use super::job::JobSpec;
use super::protocol::{self, code, ProtoError, Verb};
use super::scheduler::{kernel_thread_budget, BudgetChange, Progress};

/// Clean exit: drained or shut down with every completed job healthy.
pub const EXIT_OK: i32 = 0;
/// The daemon (or fleet) itself failed at runtime.
pub const EXIT_RUNTIME: i32 = 1;
/// Clean exit, but at least one job FAILED (vs cancelled/parked).
pub const EXIT_JOB_FAILURES: i32 = 2;
/// Could not start: bad socket path, live lock holder, corrupt sidecar…
pub const EXIT_STARTUP: i32 = 3;

/// Stride-scheduling quantum: a tenant's pass advances by
/// `STRIDE / weight` per dispatch, so relative dispatch rates follow
/// relative weights exactly.
const STRIDE: u64 = 1 << 20;

/// How long the accept loop sleeps when no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Everything `mesp serve` is configured with.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Unix socket path the daemon listens on.
    pub socket: PathBuf,
    /// Sidecars, checkpoints and the liveness lock live here; rescanned
    /// on startup for crash recovery.
    pub snapshot_dir: PathBuf,
    /// Shared admission budget in bytes.
    pub budget_bytes: u64,
    /// Worker threads running admitted jobs.
    pub workers: usize,
    /// Checkpoint running REAL jobs every N steps (0 = only on
    /// preemption/shutdown). Smaller = less lost work on SIGKILL.
    pub checkpoint_every: usize,
    /// Budget changes keyed on total fleet steps (same engine as
    /// `mesp fleet --budget-schedule`).
    pub budget_schedule: Vec<BudgetChange>,
    /// Per-tenant admission quotas in bytes (tenant, quota).
    pub quotas: Vec<(String, u64)>,
    /// Per-tenant WFQ weights (tenant, weight); unlisted tenants get 1.
    pub tenant_weights: Vec<(String, u64)>,
    /// Export the metrics-registry JSONL here on exit.
    pub metrics_out: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            socket: PathBuf::from("mesp.sock"),
            snapshot_dir: PathBuf::from("serve-state"),
            budget_bytes: 1 << 30,
            workers: 1,
            checkpoint_every: 0,
            budget_schedule: Vec::new(),
            quotas: Vec::new(),
            tenant_weights: Vec::new(),
            metrics_out: None,
        }
    }
}

/// Parse `tenant:MB,tenant:MB` (quotas) or `tenant:weight` lists.
/// `mb` scales values by 2^20 (the CLI speaks MB, quotas are bytes).
pub fn parse_tenant_list(
    s: &str,
    what: &str,
    mb: bool,
) -> anyhow::Result<Vec<(String, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let (tenant, val) = p.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("{what} entry '{p}' is not tenant:value")
        })?;
        let tenant = tenant.trim();
        anyhow::ensure!(!tenant.is_empty(), "{what} entry '{p}' has no tenant");
        let v: u64 = val.trim().parse().map_err(|_| {
            anyhow::anyhow!("{what} value '{val}' is not an integer")
        })?;
        anyhow::ensure!(v > 0, "{what} value for '{tenant}' must be positive");
        let v = if mb {
            v.checked_mul(1 << 20)
                .ok_or_else(|| anyhow::anyhow!("{what} {v} MB overflows"))?
        } else {
            v
        };
        out.push((tenant.to_string(), v));
    }
    anyhow::ensure!(!out.is_empty(), "empty {what} list '{s}'");
    Ok(out)
}

/// Lifecycle of one daemon job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobState {
    Queued,
    Running,
    Parked,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Parked => "parked",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

/// Incrementally-maintained state tallies — `status` must not scan the
/// whole job table per poll (the loadgen polls it thousands of times).
#[derive(Debug, Default, Clone, Copy)]
struct Counts {
    queued: usize,
    running: usize,
    parked: usize,
    done: usize,
    failed: usize,
    cancelled: usize,
}

impl Counts {
    fn slot(&mut self, s: JobState) -> &mut usize {
        match s {
            JobState::Queued => &mut self.queued,
            JobState::Running => &mut self.running,
            JobState::Parked => &mut self.parked,
            JobState::Done => &mut self.done,
            JobState::Failed => &mut self.failed,
            JobState::Cancelled => &mut self.cancelled,
        }
    }

    /// Jobs the daemon still owes work to.
    fn active(&self) -> usize {
        self.queued + self.running + self.parked
    }
}

/// One tenant's dispatch queue, stride state and service tallies.
#[derive(Debug)]
struct Tenant {
    queue: VecDeque<u64>,
    /// Stride pass: lowest pass is served next.
    pass: u64,
    weight: u64,
    submitted: u64,
    done: u64,
    failed: u64,
    cancelled: u64,
    /// Optimization steps completed for this tenant (service measure —
    /// the loadgen's fairness ratio is built on this).
    steps: u64,
}

/// One job the daemon has accepted.
#[derive(Debug)]
struct JobRecord {
    tenant: String,
    spec: JobSpec,
    sim: bool,
    sim_us: u64,
    state: JobState,
    submitted: Instant,
    /// Virtual steps completed so far (sim jobs park in memory).
    sim_steps_done: usize,
    /// Newest parked snapshot (real jobs).
    parked_snap: Option<PathBuf>,
    preempts: u64,
    resumes: u64,
    error: Option<String>,
    /// Cooperative-cancel flag, polled at step boundaries.
    cancel: Arc<AtomicBool>,
    /// Submit-to-done seconds, set at completion.
    latency_s: Option<f64>,
    /// Re-admitted by a crash-recovery rescan (not a live submit).
    recovered: bool,
}

/// Everything behind the daemon's state mutex.
struct DaemonState {
    jobs: BTreeMap<u64, JobRecord>,
    tenants: BTreeMap<String, Tenant>,
    counts: Counts,
    next_id: u64,
    draining: bool,
}

impl DaemonState {
    fn tenant_entry(&mut self, name: &str, weight: u64) -> &mut Tenant {
        // A newcomer starts at the minimum live pass so it neither jumps
        // the whole queue nor waits out everyone's accumulated strides.
        let floor =
            self.tenants.values().map(|t| t.pass).min().unwrap_or(0);
        self.tenants.entry(name.to_string()).or_insert_with(|| Tenant {
            queue: VecDeque::new(),
            pass: floor,
            weight,
            submitted: 0,
            done: 0,
            failed: 0,
            cancelled: 0,
            steps: 0,
        })
    }
}

/// Serve the tenant with the lowest pass (ties broken by name for
/// determinism); pop its head job and mark it Running.
fn pick_wfq(st: &mut DaemonState) -> Option<u64> {
    let name = st
        .tenants
        .iter()
        .filter(|(_, t)| !t.queue.is_empty())
        .min_by(|a, b| a.1.pass.cmp(&b.1.pass).then(a.0.cmp(b.0)))
        .map(|(n, _)| n.clone())?;
    let t = st.tenants.get_mut(&name).expect("tenant just observed");
    let id = t.queue.pop_front().expect("queue non-empty by filter");
    t.pass += STRIDE / t.weight.max(1);
    let rec = st.jobs.get_mut(&id).expect("queued job has a record");
    let from = rec.state;
    rec.state = JobState::Running;
    *st.counts.slot(from) -= 1;
    st.counts.running += 1;
    Some(id)
}

// ---------------------------------------------------------------------
// Job sidecars: the journal half of the crash-recovery contract.
// Seeds are encoded as DECIMAL STRINGS, not JSON numbers — derived
// per-job seeds use the full u64 range and must survive the round trip
// bit-exactly (JSON numbers go through f64).
// ---------------------------------------------------------------------

fn optimizer_name(o: OptimizerKind) -> &'static str {
    match o {
        OptimizerKind::Sgd => "sgd",
        OptimizerKind::Momentum { .. } => "momentum",
        OptimizerKind::Adam { .. } => "adam",
    }
}

fn sidecar_json(
    id: u64,
    tenant: &str,
    sim: bool,
    sim_us: u64,
    spec: &JobSpec,
) -> Json {
    let spec_obj = Json::obj(vec![
        ("config", Json::str(&spec.config)),
        ("method", Json::str(spec.method.name())),
        ("steps", Json::num(spec.steps as f64)),
        ("seed", Json::str(spec.seed.to_string())),
        ("lr", Json::Num(spec.lr as f64)),
        ("optimizer", Json::str(optimizer_name(spec.optimizer))),
        ("quant", Json::str(spec.quant.name())),
        ("loss_chunk", Json::num(spec.loss_chunk as f64)),
        ("act_compress", Json::str(spec.act_compress.name())),
        (
            "model_seed",
            spec.model_seed
                .map_or(Json::Null, |s| Json::str(s.to_string())),
        ),
        ("priority", Json::num(spec.priority as f64)),
    ]);
    Json::obj(vec![
        ("v", Json::num(1.0)),
        ("id", Json::num(id as f64)),
        ("tenant", Json::str(tenant)),
        ("sim", Json::Bool(sim)),
        ("sim_us", Json::num(sim_us as f64)),
        ("spec", spec_obj),
    ])
}

fn seed_field(j: &Json, key: &str) -> anyhow::Result<u64> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("sidecar spec missing '{key}'"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("sidecar '{key}' is not a u64"))
}

/// A job reconstructed from its sidecar during the startup rescan.
struct RecoveredJob {
    id: u64,
    tenant: String,
    sim: bool,
    sim_us: u64,
    spec: JobSpec,
    snap: Option<PathBuf>,
}

fn sidecar_parse(j: &Json) -> anyhow::Result<RecoveredJob> {
    let ver = j.get("v").and_then(|v| v.as_usize()).unwrap_or(0);
    anyhow::ensure!(ver == 1, "sidecar version {ver}, expected 1");
    let id = j
        .get("id")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("sidecar missing 'id'"))?
        as u64;
    let tenant = j
        .get("tenant")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("sidecar missing 'tenant'"))?
        .to_string();
    let sim = matches!(j.get("sim"), Some(Json::Bool(true)));
    let sim_us =
        j.get("sim_us").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
    let s = j
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("sidecar missing 'spec'"))?;
    let field = |key: &str| -> anyhow::Result<&str> {
        s.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("sidecar spec missing '{key}'"))
    };
    let num = |key: &str| -> anyhow::Result<usize> {
        s.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("sidecar spec missing '{key}'"))
    };
    let lr = s
        .get("lr")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("sidecar spec missing 'lr'"))?;
    let model_seed = match s.get("model_seed") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| {
                    anyhow::anyhow!("sidecar 'model_seed' is not a string")
                })?
                .parse()
                .map_err(|_| {
                    anyhow::anyhow!("sidecar 'model_seed' is not a u64")
                })?,
        ),
    };
    let spec = JobSpec {
        config: field("config")?.to_string(),
        method: Method::parse(field("method")?)?,
        steps: num("steps")?,
        seed: seed_field(s, "seed")?,
        lr: lr as f32,
        optimizer: OptimizerKind::parse(field("optimizer")?)?,
        quant: QuantMode::parse(field("quant")?)?,
        loss_chunk: num("loss_chunk")?,
        act_compress: ActCompress::parse(field("act_compress")?)?,
        model_seed,
        priority: num("priority")? as u8,
    };
    Ok(RecoveredJob { id, tenant, sim, sim_us, spec, snap: None })
}

/// Scan `dir` for job sidecars and their newest step snapshots. A
/// corrupt sidecar is a STARTUP error (named file) — sidecars are
/// written atomically, so corruption means something other than a crash
/// touched the dir.
fn scan_recovery(dir: &Path) -> anyhow::Result<Vec<RecoveredJob>> {
    let mut out: Vec<RecoveredJob> = Vec::new();
    let mut snaps: HashMap<u64, (usize, PathBuf)> = HashMap::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out), // fresh dir: nothing to recover
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let Some(rest) = name.strip_prefix("job-") else { continue };
        if let Some(ids) = rest.strip_suffix(".json") {
            if ids.parse::<u64>().is_err() {
                continue; // tmp files and friends
            }
            let text = std::fs::read_to_string(&path).map_err(|e| {
                anyhow::anyhow!("read job sidecar {}: {e}", path.display())
            })?;
            let j = Json::parse(&text).map_err(|e| {
                anyhow::anyhow!("corrupt job sidecar {}: {e}", path.display())
            })?;
            out.push(sidecar_parse(&j).map_err(|e| {
                anyhow::anyhow!("corrupt job sidecar {}: {e}", path.display())
            })?);
        } else if let Some(stem) = rest.strip_suffix(".snap") {
            // job-<id>-step-<n>.snap — keep the newest per job.
            let Some((ids, step)) = stem.split_once("-step-") else {
                continue;
            };
            let (Ok(id), Ok(step)) =
                (ids.parse::<u64>(), step.parse::<usize>())
            else {
                continue;
            };
            match snaps.get(&id) {
                Some((best, _)) if *best >= step => {}
                _ => {
                    snaps.insert(id, (step, path.clone()));
                }
            }
        }
    }
    for r in &mut out {
        r.snap = snaps.remove(&r.id).map(|(_, p)| p);
    }
    out.sort_by_key(|r| r.id);
    Ok(out)
}

// ---------------------------------------------------------------------
// The daemon proper.
// ---------------------------------------------------------------------

/// Shared daemon core: verb dispatch mutates the state table, workers
/// drain it through the admission gate.
struct Daemon {
    st: Mutex<DaemonState>,
    cv: Condvar,
    /// Set once on shutdown (verb, drain completion, or fatal accept
    /// error); workers and step loops poll it.
    stop: AtomicBool,
    admission: Admission,
    registry: MetricsRegistry,
    aggregate: MemoryTracker,
    weight_cache: WeightCache,
    progress: Progress,
    base: TrainConfig,
    opts: ServeOptions,
    quotas: HashMap<String, u64>,
    weights: HashMap<String, u64>,
    /// Root of the per-job derived seed streams (same discipline as
    /// `fleet::job::load_jobs`).
    job_seed: u64,
    started: Instant,
    recovered: u64,
}

impl Daemon {
    fn weight_of(&self, tenant: &str) -> u64 {
        self.weights.get(tenant).copied().unwrap_or(1)
    }

    fn sidecar_path(&self, id: u64) -> PathBuf {
        self.opts.snapshot_dir.join(format!("job-{id}.json"))
    }

    fn snap_path(&self, id: u64, step: usize) -> PathBuf {
        self.opts.snapshot_dir.join(format!("job-{id}-step-{step}.snap"))
    }

    fn final_path(&self, id: u64) -> PathBuf {
        self.opts.snapshot_dir.join(format!("job-{id}-final.snap"))
    }

    /// Atomically persist one job's sidecar (tmp + rename): a SIGKILL
    /// mid-write must never leave a half sidecar for the next rescan.
    fn write_sidecar(
        &self,
        id: u64,
        tenant: &str,
        sim: bool,
        sim_us: u64,
        spec: &JobSpec,
    ) -> anyhow::Result<()> {
        let path = self.sidecar_path(id);
        let tmp = path.with_extension("json.tmp");
        let text = sidecar_json(id, tenant, sim, sim_us, spec).to_string();
        std::fs::write(&tmp, text).map_err(|e| {
            anyhow::anyhow!("write sidecar {}: {e}", tmp.display())
        })?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            anyhow::anyhow!("persist sidecar {}: {e}", path.display())
        })?;
        Ok(())
    }

    /// Remove a terminal job's on-disk footprint. `keep_final` leaves
    /// `job-<id>-final.snap` behind (completed real jobs — the bitwise
    /// comparison artifact).
    fn cleanup_files(&self, id: u64, keep_final: bool) {
        let _ = std::fs::remove_file(self.sidecar_path(id));
        let prefix = format!("job-{id}-step-");
        if let Ok(entries) = std::fs::read_dir(&self.opts.snapshot_dir) {
            for entry in entries.flatten() {
                let p = entry.path();
                if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
                    if name.starts_with(&prefix) && name.ends_with(".snap") {
                        let _ = std::fs::remove_file(&p);
                    }
                }
            }
        }
        if !keep_final {
            let _ = std::fs::remove_file(self.final_path(id));
        }
    }

    /// Move a job to a terminal state and settle every ledger: counts,
    /// tenant service tallies, latency histogram, lifecycle counters,
    /// and the on-disk footprint.
    fn finish(&self, id: u64, to: JobState, error: Option<String>) {
        debug_assert!(matches!(
            to,
            JobState::Done | JobState::Failed | JobState::Cancelled
        ));
        let steps;
        {
            let mut st = self.st.lock().unwrap();
            let rec = st.jobs.get_mut(&id).expect("finishing a known job");
            let from = rec.state;
            rec.state = to;
            rec.error = error;
            steps = rec.spec.steps as u64;
            let latency = rec.submitted.elapsed().as_secs_f64();
            if to == JobState::Done {
                rec.latency_s = Some(latency);
            }
            let tenant = rec.tenant.clone();
            *st.counts.slot(from) -= 1;
            *st.counts.slot(to) += 1;
            let w = self.weight_of(&tenant);
            let t = st.tenant_entry(&tenant, w);
            match to {
                JobState::Done => {
                    t.done += 1;
                    t.steps += steps;
                }
                JobState::Failed => t.failed += 1,
                JobState::Cancelled => t.cancelled += 1,
                _ => unreachable!(),
            }
            if to == JobState::Done {
                self.registry.observe("serve/latency_s", latency);
            }
        }
        let counter = match to {
            JobState::Done => "serve/done",
            JobState::Failed => "serve/failed",
            JobState::Cancelled => "serve/cancelled",
            _ => unreachable!(),
        };
        self.registry.counter_add(counter, 1);
        self.cleanup_files(id, to == JobState::Done);
        self.cv.notify_all();
    }

    /// Park a job back into its tenant queue (preemption, or shutdown
    /// with work left). `snap` is the fresh checkpoint for real jobs;
    /// sim jobs park their virtual step count in memory instead.
    fn park(&self, id: u64, snap: Option<PathBuf>, preempted: bool) {
        {
            let mut st = self.st.lock().unwrap();
            let rec = st.jobs.get_mut(&id).expect("parking a known job");
            let from = rec.state;
            rec.state = JobState::Parked;
            if snap.is_some() {
                rec.parked_snap = snap;
            }
            if preempted {
                rec.preempts += 1;
            }
            let tenant = rec.tenant.clone();
            *st.counts.slot(from) -= 1;
            st.counts.parked += 1;
            let w = self.weight_of(&tenant);
            st.tenant_entry(&tenant, w).queue.push_back(id);
        }
        if preempted {
            self.registry.counter_add("fleet/preempts", 1);
        }
        self.cv.notify_all();
    }

    // -----------------------------------------------------------------
    // Worker side.
    // -----------------------------------------------------------------

    fn worker_loop(&self, workers: usize) {
        loop {
            let id = {
                let mut st = self.st.lock().unwrap();
                loop {
                    if self.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    if let Some(id) = pick_wfq(&mut st) {
                        break id;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            self.run_one(id, workers);
        }
    }

    /// Cost → admit (tenant-aware, blocking) → run to completion, a
    /// park, a cancel, or a failure.
    fn run_one(&self, id: u64, workers: usize) {
        let (spec, tenant, sim, sim_us, cancel, parked_snap, sim_done) = {
            let st = self.st.lock().unwrap();
            let r = &st.jobs[&id];
            (
                r.spec.clone(),
                r.tenant.clone(),
                r.sim,
                r.sim_us,
                r.cancel.clone(),
                r.parked_snap.clone(),
                r.sim_steps_done,
            )
        };
        if cancel.load(Ordering::SeqCst) {
            self.finish(id, JobState::Cancelled, None);
            return;
        }
        let cost = match job_cost_bytes(&spec) {
            Ok(c) => c,
            Err(e) => {
                self.finish(
                    id,
                    JobState::Failed,
                    Some(format!("costing failed: {e:#}")),
                );
                return;
            }
        };
        let wclass = match job_weight_class(&spec) {
            Ok(w) => w,
            Err(e) => {
                self.finish(
                    id,
                    JobState::Failed,
                    Some(format!("costing failed: {e:#}")),
                );
                return;
            }
        };
        let queued = Instant::now();
        let permit = match self.admission.admit_job_tenant(
            spec.method,
            cost,
            spec.priority,
            None,
            Some(wclass),
            Some(&tenant),
        ) {
            Ok(p) => p,
            Err(e) => {
                if self.stop.load(Ordering::SeqCst) {
                    // Gate closed by shutdown: the job is not failed,
                    // just unserved — park it for the next daemon life.
                    self.park(id, None, false);
                } else {
                    self.finish(id, JobState::Failed, Some(format!("{e:#}")));
                }
                return;
            }
        };
        self.registry
            .observe("serve/admission_wait_s", queued.elapsed().as_secs_f64());
        if sim {
            self.run_sim(id, &spec, sim_us, sim_done, &cancel, permit);
        } else {
            self.run_real(id, &spec, &cancel, parked_snap, permit, workers);
        }
    }

    /// Virtual job: real admission reservation, virtual step loop. This
    /// is what lets the loadgen push hundreds of thousands of arrivals
    /// through the REAL scheduling machinery in minutes.
    fn run_sim(
        &self,
        id: u64,
        spec: &JobSpec,
        sim_us: u64,
        mut done: usize,
        cancel: &AtomicBool,
        permit: Permit<'_>,
    ) {
        let target = spec.steps;
        while done < target {
            if cancel.load(Ordering::SeqCst) {
                drop(permit);
                self.finish(id, JobState::Cancelled, None);
                return;
            }
            let stopping = self.stop.load(Ordering::SeqCst);
            if stopping || permit.preempt_requested() {
                {
                    let mut st = self.st.lock().unwrap();
                    st.jobs
                        .get_mut(&id)
                        .expect("running job has a record")
                        .sim_steps_done = done;
                }
                drop(permit);
                self.park(id, None, !stopping);
                return;
            }
            if sim_us > 0 {
                std::thread::sleep(Duration::from_micros(sim_us));
            }
            done += 1;
            self.progress.bump(&self.admission);
        }
        drop(permit);
        self.finish(id, JobState::Done, None);
    }

    /// Real job: full `TrainSession`, resumed from its newest snapshot
    /// when one exists, checkpointed per `--checkpoint-every`, parked
    /// bitwise-resumable on preemption or shutdown.
    fn run_real(
        &self,
        id: u64,
        spec: &JobSpec,
        cancel: &AtomicBool,
        parked_snap: Option<PathBuf>,
        permit: Permit<'_>,
        workers: usize,
    ) {
        let mut cfg = spec.to_train_config(&self.base);
        if cfg.threads == 0 {
            cfg.threads = kernel_thread_budget(
                crate::runtime::kernels::auto_threads(),
                workers,
            );
        }
        let target = cfg.steps;
        let mut builder = TrainSession::builder(cfg)
            .tracker(self.aggregate.child())
            .weight_cache(self.weight_cache.clone())
            .registry(self.registry.clone());
        if let Some(p) = &parked_snap {
            builder = builder.resume_from(p);
        }
        let mut sess = match builder.build() {
            Ok(s) => s,
            Err(e) => {
                drop(permit);
                self.finish(
                    id,
                    JobState::Failed,
                    Some(format!("session build: {e:#}")),
                );
                return;
            }
        };
        let mut last_snap = parked_snap;
        if last_snap.is_some() {
            self.registry.counter_add("fleet/resumes", 1);
            let mut st = self.st.lock().unwrap();
            st.jobs.get_mut(&id).expect("running job").resumes += 1;
        }
        loop {
            if cancel.load(Ordering::SeqCst) {
                drop(sess);
                drop(permit);
                self.finish(id, JobState::Cancelled, None);
                return;
            }
            let stopping = self.stop.load(Ordering::SeqCst);
            if stopping || permit.preempt_requested() {
                let path = self.snap_path(id, sess.steps_done());
                match sess.save_snapshot(&path) {
                    Ok(_) => {
                        if let Some(old) = &last_snap {
                            if old != &path {
                                let _ = std::fs::remove_file(old);
                            }
                        }
                        drop(sess);
                        drop(permit);
                        self.park(id, Some(path), !stopping);
                    }
                    Err(e) => {
                        drop(sess);
                        drop(permit);
                        self.finish(
                            id,
                            JobState::Failed,
                            Some(format!("snapshot: {e:#}")),
                        );
                    }
                }
                return;
            }
            if sess.steps_done() >= target {
                break;
            }
            if let Err(e) = sess.step_once() {
                drop(sess);
                drop(permit);
                self.finish(id, JobState::Failed, Some(format!("{e:#}")));
                return;
            }
            self.progress.bump(&self.admission);
            let n = sess.steps_done();
            if self.opts.checkpoint_every > 0
                && n < target
                && n % self.opts.checkpoint_every == 0
            {
                // Crash-recovery checkpoint: best-effort (a failed write
                // only costs recovery granularity, not correctness).
                let path = self.snap_path(id, n);
                if sess.save_snapshot(&path).is_ok() {
                    if let Some(old) = last_snap.replace(path) {
                        let _ = std::fs::remove_file(&old);
                    }
                }
            }
        }
        // Completed: the final snapshot is the bitwise-comparison
        // artifact (`job-<id>-final.snap` survives cleanup).
        if let Err(e) = sess.save_snapshot(&self.final_path(id)) {
            drop(sess);
            drop(permit);
            self.finish(
                id,
                JobState::Failed,
                Some(format!("final snapshot: {e:#}")),
            );
            return;
        }
        drop(sess);
        drop(permit);
        self.finish(id, JobState::Done, None);
    }

    // -----------------------------------------------------------------
    // Protocol side.
    // -----------------------------------------------------------------

    /// One request line in, one response line out. Never panics; a
    /// malformed line is answered (with its id when recoverable) so the
    /// client can correlate the failure.
    fn dispatch_line(&self, line: &str) -> String {
        match protocol::parse_request(line) {
            Ok(req) => match self.dispatch(req.verb) {
                Ok(data) => protocol::ok_frame(req.id, data),
                Err(e) => protocol::err_frame(Some(req.id), &e),
            },
            Err(e) => {
                // Best-effort id recovery for correlation.
                let id = Json::parse(line.trim()).ok().and_then(|j| {
                    j.get("id").and_then(|v| v.as_f64()).and_then(|n| {
                        (n >= 0.0 && n.fract() == 0.0).then_some(n as u64)
                    })
                });
                protocol::err_frame(id, &e)
            }
        }
    }

    fn dispatch(&self, verb: Verb) -> Result<Json, ProtoError> {
        match verb {
            Verb::Submit { spec, tenant, sim, sim_us } => {
                self.submit(&spec, tenant, sim, sim_us)
            }
            Verb::Status { job: Some(id) } => self.job_status(id),
            Verb::Status { job: None } => Ok(self.aggregate_status()),
            Verb::Cancel { job } => self.cancel(job),
            Verb::SetBudget { budget_bytes, ceiling_bytes } => {
                let ceiling = ceiling_bytes
                    .unwrap_or_else(|| {
                        self.admission.ceiling().max(budget_bytes)
                    })
                    .max(budget_bytes);
                self.admission.set_budget_with_ceiling(budget_bytes, ceiling);
                Ok(Json::obj(vec![
                    ("budget_bytes", Json::num(budget_bytes as f64)),
                    ("ceiling_bytes", Json::num(ceiling as f64)),
                ]))
            }
            Verb::Drain => {
                let pending = {
                    let mut st = self.st.lock().unwrap();
                    st.draining = true;
                    st.counts.active()
                };
                self.cv.notify_all();
                Ok(Json::obj(vec![
                    ("draining", Json::Bool(true)),
                    ("pending", Json::num(pending as f64)),
                ]))
            }
            Verb::Shutdown => {
                self.stop.store(true, Ordering::SeqCst);
                self.admission.close();
                self.cv.notify_all();
                Ok(Json::obj(vec![("stopping", Json::Bool(true))]))
            }
        }
    }

    fn submit(
        &self,
        spec_json: &Json,
        tenant: String,
        sim: bool,
        sim_us: u64,
    ) -> Result<Json, ProtoError> {
        let mut spec = JobSpec::from_json(spec_json, &self.base)
            .map_err(|e| ProtoError::new(code::BAD_SPEC, format!("{e:#}")))?;
        let cost = job_cost_bytes(&spec)
            .map_err(|e| ProtoError::new(code::BAD_SPEC, format!("{e:#}")))?;
        let wbytes = job_weight_class(&spec)
            .map_err(|e| ProtoError::new(code::BAD_SPEC, format!("{e:#}")))?
            .bytes;
        // Permanent refusals are diagnosed at SUBMIT, not when a worker
        // finally gets to the job: the client hears "never" now.
        let ceiling = self.admission.ceiling();
        if cost.saturating_add(wbytes) > ceiling {
            return Err(ProtoError::new(
                code::OVER_BUDGET,
                format!(
                    "job needs {} MB solo ({} activations + {} weights) but \
                     the budget ceiling is {} MB — it can never be admitted",
                    fmt_mb(cost + wbytes),
                    fmt_mb(cost),
                    fmt_mb(wbytes),
                    fmt_mb(ceiling)
                ),
            ));
        }
        if let Some(&quota) = self.quotas.get(&tenant) {
            if cost > quota {
                return Err(ProtoError::new(
                    code::QUOTA_EXCEEDED,
                    format!(
                        "job cost {} MB exceeds tenant '{tenant}' quota {} MB",
                        fmt_mb(cost),
                        fmt_mb(quota)
                    ),
                ));
            }
        }
        let id = {
            let mut st = self.st.lock().unwrap();
            if st.draining || self.stop.load(Ordering::SeqCst) {
                return Err(ProtoError::new(
                    code::DRAINING,
                    "daemon is draining; no new jobs accepted",
                ));
            }
            let id = st.next_id;
            st.next_id += 1;
            if spec_json.get("seed").is_none() {
                // Same discipline as the fleet's job file: jobs that do
                // not pin a seed get a derived per-job data stream.
                spec.seed = derive(self.job_seed, id);
            }
            // Journal BEFORE ack: once the client hears the id, a crash
            // must not lose the job.
            if let Err(e) =
                self.write_sidecar(id, &tenant, sim, sim_us, &spec)
            {
                st.next_id -= 1;
                return Err(ProtoError::new(
                    code::INTERNAL,
                    format!("{e:#}"),
                ));
            }
            st.jobs.insert(
                id,
                JobRecord {
                    tenant: tenant.clone(),
                    spec,
                    sim,
                    sim_us,
                    state: JobState::Queued,
                    submitted: Instant::now(),
                    sim_steps_done: 0,
                    parked_snap: None,
                    preempts: 0,
                    resumes: 0,
                    error: None,
                    cancel: Arc::new(AtomicBool::new(false)),
                    latency_s: None,
                    recovered: false,
                },
            );
            st.counts.queued += 1;
            let w = self.weight_of(&tenant);
            let t = st.tenant_entry(&tenant, w);
            t.submitted += 1;
            t.queue.push_back(id);
            id
        };
        self.registry.counter_add("serve/submitted", 1);
        self.cv.notify_all();
        Ok(Json::obj(vec![
            ("job", Json::num(id as f64)),
            ("tenant", Json::str(tenant)),
            ("cost_bytes", Json::num(cost as f64)),
        ]))
    }

    fn job_status(&self, id: u64) -> Result<Json, ProtoError> {
        let st = self.st.lock().unwrap();
        let rec = st.jobs.get(&id).ok_or_else(|| {
            ProtoError::new(code::UNKNOWN_JOB, format!("no job {id}"))
        })?;
        let mut pairs = vec![
            ("job", Json::num(id as f64)),
            ("state", Json::str(rec.state.name())),
            ("tenant", Json::str(&rec.tenant)),
            ("preempts", Json::num(rec.preempts as f64)),
            ("resumes", Json::num(rec.resumes as f64)),
            ("recovered", Json::Bool(rec.recovered)),
        ];
        if rec.cancel.load(Ordering::SeqCst) && rec.state == JobState::Running
        {
            pairs.push(("cancelling", Json::Bool(true)));
        }
        if let Some(e) = &rec.error {
            pairs.push(("error", Json::str(e)));
        }
        if let Some(l) = rec.latency_s {
            pairs.push(("latency_s", Json::Num(l)));
        }
        Ok(Json::obj(pairs))
    }

    fn aggregate_status(&self) -> Json {
        let st = self.st.lock().unwrap();
        let c = st.counts;
        let tenants = Json::Obj(
            st.tenants
                .iter()
                .map(|(name, t)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("weight", Json::num(t.weight as f64)),
                            ("queued", Json::num(t.queue.len() as f64)),
                            ("submitted", Json::num(t.submitted as f64)),
                            ("done", Json::num(t.done as f64)),
                            ("failed", Json::num(t.failed as f64)),
                            ("cancelled", Json::num(t.cancelled as f64)),
                            ("steps", Json::num(t.steps as f64)),
                            (
                                "committed_bytes",
                                Json::num(
                                    self.admission.tenant_committed(name)
                                        as f64,
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let total = st.jobs.len();
        let draining = st.draining;
        drop(st);
        let latency = match self.registry.histogram("serve/latency_s") {
            Some(h) => Json::obj(vec![
                ("count", Json::num(h.count as f64)),
                ("mean", Json::Num(h.mean)),
                ("p50", Json::Num(h.p50)),
                ("p90", Json::Num(h.p90)),
                ("p99", Json::Num(h.p99)),
                ("max", Json::Num(h.max)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("draining", Json::Bool(draining)),
            ("budget_bytes", Json::num(self.admission.budget() as f64)),
            ("ceiling_bytes", Json::num(self.admission.ceiling() as f64)),
            (
                "jobs",
                Json::obj(vec![
                    ("total", Json::num(total as f64)),
                    ("queued", Json::num(c.queued as f64)),
                    ("running", Json::num(c.running as f64)),
                    ("parked", Json::num(c.parked as f64)),
                    ("done", Json::num(c.done as f64)),
                    ("failed", Json::num(c.failed as f64)),
                    ("cancelled", Json::num(c.cancelled as f64)),
                ]),
            ),
            ("recovered", Json::num(self.recovered as f64)),
            (
                "preempts",
                Json::num(self.registry.counter("fleet/preempts") as f64),
            ),
            (
                "resumes",
                Json::num(self.registry.counter("fleet/resumes") as f64),
            ),
            ("fleet_steps", Json::num(self.progress.total() as f64)),
            ("latency_s", latency),
            ("tenants", tenants),
        ])
    }

    fn cancel(&self, id: u64) -> Result<Json, ProtoError> {
        let outcome = {
            let mut st = self.st.lock().unwrap();
            let rec = st.jobs.get_mut(&id).ok_or_else(|| {
                ProtoError::new(code::UNKNOWN_JOB, format!("no job {id}"))
            })?;
            rec.cancel.store(true, Ordering::SeqCst);
            match rec.state {
                JobState::Queued | JobState::Parked => {
                    let tenant = rec.tenant.clone();
                    if let Some(t) = st.tenants.get_mut(&tenant) {
                        t.queue.retain(|j| *j != id);
                    }
                    None // settle below, outside the lock
                }
                s => Some(s),
            }
        };
        match outcome {
            None => {
                self.finish(id, JobState::Cancelled, None);
                Ok(Json::obj(vec![
                    ("job", Json::num(id as f64)),
                    ("state", Json::str("cancelled")),
                ]))
            }
            Some(JobState::Running) => Ok(Json::obj(vec![
                ("job", Json::num(id as f64)),
                ("state", Json::str("running")),
                ("cancelling", Json::Bool(true)),
            ])),
            Some(s) => Ok(Json::obj(vec![
                // Terminal already: idempotent, report where it ended.
                ("job", Json::num(id as f64)),
                ("state", Json::str(s.name())),
            ])),
        }
    }
}

// ---------------------------------------------------------------------
// The server shell: startup (exit-code 3 territory) vs runtime.
// ---------------------------------------------------------------------

/// What the daemon did over its lifetime (rendered at exit; `failed > 0`
/// maps to [`EXIT_JOB_FAILURES`]).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub submitted: u64,
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Jobs still queued/parked at exit — journaled on disk, recovered
    /// by the next daemon on this snapshot dir.
    pub pending: u64,
    pub recovered: u64,
    pub preempts: u64,
    pub resumes: u64,
    pub uptime_s: f64,
}

impl ServeSummary {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.uptime_s > 0.0 {
            self.done as f64 / self.uptime_s
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        format!(
            "## serve summary\n\n\
             jobs: {} submitted ({} recovered), {} done, {} failed, \
             {} cancelled, {} pending\n\
             preempts {} | resumes {} | uptime {:.2}s | {:.2} jobs/s\n",
            self.submitted,
            self.recovered,
            self.done,
            self.failed,
            self.cancelled,
            self.pending,
            self.preempts,
            self.resumes,
            self.uptime_s,
            self.jobs_per_sec()
        )
    }
}

/// A started-but-not-yet-serving daemon. [`Server::start`] does every
/// failable setup step (lock, recovery rescan, socket bind) so the CLI
/// can map its errors to [`EXIT_STARTUP`]; [`Server::run`] errors are
/// runtime failures ([`EXIT_RUNTIME`]).
pub struct Server {
    daemon: Arc<Daemon>,
    listener: UnixListener,
    /// Held for the daemon's lifetime; released (file removed) on drop.
    _lock: LockFile,
}

impl Server {
    pub fn start(opts: ServeOptions, base: TrainConfig) -> anyhow::Result<Server> {
        anyhow::ensure!(opts.budget_bytes > 0, "serve budget must be positive");
        anyhow::ensure!(opts.workers > 0, "serve needs at least one worker");
        // sun_path is ~108 bytes; overlong paths fail at bind with an
        // opaque OS error, so name the limit ourselves.
        anyhow::ensure!(
            opts.socket.as_os_str().len() <= 100,
            "socket path {} is too long for a Unix socket (limit ~100 bytes)",
            opts.socket.display()
        );
        let lock = LockFile::acquire(&opts.snapshot_dir, "serve.lock")?;
        let recovered_jobs = scan_recovery(&opts.snapshot_dir)?;

        // A socket file left by a SIGKILLed daemon must be cleared before
        // bind; a CONNECTABLE one means someone is live on it (the lock
        // should have caught that, but a different snapshot dir with the
        // same socket path would not).
        if opts.socket.exists() {
            if UnixStream::connect(&opts.socket).is_ok() {
                anyhow::bail!(
                    "socket {} is already being served",
                    opts.socket.display()
                );
            }
            std::fs::remove_file(&opts.socket).map_err(|e| {
                anyhow::anyhow!(
                    "remove stale socket {}: {e}",
                    opts.socket.display()
                )
            })?;
        }
        let listener = UnixListener::bind(&opts.socket).map_err(|e| {
            anyhow::anyhow!("bind socket {}: {e}", opts.socket.display())
        })?;
        listener.set_nonblocking(true).map_err(|e| {
            anyhow::anyhow!("set socket non-blocking: {e}")
        })?;

        let admission = Admission::new(opts.budget_bytes);
        let ceiling = opts
            .budget_schedule
            .iter()
            .map(|c| c.budget_bytes)
            .max()
            .unwrap_or(0)
            .max(opts.budget_bytes);
        admission.set_budget_with_ceiling(opts.budget_bytes, ceiling);
        admission.enable_preemption();
        for (tenant, quota) in &opts.quotas {
            admission.set_tenant_quota(tenant, *quota);
        }
        let quotas: HashMap<String, u64> =
            opts.quotas.iter().cloned().collect();
        let weights: HashMap<String, u64> =
            opts.tenant_weights.iter().cloned().collect();

        let aggregate = MemoryTracker::new();
        let weight_cache = WeightCache::new(aggregate.child());
        let registry = MetricsRegistry::new();
        let progress = Progress::new(opts.budget_schedule.clone());

        // Re-admit every journaled job: parked where a snapshot exists,
        // queued-from-scratch otherwise (sim jobs always requeue fresh —
        // their virtual progress died with the process, and replaying it
        // is free by construction).
        let mut st = DaemonState {
            jobs: BTreeMap::new(),
            tenants: BTreeMap::new(),
            counts: Counts::default(),
            next_id: 0,
            draining: false,
        };
        let recovered = recovered_jobs.len() as u64;
        for r in recovered_jobs {
            let state = if r.snap.is_some() {
                JobState::Parked
            } else {
                JobState::Queued
            };
            *st.counts.slot(state) += 1;
            st.next_id = st.next_id.max(r.id + 1);
            let w = weights.get(&r.tenant).copied().unwrap_or(1);
            let t = st.tenant_entry(&r.tenant, w);
            t.submitted += 1;
            t.queue.push_back(r.id);
            st.jobs.insert(
                r.id,
                JobRecord {
                    tenant: r.tenant,
                    spec: r.spec,
                    sim: r.sim,
                    sim_us: r.sim_us,
                    state,
                    submitted: Instant::now(),
                    sim_steps_done: 0,
                    parked_snap: r.snap,
                    preempts: 0,
                    resumes: 0,
                    error: None,
                    cancel: Arc::new(AtomicBool::new(false)),
                    latency_s: None,
                    recovered: true,
                },
            );
        }
        registry.counter_add("serve/recovered", recovered);

        let job_seed = derive(base.seed, stream::JOB);
        let daemon = Arc::new(Daemon {
            st: Mutex::new(st),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            admission,
            registry,
            aggregate,
            weight_cache,
            progress,
            base,
            opts,
            quotas,
            weights,
            job_seed,
            started: Instant::now(),
            recovered,
        });
        Ok(Server { daemon, listener, _lock: lock })
    }

    /// Serve until a `shutdown` verb or until draining completes.
    /// Connection handlers are detached threads (a lingering client must
    /// not block exit); workers are joined so running jobs finish
    /// parking before the summary is computed.
    pub fn run(self) -> anyhow::Result<ServeSummary> {
        let d = &self.daemon;
        let workers = d.opts.workers;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let d = Arc::clone(d);
                std::thread::spawn(move || d.worker_loop(workers))
            })
            .collect();

        let result = loop {
            if d.stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            {
                let st = d.st.lock().unwrap();
                if st.draining && st.counts.active() == 0 {
                    drop(st);
                    d.stop.store(true, Ordering::SeqCst);
                    d.admission.close();
                    d.cv.notify_all();
                    break Ok(());
                }
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let d = Arc::clone(d);
                    std::thread::spawn(move || handle_conn(&d, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => {
                    d.stop.store(true, Ordering::SeqCst);
                    d.admission.close();
                    d.cv.notify_all();
                    break Err(anyhow::anyhow!("accept failed: {e}"));
                }
            }
        };
        for h in handles {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&d.opts.socket);
        result?;

        let uptime_s = d.started.elapsed().as_secs_f64();
        d.registry.gauge_set("serve/uptime_s", uptime_s);
        d.registry
            .gauge_set("serve/aggregate_peak_bytes", d.aggregate.peak() as f64);
        if let Some(p) = &d.opts.metrics_out {
            d.registry.export_jsonl(p)?;
        }
        let st = d.st.lock().unwrap();
        Ok(ServeSummary {
            submitted: st.jobs.len() as u64,
            done: st.counts.done as u64,
            failed: st.counts.failed as u64,
            cancelled: st.counts.cancelled as u64,
            pending: st.counts.active() as u64,
            recovered: d.recovered,
            preempts: d.registry.counter("fleet/preempts"),
            resumes: d.registry.counter("fleet/resumes"),
            uptime_s,
        })
    }

    /// The daemon's socket path (tests connect to it while `run` serves
    /// on another thread).
    pub fn socket(&self) -> &Path {
        &self.daemon.opts.socket
    }
}

/// One client connection: JSONL request/response in lockstep. Reads are
/// length-capped so an unterminated line cannot balloon memory — an
/// oversized frame is answered, then the connection dropped (the stream
/// is desynced past the limit).
fn handle_conn(d: &Daemon, stream: UnixStream) {
    // The listener is non-blocking; accepted streams must not be.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let Ok(mut out) = stream.try_clone() else { return };
    let mut reader =
        BufReader::new(stream).take(protocol::MAX_FRAME_BYTES as u64 + 2);
    loop {
        reader.set_limit(protocol::MAX_FRAME_BYTES as u64 + 2);
        let mut buf = Vec::new();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return, // client hung up
            Ok(_) => {}
            Err(_) => return,
        }
        if !buf.ends_with(b"\n") && buf.len() >= protocol::MAX_FRAME_BYTES {
            let e = ProtoError::new(
                code::OVERSIZED_FRAME,
                format!(
                    "frame exceeds {} bytes; closing connection",
                    protocol::MAX_FRAME_BYTES
                ),
            );
            let _ = writeln!(out, "{}", protocol::err_frame(None, &e));
            return;
        }
        let line = match String::from_utf8(buf) {
            Ok(s) => s,
            Err(_) => {
                let e =
                    ProtoError::new(code::BAD_JSON, "frame is not UTF-8");
                if writeln!(out, "{}", protocol::err_frame(None, &e)).is_err()
                {
                    return;
                }
                continue;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        if writeln!(out, "{}", d.dispatch_line(&line)).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_are_pinned() {
        // CI scripts and docs/serving.md hard-code these.
        assert_eq!(EXIT_OK, 0);
        assert_eq!(EXIT_RUNTIME, 1);
        assert_eq!(EXIT_JOB_FAILURES, 2);
        assert_eq!(EXIT_STARTUP, 3);
    }

    #[test]
    fn sidecar_roundtrips_full_u64_seeds() {
        // Derived seeds use all 64 bits; a JSON-number encoding would
        // shear them through f64. The sidecar must be exact.
        let mut spec = JobSpec::from_base(&TrainConfig::default());
        spec.seed = 0xDEAD_BEEF_CAFE_F00D; // not representable in f64
        spec.model_seed = Some(u64::MAX - 1);
        spec.steps = 17;
        spec.priority = 3;
        spec.quant = QuantMode::Q4;
        spec.lr = 0.0123;
        let j = sidecar_json(42, "alice", true, 50, &spec);
        let text = j.to_string();
        let back = sidecar_parse(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.tenant, "alice");
        assert!(back.sim);
        assert_eq!(back.sim_us, 50);
        assert_eq!(back.spec.seed, spec.seed, "seed must survive bit-exact");
        assert_eq!(back.spec.model_seed, spec.model_seed);
        assert_eq!(back.spec.steps, 17);
        assert_eq!(back.spec.priority, 3);
        assert_eq!(back.spec.quant, QuantMode::Q4);
        assert_eq!(back.spec.lr, spec.lr, "lr must survive bit-exact");
        assert_eq!(back.spec.method, spec.method);
    }

    #[test]
    fn sidecar_null_model_seed_roundtrips() {
        let mut spec = JobSpec::from_base(&TrainConfig::default());
        spec.model_seed = None;
        let j = sidecar_json(0, "default", false, 0, &spec);
        let back = sidecar_parse(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.spec.model_seed, None);
        assert!(!back.sim);
    }

    fn state_with(tenants: &[(&str, u64, &[u64])]) -> DaemonState {
        let mut st = DaemonState {
            jobs: BTreeMap::new(),
            tenants: BTreeMap::new(),
            counts: Counts::default(),
            next_id: 0,
            draining: false,
        };
        for (name, weight, ids) in tenants {
            let t = st.tenant_entry(name, *weight);
            for id in *ids {
                t.queue.push_back(*id);
            }
            for id in *ids {
                st.jobs.insert(
                    *id,
                    JobRecord {
                        tenant: name.to_string(),
                        spec: JobSpec::from_base(&TrainConfig::default()),
                        sim: true,
                        sim_us: 0,
                        state: JobState::Queued,
                        submitted: Instant::now(),
                        sim_steps_done: 0,
                        parked_snap: None,
                        preempts: 0,
                        resumes: 0,
                        error: None,
                        cancel: Arc::new(AtomicBool::new(false)),
                        latency_s: None,
                        recovered: false,
                    },
                );
                st.counts.queued += 1;
            }
        }
        st
    }

    #[test]
    fn wfq_dispatch_follows_weights() {
        // Tenant b (weight 2) must get exactly twice tenant a's (weight
        // 1) dispatches while both stay backlogged.
        let mut st = state_with(&[
            ("a", 1, &[0, 1, 2, 3, 4, 5]),
            ("b", 2, &[10, 11, 12, 13, 14, 15]),
        ]);
        let mut a = 0;
        let mut b = 0;
        for _ in 0..9 {
            let id = pick_wfq(&mut st).unwrap();
            if id < 10 {
                a += 1;
            } else {
                b += 1;
            }
        }
        assert_eq!((a, b), (3, 6), "weight-2 tenant gets a 2:1 share");
        assert_eq!(st.counts.running, 9);
        assert_eq!(st.counts.queued, 3);
    }

    #[test]
    fn wfq_idle_tenant_share_flows_to_the_backlogged() {
        let mut st = state_with(&[("a", 1, &[0, 1, 2]), ("b", 8, &[])]);
        for want in [0, 1, 2] {
            assert_eq!(pick_wfq(&mut st), Some(want), "idle b never blocks a");
        }
        assert_eq!(pick_wfq(&mut st), None);
    }

    #[test]
    fn wfq_newcomer_starts_at_the_pass_floor() {
        let mut st = state_with(&[("a", 1, &[0, 1, 2, 3])]);
        // a accumulates pass…
        assert_eq!(pick_wfq(&mut st), Some(0));
        assert_eq!(pick_wfq(&mut st), Some(1));
        // …then z arrives. It must start at a's pass (the floor), not at
        // zero-minus-history: it gets its fair share from NOW on, not a
        // make-up monopoly over everything a already consumed.
        let t = st.tenant_entry("z", 1);
        t.queue.push_back(100);
        st.jobs.insert(
            100,
            JobRecord {
                tenant: "z".into(),
                spec: JobSpec::from_base(&TrainConfig::default()),
                sim: true,
                sim_us: 0,
                state: JobState::Queued,
                submitted: Instant::now(),
                sim_steps_done: 0,
                parked_snap: None,
                preempts: 0,
                resumes: 0,
                error: None,
                cancel: Arc::new(AtomicBool::new(false)),
                latency_s: None,
                recovered: false,
            },
        );
        st.counts.queued += 1;
        let za = st.tenants["z"].pass;
        let aa = st.tenants["a"].pass;
        assert_eq!(za, aa, "newcomer pass equals the current floor");
        // Alternating service from here (name breaks the tie).
        assert_eq!(pick_wfq(&mut st), Some(2), "tie broken by name: a first");
        assert_eq!(pick_wfq(&mut st), Some(100));
        assert_eq!(pick_wfq(&mut st), Some(3));
    }

    #[test]
    fn tenant_list_parses_and_validates() {
        let q = parse_tenant_list("a:64,b:128", "quota", true).unwrap();
        assert_eq!(
            q,
            vec![("a".to_string(), 64 << 20), ("b".to_string(), 128 << 20)]
        );
        let w = parse_tenant_list("a:1, b:3", "weight", false).unwrap();
        assert_eq!(w, vec![("a".to_string(), 1), ("b".to_string(), 3)]);
        for bad in ["", "a", "a:", ":3", "a:x", "a:0"] {
            assert!(
                parse_tenant_list(bad, "quota", true).is_err(),
                "must reject '{bad}'"
            );
        }
    }

    #[test]
    fn recovery_scan_pairs_sidecars_with_newest_snapshots() {
        let dir = std::env::temp_dir().join("mesp-test-serve-scan");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = JobSpec::from_base(&TrainConfig::default());
        for id in [3u64, 7] {
            std::fs::write(
                dir.join(format!("job-{id}.json")),
                sidecar_json(id, "t", false, 0, &spec).to_string(),
            )
            .unwrap();
        }
        // job 3: two checkpoints — the newest must win. job 7: none.
        std::fs::write(dir.join("job-3-step-2.snap"), b"old").unwrap();
        std::fs::write(dir.join("job-3-step-10.snap"), b"new").unwrap();
        // Noise that must be ignored: final snaps without sidecars,
        // tmp files, unrelated names.
        std::fs::write(dir.join("job-9-final.snap"), b"done").unwrap();
        std::fs::write(dir.join("job-4.json.tmp"), b"half").unwrap();
        std::fs::write(dir.join("serve.lock"), b"123").unwrap();

        let rec = scan_recovery(&dir).unwrap();
        assert_eq!(rec.len(), 2);
        assert_eq!(rec[0].id, 3);
        assert_eq!(
            rec[0].snap.as_deref(),
            Some(dir.join("job-3-step-10.snap").as_path()),
            "newest checkpoint wins"
        );
        assert_eq!(rec[1].id, 7);
        assert!(rec[1].snap.is_none(), "no checkpoint → requeue from scratch");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_scan_names_a_corrupt_sidecar() {
        let dir = std::env::temp_dir().join("mesp-test-serve-corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("job-0.json"), "{not json").unwrap();
        let err = scan_recovery(&dir).unwrap_err().to_string();
        assert!(err.contains("job-0.json"), "names the file: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
