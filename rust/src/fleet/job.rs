//! Fleet job descriptions: what to train (config + method + steps + seed)
//! without any of the per-run wiring. Jobs come from a JSONL job file
//! (one object per line) or from a generated grid; either way each job
//! gets its own derived seed stream so jobs sharing a base seed do NOT
//! see identical data.

use std::path::Path;

use crate::config::{ActCompress, Method, OptimizerKind, QuantMode, TrainConfig};
use crate::util::rng::{derive, stream};
use crate::util::Json;

/// The allowed keys of one JSONL job object — anything else is a typo
/// and fails loudly (same discipline as the CLI flag allowlists).
/// `from_json`'s match must accept exactly this set (asserted by the
/// `job_keys_list_matches_parser` test).
pub const JOB_KEYS: &[&str] = &[
    "config", "method", "steps", "seed", "lr", "optimizer", "quant", "priority",
    "model_seed", "loss_chunk", "act_compress",
];

/// Highest admissible job priority (priorities are 0..=9; 0 = default).
pub const MAX_PRIORITY: u64 = 9;

/// A JSON number that must be a non-negative integer (seeds, step
/// counts): floats with fractional parts, negatives, and values beyond
/// f64's exact-integer range are rejected instead of silently truncated.
fn as_exact_u64(v: &Json, key: &str) -> anyhow::Result<u64> {
    let n = v
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number"))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64,
        "'{key}' must be a non-negative integer <= 2^53, got {n}"
    );
    Ok(n as u64)
}

/// What one fine-tuning job trains. Everything not listed here (backend,
/// artifacts dir, logging…) comes from the fleet's base [`TrainConfig`].
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: String,
    pub method: Method,
    pub steps: usize,
    pub seed: u64,
    pub lr: f32,
    pub optimizer: OptimizerKind,
    /// Resident precision of the job's frozen base weights — admission
    /// charges the packed footprint under `q4`, so the same budget
    /// overlaps more quantized jobs.
    pub quant: QuantMode,
    /// Loss-head streaming tile (rows of the sequence per chunk; 0 =
    /// unchunked). Admission charges only the tile's logits slab, so a
    /// chunked long-context job costs far less of the budget.
    pub loss_chunk: usize,
    /// Compression of buffered activations (store-h's saved h = xA);
    /// `int8` shrinks the per-layer stored-h charge ~4×.
    pub act_compress: ActCompress,
    /// Pinned seed of the frozen base weights. `None` derives the model
    /// stream from the job's own `seed` (private weights); `Some` pins
    /// it, so jobs sharing the pin (and config + quant) attach to ONE
    /// cached `FrozenModel` and admission charges its bytes once across
    /// all of them. [`grid`] pins every generated job to the base
    /// config's model stream for exactly this reason.
    pub model_seed: Option<u64>,
    /// Scheduling priority 0..=9 (higher wins). When the budget cannot
    /// fit an arriving higher-priority job — or shrinks mid-run under a
    /// `--budget-schedule` — the scheduler preempts the lowest-priority
    /// RUNNING job: its session is snapshotted to disk, its budget
    /// reservation released, and it re-enters the queue to resume later.
    pub priority: u8,
}

impl JobSpec {
    /// A spec inheriting every field from the fleet's base config.
    pub fn from_base(base: &TrainConfig) -> JobSpec {
        JobSpec {
            config: base.config.clone(),
            method: base.method,
            steps: base.steps,
            seed: base.seed,
            lr: base.lr,
            optimizer: base.optimizer,
            quant: base.quant,
            loss_chunk: base.loss_chunk,
            act_compress: base.act_compress,
            model_seed: base.model_seed,
            priority: 0,
        }
    }

    /// Parse one JSONL job object, with `base` supplying defaults for
    /// absent keys. Unknown keys are rejected.
    pub fn from_json(j: &Json, base: &TrainConfig) -> anyhow::Result<JobSpec> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("job line must be a JSON object"))?;
        let mut spec = JobSpec::from_base(base);
        for (k, v) in obj {
            match k.as_str() {
                "config" => {
                    spec.config = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("'config' must be a string"))?
                        .to_string();
                }
                "method" => {
                    spec.method = Method::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("'method' must be a string"))?,
                    )?;
                }
                "steps" => {
                    spec.steps = as_exact_u64(v, "steps")? as usize;
                }
                "seed" => {
                    spec.seed = as_exact_u64(v, "seed")?;
                }
                "lr" => {
                    let lr = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("'lr' must be a number"))?;
                    anyhow::ensure!(
                        lr.is_finite() && lr > 0.0,
                        "'lr' must be a positive float, got {lr}"
                    );
                    spec.lr = lr as f32;
                }
                "optimizer" => {
                    spec.optimizer = OptimizerKind::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("'optimizer' must be a string"))?,
                    )?;
                }
                "quant" => {
                    spec.quant = QuantMode::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("'quant' must be a string"))?,
                    )?;
                }
                "model_seed" => {
                    spec.model_seed = Some(as_exact_u64(v, "model_seed")?);
                }
                "loss_chunk" => {
                    spec.loss_chunk = as_exact_u64(v, "loss_chunk")? as usize;
                }
                "act_compress" => {
                    spec.act_compress = ActCompress::parse(
                        v.as_str().ok_or_else(|| {
                            anyhow::anyhow!("'act_compress' must be a string")
                        })?,
                    )?;
                }
                "priority" => {
                    let p = as_exact_u64(v, "priority")?;
                    anyhow::ensure!(
                        p <= MAX_PRIORITY,
                        "'priority' must be 0..={MAX_PRIORITY}, got {p}"
                    );
                    spec.priority = p as u8;
                }
                other => anyhow::bail!(
                    "unknown job key '{other}' (known: {})",
                    JOB_KEYS.join(", ")
                ),
            }
        }
        Ok(spec)
    }

    /// The full training config this job runs under: base wiring
    /// (backend, artifacts, logging) + this spec's overrides.
    pub fn to_train_config(&self, base: &TrainConfig) -> TrainConfig {
        TrainConfig {
            config: self.config.clone(),
            method: self.method,
            steps: self.steps,
            seed: self.seed,
            lr: self.lr,
            optimizer: self.optimizer,
            quant: self.quant,
            loss_chunk: self.loss_chunk,
            act_compress: self.act_compress,
            model_seed: self.model_seed,
            ..base.clone()
        }
    }
}

/// One schedulable unit: a spec plus its stable queue id (report order).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    pub spec: JobSpec,
}

/// Load jobs from a JSONL file: one JSON object per line, blank lines
/// ignored. Each job inherits defaults from `base`; a job that does not
/// set `seed` explicitly gets a derived per-job seed stream.
pub fn load_jobs(path: &Path, base: &TrainConfig) -> anyhow::Result<Vec<Job>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read job file {}: {e}", path.display()))?;
    let job_seed = derive(base.seed, stream::JOB);
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        // Malformed lines name the file AND the line — a fleet launched
        // from several stitched job files must point at the real source.
        let j = Json::parse(line).map_err(|e| {
            anyhow::anyhow!("job file {}:{}: {e}", path.display(), lineno + 1)
        })?;
        let mut spec = JobSpec::from_json(&j, base).map_err(|e| {
            anyhow::anyhow!("job file {}:{}: {e}", path.display(), lineno + 1)
        })?;
        if j.get("seed").is_none() {
            spec.seed = derive(job_seed, jobs.len() as u64);
        }
        jobs.push(Job { id: jobs.len(), spec });
    }
    anyhow::ensure!(!jobs.is_empty(), "job file {} has no jobs", path.display());
    Ok(jobs)
}

/// Generate a grid of `count` jobs on the base config, cycling through
/// `methods`. Every job gets its own seed derived from the base seed and
/// the job index, so the fleet trains on `count` distinct data streams —
/// but all of them pin `model_seed` to the base config's model stream,
/// so the whole grid fine-tunes ONE shared frozen base (one cached copy,
/// charged once by admission) on distinct data.
pub fn grid(base: &TrainConfig, methods: &[Method], count: usize) -> Vec<Job> {
    if methods.is_empty() {
        return Vec::new();
    }
    let job_seed = derive(base.seed, stream::JOB);
    let model_seed = base.model_seed();
    (0..count)
        .map(|i| {
            let mut spec = JobSpec::from_base(base);
            spec.method = methods[i % methods.len()];
            spec.seed = derive(job_seed, i as u64);
            spec.model_seed = Some(model_seed);
            Job { id: i, spec }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> TrainConfig {
        TrainConfig { steps: 7, seed: 42, ..Default::default() }
    }

    #[test]
    fn grid_cycles_methods_and_derives_seeds() {
        let jobs = grid(&base(), &[Method::Mesp, Method::Mebp], 4);
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].spec.method, Method::Mesp);
        assert_eq!(jobs[1].spec.method, Method::Mebp);
        assert_eq!(jobs[2].spec.method, Method::Mesp);
        let seeds: Vec<u64> = jobs.iter().map(|j| j.spec.seed).collect();
        for (i, a) in seeds.iter().enumerate() {
            assert_ne!(*a, 42, "job seeds must differ from the base seed");
            for b in &seeds[i + 1..] {
                assert_ne!(a, b, "job seeds must be pairwise distinct");
            }
        }
        assert_eq!(jobs[3].spec.steps, 7, "grid inherits base steps");
        for j in &jobs {
            assert_eq!(
                j.spec.model_seed,
                Some(base().model_seed()),
                "grid jobs pin the base model stream (shared frozen weights)"
            );
        }
    }

    #[test]
    fn json_overrides_and_defaults() {
        let j = Json::parse(
            r#"{"method": "mebp", "steps": 3, "seed": 9, "lr": 0.01}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&j, &base()).unwrap();
        assert_eq!(spec.method, Method::Mebp);
        assert_eq!(spec.steps, 3);
        assert_eq!(spec.seed, 9);
        assert!((spec.lr - 0.01).abs() < 1e-9);
        assert_eq!(spec.config, "toy", "inherited from base");
    }

    #[test]
    fn json_unknown_key_rejected() {
        let j = Json::parse(r#"{"mthod": "mebp"}"#).unwrap();
        let err = JobSpec::from_json(&j, &base()).unwrap_err().to_string();
        assert!(err.contains("unknown job key"), "{err}");
    }

    #[test]
    fn json_invalid_numbers_fail_loudly() {
        for bad in [
            r#"{"seed": -3}"#,
            r#"{"seed": 1.7}"#,
            r#"{"steps": -1}"#,
            r#"{"steps": 2.5}"#,
            r#"{"lr": -0.01}"#,
            r#"{"lr": 0}"#,
            r#"{"seed": 1e17}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(
                JobSpec::from_json(&j, &base()).is_err(),
                "must reject {bad}"
            );
        }
    }

    #[test]
    fn job_keys_list_matches_parser() {
        // JOB_KEYS is the advertised allowlist; the parser must accept
        // exactly that set (a valid value per key), nothing more.
        for (key, val) in [
            ("config", "\"toy\""),
            ("method", "\"mesp\""),
            ("steps", "3"),
            ("seed", "7"),
            ("lr", "0.01"),
            ("optimizer", "\"adam\""),
            ("quant", "\"q4\""),
            ("priority", "9"),
            ("model_seed", "7"),
            ("loss_chunk", "64"),
            ("act_compress", "\"int8\""),
        ] {
            assert!(JOB_KEYS.contains(&key), "test table missing {key}");
            let j = Json::parse(&format!("{{\"{key}\": {val}}}")).unwrap();
            assert!(
                JobSpec::from_json(&j, &base()).is_ok(),
                "advertised key '{key}' rejected"
            );
        }
        assert_eq!(JOB_KEYS.len(), 11, "update the table when adding keys");
    }

    #[test]
    fn model_seed_key_parses_and_defaults_to_base() {
        let j = Json::parse(r#"{"model_seed": 7}"#).unwrap();
        assert_eq!(JobSpec::from_json(&j, &base()).unwrap().model_seed, Some(7));
        let j = Json::parse(r#"{"seed": 5}"#).unwrap();
        assert_eq!(
            JobSpec::from_json(&j, &base()).unwrap().model_seed,
            None,
            "inherits the base's unpinned model seed"
        );
        let j = Json::parse(r#"{"model_seed": -1}"#).unwrap();
        assert!(JobSpec::from_json(&j, &base()).is_err());
    }

    #[test]
    fn priority_key_parses_validates_and_defaults() {
        let j = Json::parse(r#"{"priority": 9}"#).unwrap();
        assert_eq!(JobSpec::from_json(&j, &base()).unwrap().priority, 9);
        let j = Json::parse(r#"{"method": "mesp"}"#).unwrap();
        assert_eq!(JobSpec::from_json(&j, &base()).unwrap().priority, 0,
                   "priority defaults to 0");
        for bad in [r#"{"priority": 10}"#, r#"{"priority": -1}"#,
                    r#"{"priority": 2.5}"#, r#"{"priority": "high"}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&j, &base()).is_err(), "must reject {bad}");
        }
    }

    #[test]
    fn quant_key_parses_and_inherits() {
        let j = Json::parse(r#"{"quant": "q4"}"#).unwrap();
        let spec = JobSpec::from_json(&j, &base()).unwrap();
        assert_eq!(spec.quant, QuantMode::Q4);
        let j = Json::parse(r#"{"method": "mebp"}"#).unwrap();
        assert_eq!(JobSpec::from_json(&j, &base()).unwrap().quant,
                   QuantMode::F32, "inherits the base quant mode");
        let j = Json::parse(r#"{"quant": "q8"}"#).unwrap();
        assert!(JobSpec::from_json(&j, &base()).is_err());
    }

    #[test]
    fn jsonl_file_roundtrip() {
        let dir = std::env::temp_dir().join("mesp-test-fleet-jobs");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("jobs.jsonl");
        std::fs::write(
            &path,
            "{\"method\": \"mesp\", \"steps\": 2}\n\n{\"method\": \"mezo\", \"seed\": 5}\n",
        )
        .unwrap();
        let jobs = load_jobs(&path, &base()).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].spec.method, Method::Mesp);
        assert_eq!(jobs[0].spec.steps, 2);
        assert_ne!(jobs[0].spec.seed, 42, "unset seed gets a derived stream");
        assert_eq!(jobs[1].spec.seed, 5, "explicit seed wins");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jsonl_bad_line_reports_file_and_lineno() {
        let dir = std::env::temp_dir().join("mesp-test-fleet-badjobs");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("jobs.jsonl");
        std::fs::write(&path, "{\"method\": \"mesp\"}\nnot json\n").unwrap();
        let err = load_jobs(&path, &base()).unwrap_err().to_string();
        assert!(err.contains("jobs.jsonl:2:"), "must name file:line — {err}");
        // A bad value (valid JSON, invalid spec) points at its line too.
        std::fs::write(&path, "{\"mthod\": \"mesp\"}\n").unwrap();
        let err = load_jobs(&path, &base()).unwrap_err().to_string();
        assert!(err.contains("jobs.jsonl:1:"), "{err}");
        assert!(err.contains("unknown job key"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
